"""End-to-end offloaded training: learning, policy equivalence (paper
Fig. 19), and the memory ordering the paper claims."""

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (OffloadedTrainer, memascend_policy,
                        zero_infinity_policy)
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _run(policy, steps=10, seed=0):
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(seed))
    tr = OffloadedTrainer(model, policy)
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=1), batch=8,
                    seq_len=32)
    losses, metrics = [], None
    for _ in range(steps):
        b = dl.next_batch()
        metrics = tr.train_step(b["tokens"], b["labels"])
        losses.append(metrics["loss"])
    peak = tr.tracker.peak_allocated
    breakdown = tr.tracker.breakdown()
    tr.close()
    return losses, peak, breakdown, metrics


def test_offloaded_training_learns(tmp_store_root):
    losses, _, _, m = _run(memascend_policy(tmp_store_root, lr=3e-3),
                           steps=20)
    assert losses[-1] < losses[0] - 0.5
    assert m["applied"] and not m["overflowed"]
    assert m["optimizer_io_bytes"] > 0


def test_policy_equivalence_fig19(tmp_store_root):
    """MemAscend is numerics-preserving: identical loss trajectory."""
    l_mem, peak_mem, _, _ = _run(memascend_policy(tmp_store_root + "m",
                                                  lr=3e-3))
    l_base, peak_base, _, _ = _run(zero_infinity_policy(tmp_store_root + "z",
                                                        lr=3e-3))
    np.testing.assert_allclose(l_mem, l_base, rtol=0, atol=1e-6)
    assert peak_mem < peak_base   # and it saves memory while at it


def test_memory_breakdown_components(tmp_store_root):
    _, peak, breakdown, _ = _run(memascend_policy(tmp_store_root, lr=1e-3),
                                 steps=3)
    assert "pinned" in breakdown            # pool arena + flat buffer
    assert "optimizer_stream" in breakdown
    assert "overflow_tmp" in breakdown
    assert "activation_checkpoints" in breakdown
    assert breakdown["activation_checkpoints"]["live_allocated"] == 0  # freed


def test_fp16_loss_scaling_path(tmp_store_root):
    """fp16 compute exercises real dynamic loss scaling end to end."""
    pol = memascend_policy(tmp_store_root, lr=1e-3, compute_dtype="float16")
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    tr = OffloadedTrainer(model, pol)
    assert tr.scaler.scale > 1.0            # fp16 => real scale
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=1), batch=4,
                    seq_len=32)
    for _ in range(3):
        b = dl.next_batch()
        m = tr.train_step(b["tokens"], b["labels"])
    assert np.isfinite(m["loss"])
    tr.close()


def test_bf16_optimizer_reduces_io(tmp_store_root):
    m1 = _run(memascend_policy(tmp_store_root + "a", lr=1e-3), steps=2)[-1]
    m2 = _run(memascend_policy(tmp_store_root + "b", lr=1e-3,
                               bf16_optimizer=True), steps=2)[-1]
    assert m2["optimizer_io_bytes"] < 0.65 * m1["optimizer_io_bytes"]


def test_eval_loss_consistent(tmp_store_root):
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    tr = OffloadedTrainer(model, memascend_policy(tmp_store_root, lr=1e-3))
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=2), batch=4,
                    seq_len=32)
    b = dl.next_batch()
    e1 = tr.eval_loss(b["tokens"], b["labels"])
    m = tr.train_step(b["tokens"], b["labels"])
    # train loss on same batch equals eval loss before the update
    assert abs(e1 - m["loss"]) < 1e-5
    e2 = tr.eval_loss(b["tokens"], b["labels"])
    assert e2 < e1   # the streamed update actually changed the weights
    tr.close()
