"""Property-based lockdown of the overflow screen (fused bitwise pass).

Three families of invariants, run under real ``hypothesis`` when installed
(requirements-dev.txt; CI's ``property-tests`` job) and under the
deterministic in-repo stub otherwise (tests/_hypothesis_stub.py — the
default tier-1 job exercises that path):

* **agreement** — the fused check matches numpy Inf/NaN semantics (and the
  chained baseline) for fp32/fp16/bf16, over array sizes straddling chunk
  boundaries, with ±Inf/NaN payloads at the first element, the last
  element, and arbitrary positions;
* **partition invariant** — the OR of per-region verdicts over *any*
  partition of the flat buffer equals the whole-buffer verdict.  This is
  what lets the executor screen each unit's region as its gradient
  write-back lands and only OR verdicts at the barrier;
* **hygiene** — every check returns its tracker charges (balance zero).
"""

import numpy as np
import ml_dtypes
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (MemoryTracker, baseline_overflow_check,
                        fused_overflow_check)
from repro.core.overflow import FUSED_CHUNK, check_region, flat_overflow_check

BF16 = np.dtype(ml_dtypes.bfloat16)
DTYPES = [np.dtype(np.float32), np.dtype(np.float16), BF16]
PAYLOADS = {"inf": np.inf, "-inf": -np.inf, "nan": np.nan}
# a small chunk so property-sized arrays straddle many chunk boundaries
# (the deterministic tests below cover the real FUSED_CHUNK)
CHUNK = 64


def _numpy_verdict(g: np.ndarray) -> bool:
    """Ground truth; the fp32 upcast is exact for fp16/bf16."""
    f = g.astype(np.float32)
    return bool(np.isinf(f).any() or np.isnan(f).any())


def _payload_array(n, dtype, kind, where, seed):
    rng = np.random.default_rng(seed)
    g = (rng.standard_normal(n) * 100).astype(dtype)
    if kind != "none":
        pos = {"first": 0, "last": n - 1,
               "random": int(rng.integers(0, n))}[where]
        g[pos] = PAYLOADS[kind]
    return g


@settings(max_examples=80, deadline=None)
@given(n=st.integers(min_value=1, max_value=4 * CHUNK + 3),
       dtype=st.sampled_from(DTYPES),
       kind=st.sampled_from(["none", "inf", "-inf", "nan"]),
       where=st.sampled_from(["first", "last", "random"]),
       seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_fused_agrees_with_numpy_and_baseline(n, dtype, kind, where, seed):
    g = _payload_array(n, dtype, kind, where, seed)
    expected = _numpy_verdict(g)
    assert expected == (kind != "none")
    t = MemoryTracker()
    assert fused_overflow_check(g, tracker=t, chunk=CHUNK) == expected
    assert baseline_overflow_check(g, tracker=t) == expected
    t.assert_quiescent()          # every temporary charge was returned


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=1, max_value=4 * CHUNK + 3),
       dtype=st.sampled_from(DTYPES),
       kind=st.sampled_from(["none", "inf", "-inf", "nan"]),
       where=st.sampled_from(["first", "last", "random"]),
       fracs=st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=0,
                      max_size=6),
       seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_partition_or_equals_whole_buffer(n, dtype, kind, where, fracs,
                                          seed):
    """The per-subgroup screen's correctness argument: for ANY partition
    of the flat buffer into regions (including empty ones), the OR of the
    per-region verdicts equals the whole-buffer verdict."""
    g = _payload_array(n, dtype, kind, where, seed)
    t = MemoryTracker()
    whole = flat_overflow_check(g, fused=True, tracker=t)
    cuts = sorted({0, n, *(int(f * n) for f in fracs)})
    or_of_regions = False
    for lo, hi in zip(cuts, cuts[1:], strict=False):
        or_of_regions = or_of_regions or check_region(
            g, lo, hi, fused=True, tracker=t)
    assert or_of_regions == whole == _numpy_verdict(g)
    t.assert_quiescent()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(min_value=1, max_value=2 * CHUNK),
       kind=st.sampled_from(["none", "inf", "nan"]),
       seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_partition_matches_for_baseline_check_too(n, kind, seed):
    """The invariant is a property of Inf/NaN detection, not of the fused
    formulation: the chained baseline ORs over partitions identically
    (fp32 — the gradient flat buffer's dtype)."""
    g = _payload_array(n, np.float32, kind, "random", seed)
    t = MemoryTracker()
    whole = flat_overflow_check(g, fused=False, tracker=t)
    mid = n // 2
    split = (check_region(g, 0, mid, fused=False, tracker=t)
             or check_region(g, mid, n, fused=False, tracker=t))
    assert split == whole
    t.assert_quiescent()


@pytest.mark.parametrize("n", [FUSED_CHUNK - 1, FUSED_CHUNK,
                               FUSED_CHUNK + 1])
@pytest.mark.parametrize("kind", ["inf", "-inf", "nan"])
@pytest.mark.parametrize("where", ["first", "last"])
def test_real_chunk_boundary_payloads(n, kind, where):
    """Deterministic straddle of the real FUSED_CHUNK: a payload at the
    first or last element of an array one-off either side of the chunk
    size must be found (the boundary slicing loses no element)."""
    g = np.zeros(n, np.float32)
    g[0 if where == "first" else n - 1] = PAYLOADS[kind]
    assert fused_overflow_check(g)
    g[0 if where == "first" else n - 1] = 1.0
    assert not fused_overflow_check(g)


def test_region_screen_sees_only_its_region():
    """A payload OUTSIDE the screened region must not trip it — region
    boundaries are exact (the per-unit screen depends on it)."""
    g = np.zeros(4 * CHUNK, np.float32)
    g[0] = np.inf
    g[-1] = np.nan
    assert not check_region(g, 1, g.size - 1, fused=True)
    assert check_region(g, 0, 1, fused=True)
    assert check_region(g, g.size - 1, g.size, fused=True)
    assert check_region(g, 0, 0, fused=True) is False   # empty region
