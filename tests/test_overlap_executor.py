"""Full-overlap executor: async H2D staging, async gradient write-back,
in-plan optimizer with cross-step pipelining — equivalence, error paths,
and resource hygiene across the overlap ablation levels."""

import threading

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, OffloadSession
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _batches(n, batch=4, seq=32, seed=1):
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=seed), batch=batch,
                    seq_len=seq)
    return [dl.next_batch() for _ in range(n)]


def _policy(root, overlap, **adam):
    adam.setdefault("lr", 3e-3)
    return (OffloadPolicy.preset("memascend").with_store(root)
            .with_adam(**adam).with_overlap(overlap).build())


# -- equivalence -------------------------------------------------------------

def test_overlap_modes_loss_bit_identical(tmp_store_root):
    """The same float ops run in the same order in every mode — only the
    thread paying the wait changes.  Losses AND post-run master weights
    must match bit for bit, including across a loss-scale growth step
    (fp16 exercises real unscaling)."""
    bs = _batches(4)
    losses, masters = {}, {}
    for mode in ("sync", "h2d", "full"):
        pol = _policy(tmp_store_root + mode, mode, compute_dtype="float16")
        with OffloadSession(_model(), pol) as s:
            s.scaler.scale = 1024.0
            s.scaler.growth_interval = 2   # growth mid-run: 2x scale jump
            losses[mode] = [s.train_step(b["tokens"], b["labels"])["loss"]
                            for b in bs]
            masters[mode] = s.master_param("embed", "embed")  # synchronizes
        s.tracker.assert_quiescent()
    assert losses["sync"] == losses["h2d"] == losses["full"]
    for mode in ("h2d", "full"):
        np.testing.assert_array_equal(
            masters["sync"].view(np.uint8), masters[mode].view(np.uint8))


def test_full_overlap_runs_pipeline_legs_off_thread(tmp_store_root):
    """The point of the PR: under "full", Adam subgroups and gradient
    scatters execute on their workers, H2D staging serves every FetchOp,
    and no read ever degrades to a synchronous fallback."""
    b = _batches(1)[0]
    with OffloadSession(_model(), _policy(tmp_store_root, "full")) as s:
        optim_threads, writer_threads = set(), set()
        issue_threads, commit_threads = set(), set()
        real_compute = s.optimizer.compute_subgroup
        real_issue = s.optimizer.issue_subgroup
        real_commit = s.optimizer.commit_subgroup_async
        real_write = s._write_grads

        def compute(staged, grad):
            optim_threads.add(threading.current_thread().name)
            return real_compute(staged, grad)

        def issue(key):
            issue_threads.add(threading.current_thread().name)
            return real_issue(key)

        def commit(staged, **kw):
            commit_threads.add(threading.current_thread().name)
            return real_commit(staged, **kw)

        def write(unit, grads, gate=None):
            writer_threads.add(threading.current_thread().name)
            return real_write(unit, grads, gate)

        s.optimizer.compute_subgroup = compute
        s.optimizer.issue_subgroup = issue
        s.optimizer.commit_subgroup_async = commit
        s._write_grads = write
        m = s.train_step(b["tokens"], b["labels"])
        s.synchronize()
        plan = s.plan("train")
        n_fetches = len(plan.fetch_order)
        assert s._ostats.h2d_gets == n_fetches   # every FetchOp was staged
        assert s.swapper.stats.sync_fallbacks == 0
        assert optim_threads == {"offload-optim"}
        # state reads stream on the prefetch worker; write-back batches are
        # submitted by the optimizer worker and drain on the store's pool
        assert issue_threads == {"offload-optim-prefetch"}
        assert commit_threads == {"offload-optim"}
        assert writer_threads == {"offload-gradwrite"}
        assert s.optimizer.staging_idle()
        assert m["applied"]
        # the completed-step I/O ledger lands with synchronize()
        assert s._optim_io_completed > 0
    s.tracker.assert_quiescent()


def test_sync_mode_has_no_pipeline_threads(tmp_store_root):
    b = _batches(1)[0]
    with OffloadSession(_model(), _policy(tmp_store_root, "sync")) as s:
        assert s._h2d is None and s._grad_writer is None \
            and s._optim_worker is None
        m = s.train_step(b["tokens"], b["labels"])
        assert m["optimizer_io_bytes"] > 0   # inline Adam: exact immediately
        assert m["h2d_wait_s"] == 0.0


def test_metrics_report_overlap_counters(tmp_store_root):
    b = _batches(1)[0]
    with OffloadSession(_model(), _policy(tmp_store_root, "full")) as s:
        m = s.train_step(b["tokens"], b["labels"])
    for key in ("fetch_wait_s", "ssd_wait_s", "h2d_wait_s",
                "gradwrite_drain_s", "optim_gate_s"):
        assert m[key] >= 0.0
    assert m["prefetch_hits"] > 0


def test_eval_after_step_sees_updated_weights_under_full_overlap(
        tmp_store_root):
    """The per-unit readiness gate: an eval issued while step k's Adam may
    still be streaming must fetch post-update weights (identical to a
    fully-synchronized session)."""
    bs = _batches(2)
    with OffloadSession(_model(), _policy(tmp_store_root + "f", "full")) as s:
        s.train_step(bs[0]["tokens"], bs[0]["labels"])
        e_full = s.eval_loss(bs[1]["tokens"], bs[1]["labels"])  # no sync
    with OffloadSession(_model(), _policy(tmp_store_root + "s", "sync")) as s:
        s.train_step(bs[0]["tokens"], bs[0]["labels"])
        e_sync = s.eval_loss(bs[1]["tokens"], bs[1]["labels"])
    assert e_full == e_sync


# -- error paths: nothing may leak ------------------------------------------

def test_failed_h2d_releases_every_slot(tmp_store_root):
    """A device_put failure on the staging worker must propagate out of
    the FetchOp wait and leave no pool slot, device slot, or in-flight
    read behind."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))
    calls = {"n": 0}
    real_copy = s._h2d_copy

    def flaky_copy(view):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected H2D failure")
        return real_copy(view)

    s._h2d_copy = flaky_copy
    with pytest.raises(RuntimeError, match="injected H2D"):
        s.train_step(b["tokens"], b["labels"])
    assert s.pool.in_use_payload == 0
    assert len(s.swapper._inflight) == 0
    assert s._device_slots.idle()
    s.close()
    s.tracker.assert_quiescent()


def test_writer_thread_exception_surfaces_and_releases(tmp_store_root):
    """A failed D2H scatter on the writer thread surfaces at the overflow
    barrier (the first point the step depends on it) and the abort path
    returns every resource."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))

    def failing_write(unit, grads, gate=None):
        raise RuntimeError("injected writer failure")

    s._write_grads = failing_write
    with pytest.raises(RuntimeError, match="injected writer"):
        s.train_step(b["tokens"], b["labels"])
    assert s.pool.in_use_payload == 0
    assert len(s.swapper._inflight) == 0
    assert s._device_slots.idle()
    assert s.tracker.component("activation_checkpoints").live_allocated == 0
    s.close()
    s.tracker.assert_quiescent()


def test_optimizer_worker_failure_surfaces_at_synchronize(tmp_store_root):
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))

    def failing_compute(staged, grad):
        raise IOError("injected optimizer-store failure")

    s.optimizer.compute_subgroup = failing_compute
    s.train_step(b["tokens"], b["labels"])   # enqueues the doomed stage
    with pytest.raises(IOError, match="injected optimizer"):
        s.synchronize()
    s.close()    # still closes cleanly after the pipeline failure
    s.tracker.assert_quiescent()


def test_optimizer_worker_failure_blocks_next_step_fetch(tmp_store_root):
    """Without an explicit synchronize(), the failure must still surface —
    at the next step's readiness gate, before stale weights are read."""
    bs = _batches(2)
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))
    real_compute = s.optimizer.compute_subgroup
    fail = {"on": True}

    def flaky_compute(staged, grad):
        if fail["on"]:
            raise IOError("injected optimizer-store failure")
        return real_compute(staged, grad)

    s.optimizer.compute_subgroup = flaky_compute
    s.train_step(bs[0]["tokens"], bs[0]["labels"])
    with pytest.raises(IOError, match="injected optimizer"):
        s.train_step(bs[1]["tokens"], bs[1]["labels"])
    assert s.pool.in_use_payload == 0
    s.close()
    s.tracker.assert_quiescent()


def test_failed_optim_for_late_unit_never_serves_stale_weights(
        tmp_store_root):
    """A failed Adam stage for a unit reached only at an ahead-of-need
    window position must STALL that position (done-with-exception is not
    ready) and surface at the unit's own fetch — not silently serve
    pre-update weights to the next plan (regression: the gate treated any
    done() future as ready)."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))
    real_compute = s.optimizer.compute_subgroup

    def flaky_compute(staged, grad):
        if staged.key.startswith("head/"):
            raise IOError("injected head-Adam failure")
        return real_compute(staged, grad)

    s.optimizer.compute_subgroup = flaky_compute
    s.train_step(b["tokens"], b["labels"])
    with pytest.raises(IOError, match="injected head-Adam"):
        s.eval_loss(b["tokens"], b["labels"])   # head fetch must deliver it
    assert s.pool.in_use_payload == 0
    s.close()
    s.tracker.assert_quiescent()


def test_failed_claim_mid_unit_releases_earlier_claims(tmp_store_root):
    """A claim that raises partway through a unit's parameters (pool
    timeout, store shutdown) must release the tickets already claimed —
    they left the swapper's in-flight map, so nothing else can."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))
    calls = {"n": 0}
    real_claim = s.swapper.claim

    def flaky_claim(key, dtype, shape, **kw):
        calls["n"] += 1
        if calls["n"] == 4:      # partway through block_000's params
            raise RuntimeError("injected claim failure")
        return real_claim(key, dtype, shape, **kw)

    s.swapper.claim = flaky_claim
    with pytest.raises(RuntimeError, match="injected claim"):
        s.train_step(b["tokens"], b["labels"])
    assert s.pool.in_use_payload == 0
    assert len(s.swapper._inflight) == 0
    assert s._device_slots.idle()
    s.close()
    s.tracker.assert_quiescent()


def test_error_path_drains_staged_fetches(tmp_store_root):
    """A compute failure with H2D jobs still queued/staged must wait them
    out and return their device slots (regression probe for the abort
    path's FIFO settle)."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, "full"))
    calls = {"n": 0}
    real_block = s._jit_block

    def flaky_block(params, h):
        calls["n"] += 1
        if calls["n"] == 1:      # fail on the first block: embed staged,
            raise RuntimeError("injected block failure")  # blocks in flight
        return real_block(params, h)

    s._jit_block = flaky_block
    with pytest.raises(RuntimeError, match="injected block"):
        s.train_step(b["tokens"], b["labels"])
    assert s.pool.in_use_payload == 0
    assert len(s.swapper._inflight) == 0
    assert s._device_slots.idle()
    s.close()
    s.tracker.assert_quiescent()


# -- thread hygiene ----------------------------------------------------------
# The census assertions live in conftest.py's autouse worker_thread_leak_guard
# fixture now: these tests only need to *exercise* the open/close cycles —
# any leftover "offload-*" / "direct-nvme" / "*-aio" thread fails the guard.

def test_session_cycles_leak_no_threads(tmp_store_root):
    """Open/train/close cycles must return the thread census to baseline:
    the session workers AND the store's I/O pools (the TensorStore
    -aio executor used to outlive close(), 4 threads per cycle)."""
    b = _batches(1)[0]
    for i in range(3):
        with OffloadSession(
                _model(), _policy(f"{tmp_store_root}{i}", "full")) as s:
            s.train_step(b["tokens"], b["labels"])


def test_filesystem_store_session_leaks_no_aio_threads(tmp_store_root):
    """FilesystemEngine-backed sessions exercise the base-class close():
    every read_async spins the lazy -aio pool up; close must take it down."""
    from repro.core import zero_infinity_policy
    b = _batches(1)[0]
    for i in range(2):
        pol = zero_infinity_policy(f"{tmp_store_root}{i}", lr=1e-3)
        with OffloadSession(_model(), pol) as s:
            s.train_step(b["tokens"], b["labels"])
