"""Speculative decoding over the paged serve path: per-slot KV rollback
(truncation across page/bucket boundaries, spilled-page no-resurrection,
in-flight transfer safety), verify-window bitwise identity with the
sequential step chain, and end-to-end token identity of ``generate(spec=)``
and the spec-decoding ServingEngine with plain greedy."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (DecodeSpec, MemoryTracker, PlanError,
                        SpillableKVCache, memascend_policy)
from repro.core.buffer_pool import (AdaptiveBufferPool, PoolCensus,
                                    ShapeClass)
from repro.core.model_adapter import make_offloadable_lm
from repro.core.nvme import FilesystemEngine
from repro.core.pinned_alloc import AlignmentFreeAllocator
from repro.core.session import verify_bucket
from repro.core.stream_plan import (ComputeOp, KVReadOp, KVWriteOp,
                                    compile_decode_verify)
from repro.serve import (NGramDraft, OffloadedDecoder, Request,
                         ServingEngine, SpecConfig)

CFG = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def model():
    return _model()


def _slotted_kv(tmp_store_root, units=("a",), slots=2, resident=4,
                max_seq=8, store=None):
    """Paged cache with batch slots over a real pool + store: per-slot
    single-row pages of 2 tokens, so rollback boundaries land mid-page,
    on-page, and across pages within a handful of tokens."""
    page_shape = (2, 1, 2, 1, 2)
    nbytes = int(np.prod(page_shape)) * 4
    census = PoolCensus((ShapeClass("w", 64, per_block=1),),
                        inflight_blocks=1).with_kv(nbytes, resident)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pinned", backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    store = store or FilesystemEngine(tmp_store_root)
    kv = SpillableKVCache(list(units), page_shape, max_seq, np.float32,
                          pool, store, resident_limit=resident, slots=slots)
    return kv, pool, store


def _window(batch, k, base=1.0):
    """(batch, k, 1, 2) K/V windows with per-(slot, position) unique
    values so truncation and resurrection are detectable bitwise."""
    arr = np.zeros((batch, k, 1, 2), np.float32)
    for b in range(batch):
        for t in range(k):
            arr[b, t] = base + 10 * b + t
    return arr


# -- rollback: truncation mechanics -------------------------------------------

def test_rollback_truncates_across_page_boundary(tmp_store_root):
    """Rolling back from 3 tokens to 1 drops page 1 entirely (its slot
    returns to the pool, the page rereads as zeros) while page 0 keeps
    the surviving prefix bitwise; a later append overwrites the stale
    tail byte of the kept partial page."""
    kv, pool, _store = _slotted_kv(tmp_store_root)
    k3, v3 = _window(2, 3), _window(2, 3, base=100.0)
    kv.append_window("a", k3, v3)
    for s in (0, 1):
        kv.rollback(s, 3)                      # commit all 3 (pure advance)
    assert kv.stats.rollback_pages == 0        # advance drops nothing
    kv.rollback(0, 1)                          # truncate: page 1 dropped
    assert kv.slot_length(0) == 1 and kv.slot_length(1) == 3
    assert kv.stats.rollback_pages == 1
    kg, vg = kv.gather_window("a", 4)
    np.testing.assert_array_equal(kg[0, 0], k3[0, 0])      # kept prefix
    assert (kg[0, 2:] == 0).all()                          # dropped page
    np.testing.assert_array_equal(kg[1, :3], k3[1])        # other slot
    np.testing.assert_array_equal(vg[1, :3], v3[1])
    # the kept partial page's stale tail byte is overwritten by the next
    # append, exactly as a sequential decode would have written it
    one_k, one_v = _window(2, 1, base=50.0), _window(2, 1, base=60.0)
    kv.append_window("a", one_k, one_v)
    kg2, _ = kv.gather_window("a", 4)
    np.testing.assert_array_equal(kg2[0, 1], one_k[0, 0])
    kv.close()
    assert pool.in_use_payload == 0


def test_rollback_across_bucket_boundary(tmp_store_root):
    """A rollback crossing a time-bucket boundary (4 -> 1 with 2-token
    pages) drops every page past the new tail and the cache keeps
    serving appends from the truncated length."""
    kv, pool, _store = _slotted_kv(tmp_store_root, resident=6)
    k4, v4 = _window(2, 4), _window(2, 4, base=100.0)
    kv.append_window("a", k4, v4)
    kv.rollback(0, 4)
    kv.rollback(1, 1)                          # 2 pages -> partial page 0
    assert kv.stats.rollback_pages == 1
    assert kv.slot_length(1) == 1
    k2, v2 = _window(2, 2, base=200.0), _window(2, 2, base=300.0)
    kv.append_window("a", k2, v2)              # slot1 writes at 1..2
    kv.rollback(0, 5)
    kv.rollback(1, 3)
    kg, _ = kv.gather_window("a", 6)
    np.testing.assert_array_equal(kg[1, 0], k4[1, 0])
    np.testing.assert_array_equal(kg[1, 1:3], k2[1])
    np.testing.assert_array_equal(kg[0, :4], k4[0])
    np.testing.assert_array_equal(kg[0, 4], k2[0, 0])
    kv.close()
    assert pool.in_use_payload == 0


def test_rollback_dirty_spilled_page_not_resurrected(tmp_store_root):
    """A dirty page that reached the SSD before its tokens were rejected
    must NOT come back: rollback forgets the spilled key, so the page
    rereads as zeros even though the store may still hold the bytes."""
    kv, pool, store = _slotted_kv(tmp_store_root, resident=2)
    k4, v4 = _window(2, 4), _window(2, 4, base=100.0)
    kv.append_window("a", k4, v4)              # 4 pages through 2 slots
    assert kv.stats.spills >= 1
    spilled_keys = [f"kv/a/s{s:02d}/p{p:04d}" for s in (0, 1)
                    for p in (0, 1) if store.contains(
                        f"kv/a/s{s:02d}/p{p:04d}")]
    assert spilled_keys                         # something hit the SSD
    kv.rollback(0, 1)                           # reject slot 0's page 1
    kv.rollback(1, 4)
    kg, vg = kv.gather_window("a", 4)
    assert (kg[0, 2:] == 0).all() and (vg[0, 2:] == 0).all()
    np.testing.assert_array_equal(kg[1], k4[1])  # slot 1 survives, bitwise
    kv.close()
    assert pool.in_use_payload == 0


def test_rollback_waits_for_pinned_page(tmp_store_root):
    """Rollback while a dropped-range page is pinned (staging worker
    mid-copy) blocks until the pin clears instead of yanking the buffer
    or raising — the 'un-pin in-flight gathers safely' contract."""
    kv, pool, _store = _slotted_kv(tmp_store_root)
    k2, v2 = _window(2, 2), _window(2, 2, base=100.0)
    kv.append_window("a", k2, v2)
    kv.ensure_page("a", 0, slot=0, pin=True)    # reader holds the page
    done = threading.Event()

    def _roll():
        kv.rollback(0, 0)                       # drops page 0 -> must wait
        done.set()

    t = threading.Thread(target=_roll)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()                    # blocked on the pin
    kv.unpin("a", 0, slot=0)
    t.join(timeout=10.0)
    assert done.is_set()
    assert kv.slot_length(0) == 0
    kg, _ = kv.gather_window("a", 2)
    assert (kg[0] == 0).all()
    kv.close()
    assert pool.in_use_payload == 0


def test_rollback_with_inflight_refill_future(tmp_store_root):
    """Rollback of a page whose async SSD refill is still in flight on
    the transfer worker: the future is settled and its buffer released —
    the refilled bytes never land back in the cache."""
    class GatedStore(FilesystemEngine):
        def __init__(self, root):
            super().__init__(root)
            self.gate = threading.Event()

        def read_async(self, key, view):
            inner = super().read_async
            from concurrent.futures import ThreadPoolExecutor
            pool = ThreadPoolExecutor(1)

            def _wait_then_read():
                assert self.gate.wait(timeout=10.0)
                return inner(key, view).result()
            fut = pool.submit(_wait_then_read)
            pool.shutdown(wait=False)
            return fut

    store = GatedStore(tmp_store_root)
    kv, pool, _ = _slotted_kv(tmp_store_root, resident=5, store=store)
    k4, v4 = _window(2, 4), _window(2, 4, base=100.0)
    kv.append_window("a", k4, v4)
    kv.rollback(0, 4)
    kv.rollback(1, 4)
    target = ("a", 0, 1)
    with kv._lock:                     # force-spill exactly the target page
        kv._use_order.remove(target)
        kv._use_order.append(target)
        assert kv._try_spill_one(set())
        assert target in kv._spilled
    kv.prefetch_window("a", 4)         # async refill: gated in flight
    with kv._lock:
        assert target in kv._futures
    done = threading.Event()

    def _roll():
        kv.rollback(0, 1)
        done.set()

    t = threading.Thread(target=_roll)
    t.start()
    time.sleep(0.05)
    store.gate.set()                           # let the refill finish
    t.join(timeout=10.0)
    assert done.is_set()
    kg, _ = kv.gather_window("a", 4)
    assert (kg[0, 2:] == 0).all()              # refill did not resurrect
    kv.close()
    assert pool.in_use_payload == 0


def test_rollback_validation(tmp_store_root):
    kv, _pool, _store = _slotted_kv(tmp_store_root)
    kv.retire(1)
    with pytest.raises(RuntimeError, match="retired"):
        kv.rollback(1, 0)
    with pytest.raises(ValueError, match="length"):
        kv.rollback(0, 99)                     # beyond capacity
    with pytest.raises(ValueError, match="slot"):
        kv.rollback(7, 0)
    kv.close()
    with pytest.raises(RuntimeError, match="closed"):
        kv.rollback(0, 0)


# -- verify plan + bucketing ---------------------------------------------------

def test_verify_bucket_powers_of_two():
    assert [verify_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    with pytest.raises(ValueError):
        verify_bucket(0)


def test_decode_verify_plan_structure(model):
    plan = compile_decode_verify(model)
    blocks = [f"block_{i:03d}" for i in range(CFG.n_layers)]
    assert plan.fetch_order == tuple(["embed"] + blocks + ["head"])
    for b in blocks:
        kinds = [op for op in plan.ops
                 if getattr(op, "unit", None) == b
                 and isinstance(op, (KVReadOp, ComputeOp, KVWriteOp))]
        assert isinstance(kinds[0], KVReadOp)
        assert isinstance(kinds[1], ComputeOp)
        assert kinds[1].kind == "block_verify"
        assert isinstance(kinds[2], KVWriteOp)
        assert kinds[2].mode == "verify"


def test_decode_verify_plan_requires_block_verify(model):
    import dataclasses
    headless = dataclasses.replace(model, block_verify=None)
    with pytest.raises(PlanError, match="block_verify"):
        compile_decode_verify(headless)


# -- verify step: bitwise identity with the sequential chain -------------------

def test_verify_logits_match_sequential_steps(tmp_store_root):
    """Every window position's verify logits are bitwise the sequential
    decode_step chain's, and neither lengths nor output drift after a
    partial-commit rollback."""
    from repro.core import OffloadSession
    spec = DecodeSpec(batch=2, max_seq=64, bucket=16)
    rng = np.random.default_rng(0)
    prompt = rng.integers(3, CFG.vocab, (2, 7)).astype(np.int32)
    window = rng.integers(3, CFG.vocab, (2, 5)).astype(np.int32)

    with OffloadSession(_model(), memascend_policy(tmp_store_root + "a",
                                                   lr=1e-3),
                        mode="serve", decode=spec) as sess:
        kv = sess.open_kv_cache()
        sess.prefill(kv, prompt)
        seq = [sess.decode_step(kv, window[:, j:j + 1]) for j in range(5)]
        kv.close()

    with OffloadSession(_model(), memascend_policy(tmp_store_root + "b",
                                                   lr=1e-3),
                        mode="serve", decode=spec) as sess:
        kv = sess.open_kv_cache()
        sess.prefill(kv, prompt)
        base = kv.length
        vlg = sess.verify_step(kv, window)     # padded to 8 internally
        assert vlg.shape == (2, 5, CFG.vocab)
        for j in range(5):
            np.testing.assert_array_equal(vlg[:, j], seq[j])
        assert kv.length == base               # no advance
        for s in sorted(kv.active):
            kv.rollback(s, base + 3)           # commit 3, reject the tail
        after = sess.decode_step(kv, window[:, 3:4])
        np.testing.assert_array_equal(after, seq[3])
        kv.close()


def test_verify_step_slots_ragged_lengths(tmp_store_root):
    """Per-slot verify at ragged lengths matches each lane's sequential
    chain and leaves every slot's length untouched."""
    from repro.core import OffloadSession
    spec = DecodeSpec(batch=2, max_seq=64, bucket=16)
    rng = np.random.default_rng(1)
    prompt = rng.integers(3, CFG.vocab, (2, 6)).astype(np.int32)
    step1 = rng.integers(3, CFG.vocab, (2, 1)).astype(np.int32)
    w = rng.integers(3, CFG.vocab, (2, 3)).astype(np.int32)

    def drive(sess, kv):
        sess.prefill(kv, prompt)
        sess.decode_step_slots(kv, step1)
        kv.rollback(0, kv.slot_length(0) - 1)   # make lengths ragged

    with OffloadSession(_model(), memascend_policy(tmp_store_root + "a",
                                                   lr=1e-3),
                        mode="serve", decode=spec) as sess:
        kv = sess.open_kv_cache()
        drive(sess, kv)
        ref = [sess.decode_step_slots(kv, w[:, j:j + 1]) for j in range(3)]
        kv.close()

    with OffloadSession(_model(), memascend_policy(tmp_store_root + "b",
                                                   lr=1e-3),
                        mode="serve", decode=spec) as sess:
        kv = sess.open_kv_cache()
        drive(sess, kv)
        lens = {s: kv.slot_length(s) for s in sorted(kv.active)}
        vlg = sess.verify_step_slots(kv, w)
        for j in range(3):
            np.testing.assert_array_equal(vlg[:, j], ref[j])
        assert {s: kv.slot_length(s) for s in sorted(kv.active)} == lens
        kv.close()


# -- draft sources -------------------------------------------------------------

def test_ngram_draft_most_recent_match_wins():
    d = NGramDraft(gram=2)
    ctx = np.array([5, 6, 7, 8, 5, 6, 9, 1, 5, 6], np.int32)
    np.testing.assert_array_equal(d.propose(ctx, 2), [9, 1])
    np.testing.assert_array_equal(d.propose(ctx, 4), [9, 1, 5, 6])


def test_ngram_draft_no_match_and_bounds():
    d = NGramDraft(gram=3)
    assert d.propose(np.array([1, 2, 3], np.int32), 4).size == 0
    assert d.propose(np.array([1, 2, 3, 1, 2, 3], np.int32), 0).size == 0
    np.testing.assert_array_equal(
        d.propose(np.array([1, 2, 3, 9, 1, 2, 3], np.int32), 2), [9, 1])
    with pytest.raises(ValueError):
        NGramDraft(gram=0)
    with pytest.raises(ValueError):
        SpecConfig(k=0)


# -- end to end: token identity ------------------------------------------------

def test_generate_spec_matches_plain_greedy(tmp_store_root):
    """The acceptance gate for the joint path: generate(spec=) emits
    bit-identical tokens to the plain cached greedy loop, while actually
    committing more than one token per streamed pass."""
    rng = np.random.default_rng(1)
    pat = rng.integers(3, 40, 6)
    prompt = np.tile(pat, 4)[None, :].repeat(2, axis=0).astype(np.int32)
    spec = DecodeSpec(batch=2, max_seq=96, bucket=16)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "p",
                                                     lr=1e-3),
                          decode=spec) as dec:
        plain = dec.generate(prompt, 48)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "s",
                                                     lr=1e-3),
                          decode=spec) as dec:
        fast = dec.generate(prompt, 48, spec=SpecConfig(k=4))
        st = dec.spec_stats
    np.testing.assert_array_equal(plain, fast)
    assert st.rounds < 47            # fewer passes than plain's steps
    assert st.accepted_per_step > 1.0
    assert st.committed_tokens == 47 * 2   # everything after the prefill


def test_generate_spec_rejects_uncached(tmp_store_root):
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=DecodeSpec(batch=1, max_seq=32,
                                            bucket=8)) as dec, \
            pytest.raises(ValueError, match="cached"):
        dec.generate(np.ones((1, 4), np.int32), 4, use_cache=False,
                     spec=SpecConfig())


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, d):
        self.t += d


def test_serving_engine_spec_matches_plain(tmp_store_root):
    """Mixed accept/reject across slots: the spec-decoding engine serves
    ragged arrivals with per-slot rollback and emits, per request, the
    same tokens as the plain engine (itself pinned to solo greedy)."""
    rng = np.random.default_rng(2)
    pat = rng.integers(3, 40, 5)

    def reqs():
        return [Request(rid=f"r{i}",
                        prompt=np.tile(pat, 2 + i).astype(np.int32),
                        max_new_tokens=8 + 3 * i,
                        arrival=0.05 * i) for i in range(4)]

    spec = DecodeSpec(batch=2, max_seq=96, bucket=16)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "p",
                                                     lr=1e-3),
                          decode=spec) as dec:
        clk = _FakeClock()
        plain = ServingEngine(dec, clock=clk, sleep=clk.sleep).run(reqs())
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "s",
                                                     lr=1e-3),
                          decode=spec) as dec:
        clk = _FakeClock()
        fast = ServingEngine(dec, spec=SpecConfig(k=4), clock=clk,
                             sleep=clk.sleep).run(reqs())
        assert dec.spec_stats is not None
    assert len(fast.completed) == len(plain.completed) == 4
    for rp, rs in zip(plain.completed, fast.completed, strict=True):
        assert rp.rid == rs.rid
        assert rp.output == rs.output
    assert fast.spec_rounds > 0
    # every token after each request's prefill-emitted first one came
    # through a spec round
    total = sum(r.metrics.tokens_out for r in fast.completed)
    assert fast.spec_committed == total - len(fast.completed)
    assert fast.accepted_per_step > 0.0
    assert fast.kv_stats["rollbacks"] > 0
