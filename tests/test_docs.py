"""Docs stay true: markdown links resolve and examples import (the same
checks CI's ``docs`` job runs via tools/check_docs.py, so drift like a
renamed DecodeSpec field or a moved doc fails tier-1 locally too)."""

import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "tools"))

from check_docs import check_example_imports, check_markdown_links  # noqa: E402


def test_markdown_links_resolve():
    assert check_markdown_links(_REPO_ROOT) == []


def test_examples_import():
    assert check_example_imports(_REPO_ROOT) == []
