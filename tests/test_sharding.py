"""Sharding rules: divisibility gating, axis uniqueness, per-arch validity."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.mesh import make_host_mesh
from repro.launch import sharding as shd
from repro.models import build

# a fake 16x16 mesh object good enough for spec computation (no devices)
class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_spec(spec, shape, mesh):
    used = []
    for dim, part in zip(shape, tuple(spec) + (None,) * len(shape),
                         strict=False):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        total = 1
        for n in names:
            assert n in mesh.axis_names
            assert n not in used, f"axis {n} reused in {spec}"
            used.append(n)
            total *= mesh.shape[n]
        assert dim % total == 0, f"{dim} not divisible by {total} in {spec}"


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["pod", "multipod"])
def test_param_specs_valid(arch, mesh):
    cfg = ARCHS[arch]
    impl = build(cfg)
    params_shape = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, params_shape, mesh)
    leaves_shape = jax.tree.leaves(params_shape)
    leaves_spec = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_shape) == len(leaves_spec)
    for sds, spec in zip(leaves_shape, leaves_spec, strict=True):
        _check_spec(spec, sds.shape, mesh)


@pytest.mark.parametrize("arch", ["gemma-7b", "deepseek-v3-671b",
                                  "jamba-v0.1-52b", "xlstm-1.3b"])
def test_big_weights_actually_sharded(arch):
    """The embedding and expert/FFN weights must not be replicated."""
    cfg = ARCHS[arch]
    impl = build(cfg)
    params_shape = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, params_shape, MESH)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_key = {"/".join(str(getattr(e, "key", e)) for e in path): spec
              for path, spec in flat}
    embed_spec = next(v for k, v in by_key.items() if k.endswith("embed"))
    assert any(p is not None for p in embed_spec), "embedding replicated!"


@pytest.mark.parametrize("arch", list(ARCHS))
def test_cache_specs_valid(arch):
    cfg = ARCHS[arch]
    impl = build(cfg)
    shape = INPUT_SHAPES["decode_32k"]
    cache_sds = jax.eval_shape(
        lambda: impl.init_cache(shape.global_batch, shape.seq_len))
    specs = shd.cache_specs(cfg, cache_sds, MESH)
    for sds, spec in zip(jax.tree.leaves(cache_sds),
                         jax.tree.leaves(specs,
                                         is_leaf=lambda x: isinstance(x, P)),
                         strict=True):
        _check_spec(spec, sds.shape, MESH)


@settings(max_examples=50)
@given(shape=st.lists(st.integers(min_value=1, max_value=4096), min_size=1,
                      max_size=4),
       seed=st.integers(min_value=0, max_value=1000))
def test_greedy_spec_properties(shape, seed):
    import random
    r = random.Random(seed)
    axes = ["data", "model", ("data", "model")]
    prefs = [[r.choice(axes)] if r.random() < 0.7 else []
             for _ in shape]
    spec = shd.greedy_spec(MESH, shape, prefs)
    _check_spec(spec, shape, MESH)


def test_train_step_runs_on_host_mesh():
    """Reduced config through the real pjit path on a 1x1 mesh, and grads
    match direct jax.grad."""
    import numpy as np
    from repro.train.step import build_train_step
    cfg = ARCHS["qwen3-4b"].reduced()
    impl = build(cfg)
    mesh = make_host_mesh()
    b, s = 2, 32
    batch_sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    with mesh:
        fn, in_sh, out_sh = build_train_step(impl, mesh,
                                             batch_shape=batch_sds)
        step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        params = impl.init_params(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.full((b, s), 3, jnp.int32),
                 "labels": jnp.ones((b, s), jnp.int32)}
        loss, grads, overflow = step(params, batch, jnp.float32(4.0))
        assert np.isfinite(float(loss)) and not bool(overflow)
        # grads are scaled by loss_scale: compare against direct grad
        direct = jax.grad(lambda p: impl.loss_fn(p, batch))(params)
        g1 = jax.tree.leaves(grads)[0]
        g2 = jax.tree.leaves(direct)[0]
        np.testing.assert_allclose(np.asarray(g1, np.float32) / 4.0,
                                   np.asarray(g2, np.float32),
                                   rtol=2e-2, atol=2e-5)


def test_serve_step_runs_on_host_mesh():
    import numpy as np
    from repro.serve.decode import build_serve_step
    from repro.configs.base import InputShape
    cfg = ARCHS["qwen3-4b"].reduced()
    impl = build(cfg)
    mesh = make_host_mesh()
    shape = InputShape("tiny_decode", 64, 2, "decode")
    with mesh:
        fn, in_sh, out_sh, (cache_sds, tok_sds, len_sds) = build_serve_step(
            impl, mesh, shape)
        step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        params = impl.init_params(jax.random.PRNGKey(0))
        cache = impl.init_cache(2, 64)
        logits, cache2 = step(params, cache,
                              jnp.full((2, 1), 3, jnp.int32), jnp.int32(63))
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
