"""Continuous-batching serving: per-slot request lifecycle over the paged
KV cache (join / prefill-scatter / per-slot decode / retire-and-reclaim),
the FIFO scheduler's admission policy, greedy-output equivalence with
decoding every request alone, and decoder teardown hardening."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (DecodeSpec, MemoryTracker, SpillableKVCache,
                        memascend_policy)
from repro.core.buffer_pool import (AdaptiveBufferPool, PoolCensus,
                                    ShapeClass)
from repro.core.model_adapter import make_offloadable_lm
from repro.core.nvme import FilesystemEngine
from repro.core.pinned_alloc import AlignmentFreeAllocator
from repro.serve import (OffloadedDecoder, Request, RequestState,
                         ServingEngine)

CFG = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(3, CFG.vocab, size=n, dtype=np.int32)


class FakeClock:
    """Deterministic engine clock: advances only via sleep() plus an
    optional fixed tick per observation (so arrivals can land while the
    engine is mid-decode without any wall time passing)."""

    def __init__(self, tick=0.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        now = self.t
        self.t += self.tick
        return now

    def sleep(self, d):
        self.t += d


def _engine(decoder, tick=0.0):
    clk = FakeClock(tick)
    return ServingEngine(decoder, clock=clk, sleep=clk.sleep)


# -- per-slot cache lifecycle (no model) --------------------------------------

def _slotted_kv(tmp_store_root, units=("a",), slots=2, resident=3,
                max_seq=4):
    """Paged cache with batch slots over a real pool + store: per-slot
    pages of 2 tokens x 1 row, 4-token capacity (2 pages per slot)."""
    page_shape = (2, 1, 2, 1, 2)
    nbytes = int(np.prod(page_shape)) * 4
    census = PoolCensus((ShapeClass("w", 64, per_block=1),),
                        inflight_blocks=1).with_kv(nbytes, resident)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pinned", backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    store = FilesystemEngine(tmp_store_root)
    kv = SpillableKVCache(list(units), page_shape, max_seq, np.float32,
                          pool, store, resident_limit=resident, slots=slots)
    return kv, pool, store


def test_join_retire_refcount_balance(tmp_store_root):
    """N join/write/retire cycles leak nothing: every retired slot's pages
    come back to the pool as reclaims (no spill writes), the free list
    refills, and the pool's payload refcount lands back at zero."""
    kv, pool, _store = _slotted_kv(tmp_store_root, slots=3, resident=7)
    for s in sorted(kv.active):
        kv.retire(s)
    assert kv.free_slots == 3 and not kv.active
    assert pool.in_use_payload == 0
    rng = np.random.default_rng(0)
    for _cycle in range(6):
        s = kv.join()
        assert s is not None
        k = rng.standard_normal((3, 4, 1, 2), dtype=np.float32)
        kv.write_prefill("a", k, k, slots=[s])
        kv.set_slot_length(s, 4)
        kv.retire(s)
        assert kv.free_slots == 3
    assert kv.stats.reclaims >= 6 * 2          # 2 pages per retired slot
    assert kv.stats.spills == 0                # reclaim never pays a write
    assert pool.in_use_payload == 0
    kv.close()
    assert pool.in_use_payload == 0


def test_retired_slot_pages_never_readable_by_next_request(tmp_store_root):
    """Retire forgets the slot's spilled SSD keys and drops its resident
    pages, so a request rejoining the same slot reads zeros — never the
    previous occupant's K/V, even when its pages reached the store."""
    kv, pool, store = _slotted_kv(tmp_store_root, slots=2, resident=3)
    junk = np.full((2, 4, 1, 2), 7.5, np.float32)
    kv.write_prefill("a", junk, junk)          # 4 pages through 3 slots
    kv.set_length(4)
    assert kv.stats.spills >= 1                # slot 0's page hit the store
    assert any(store.contains(f"kv/a/s00/p{p:04d}") for p in (0, 1))
    kv.retire(0)
    s = kv.join()
    assert s == 0                              # same physical slot
    kg, vg = kv.gather_window("a", 4)
    assert (kg[0] == 0).all() and (vg[0] == 0).all()       # not 7.5
    np.testing.assert_array_equal(kg[1], junk[1])          # slot 1 untouched
    kv.close()
    assert pool.in_use_payload == 0


def test_retire_rejects_pinned_pages(tmp_store_root):
    """Retire is a between-plan-runs operation: a pinned page (staging
    worker mid-copy) must fail loudly, not be yanked."""
    kv, _pool, _store = _slotted_kv(tmp_store_root)
    kv.ensure_page("a", 0, slot=0, pin=True)
    with pytest.raises(RuntimeError, match="pinned"):
        kv.retire(0)
    kv.unpin("a", 0, slot=0)
    kv.retire(0)
    kv.close()


def test_admissible_page_budget_check(tmp_store_root):
    """A prompt whose page window plus one turnover slot exceeds the
    residency budget can never stream a gather without self-eviction —
    admissible() is the scheduler's terminal-refusal predicate."""
    kv, _pool, _store = _slotted_kv(tmp_store_root, resident=2, max_seq=4)
    assert kv.admissible(2)                    # 1 page + 1 turnover = 2
    assert not kv.admissible(3)                # 2 pages + 1 > 2
    assert not kv.admissible(0) and not kv.admissible(5)   # bounds
    kv.close()


# -- the serving engine over a real offloaded session --------------------------

def _requests(specs):
    """specs: list of (prompt_len, max_new, arrival[, eos]) tuples."""
    out = []
    for i, spec in enumerate(specs):
        n, max_new, arrival = spec[:3]
        eos = spec[3] if len(spec) > 3 else None
        out.append(Request(rid=f"r{i}", prompt=_prompt(n, seed=i),
                           max_new_tokens=max_new, arrival=arrival,
                           eos_token=eos))
    return out


def _solo_reference(tmp_store_root, req, batch=2):
    """Greedy tokens for one request decoded entirely alone, through the
    uncached full-prefix path (the independently-trusted oracle: PR-5
    pinned cached == uncached on the joint path)."""
    with OffloadedDecoder(_model(),
                          memascend_policy(tmp_store_root, lr=1e-3)) as dec:
        tokens = np.tile(req.prompt[None, :], (batch, 1))
        out = dec.generate(tokens, req.max_new_tokens)[0]
    toks = []
    for t in out:
        toks.append(int(t))
        if req.eos_token is not None and int(t) == req.eos_token:
            break
    return toks


def test_continuous_matches_solo_greedy_with_ragged_arrivals(tmp_store_root):
    """The acceptance gate: a ragged-arrival continuous-batched run emits,
    per request, exactly the greedy tokens that request produces decoded
    alone — joins, retires, slot reuse, and lane masking included — and a
    second identically-shaped run retraces nothing."""
    specs = [(3, 6, 0.0), (6, 4, 0.0), (9, 5, 0.02), (5, 6, 0.05)]
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        report = _engine(dec, tick=0.005).run(_requests(specs))
        warm = dec.session.decode_compiles()
        report2 = _engine(dec, tick=0.005).run(_requests(specs))
        assert dec.session.decode_compiles() == warm   # zero warm retraces
        assert report.kv_stats["reclaims"] > 0         # retires reclaimed
    assert [r.state for r in report.requests] == [RequestState.DONE] * 4
    assert report.occupancy > 0.5
    for i, r in enumerate(sorted(report.requests, key=lambda r: r.rid)):
        ref = _solo_reference(tmp_store_root + f"s{i}", r)
        assert r.output == ref, f"request {r.rid} diverged from solo decode"
        assert r.metrics.tokens_out == len(ref)
    for r1, r2 in zip(report.requests, report2.requests, strict=True):
        assert r1.output == r2.output                  # runs are deterministic


def test_eos_retires_slot_early(tmp_store_root):
    """An emitted EOS retires the request at that token (EOS kept in the
    output) and hands the slot to the queue's next request."""
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        probe = _engine(dec).run(_requests([(4, 8, 0.0)]))
        full = probe.requests[0].output
        # pick an emitted token at its own first occurrence as the EOS, so
        # the stop index is well-defined
        idx = next(i for i, t in enumerate(full) if t not in full[:i])
        reqs = _requests([(4, 8, 0.0, full[idx]), (5, 3, 0.0), (6, 3, 0.0)])
        report = _engine(dec).run(reqs)
    r0 = report.requests[0]
    assert r0.state is RequestState.DONE
    assert r0.output == full[:idx + 1]                 # EOS kept, then stop
    assert all(r.state is RequestState.DONE for r in report.requests)


def test_scheduler_refuses_oversized_prompt_terminally(tmp_store_root):
    """A prompt too long for the page budget is REFUSED (terminal), while
    admissible requests behind it in the queue are served normally."""
    spec = DecodeSpec(batch=2, max_seq=16, bucket=4, page_tokens=4,
                      resident_pages=2)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        kv_probe = dec.session.open_kv_cache()
        assert kv_probe.admissible(12) and not kv_probe.admissible(13)
        kv_probe.close()
        reqs = _requests([(14, 4, 0.0), (4, 3, 0.0)])
        report = _engine(dec).run(reqs)
    assert report.requests[0].state is RequestState.REFUSED
    assert report.requests[0].output == []
    assert report.requests[1].state is RequestState.DONE
    assert len(report.requests[1].output) == 3


def test_static_mode_matches_continuous_tokens(tmp_store_root):
    """The ablation baseline decodes the same greedy tokens — it only
    schedules worse (whole batches, no backfill), it is not allowed to
    change outputs."""
    specs = [(3, 5, 0.0), (6, 3, 0.0), (4, 4, 0.01)]
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        cont = _engine(dec, tick=0.005).run(_requests(specs))
        stat = _engine(dec, tick=0.005).run(_requests(specs), mode="static")
    assert all(r.state is RequestState.DONE for r in stat.requests)
    for rc, rs in zip(cont.requests, stat.requests, strict=True):
        assert rc.output == rs.output


def test_gqa_step_bitwise_invariant_to_cache_extent():
    """The kernel contract continuous batching stands on: a row's decode
    attention output is BITWISE identical no matter how far the shared
    device extent stretches past its own length (a co-lane crossing a
    time-bucket boundary grows the extent for everyone).  The chunked
    reduction grid makes this exact; without it XLA regroups the softmax
    and PV reductions per extent shape and the same row rounds
    differently — one bf16 ulp, enough to flip a near-tie argmax."""
    import jax.numpy as jnp

    from repro.models.attention import gqa_step
    from repro.models.transformer import init_layer_params

    chunk, length = 8, 5
    params = {k: jnp.asarray(v, jnp.bfloat16)
              for k, v in init_layer_params(jax.random.PRNGKey(1),
                                            CFG, 0).items()}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 1, CFG.d_model)), jnp.bfloat16)
    kh, hd = CFG.n_kv_heads, CFG.d_model // CFG.n_heads
    valid_k = rng.normal(size=(2, length, kh, hd))
    valid_v = rng.normal(size=(2, length, kh, hd))
    outs = []
    for extent in (chunk, 3 * chunk):
        # junk past each row's length: huge values, different per extent —
        # masking must keep them out of the math entirely
        k = rng.normal(size=(2, extent, kh, hd)) * 50.0
        v = rng.normal(size=(2, extent, kh, hd)) * 50.0
        k[:, :length], v[:, :length] = valid_k, valid_v
        cl = jnp.asarray([length, extent - 1], jnp.int32)
        out, _k, _v = gqa_step(params, x, CFG, jnp.asarray(k, jnp.bfloat16),
                               jnp.asarray(v, jnp.bfloat16), cl, chunk=chunk)
        outs.append(np.asarray(out[0], np.float32))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_fake_clock_arrival_and_queue_metrics(tmp_store_root):
    """Deterministic clock: a request arriving at t=5 is admitted at
    exactly t=5 after an idle sleep, with zero queue wait; the first
    request's TTFT is zero (no queue, instant prefill on the fake clock)."""
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        report = _engine(dec).run(_requests([(4, 2, 0.0), (4, 2, 5.0)]))
    r0, r1 = report.requests
    assert r0.metrics.ttft_s == 0.0 and r0.metrics.queue_wait_s == 0.0
    assert r1.metrics.admitted_at == 5.0
    assert r1.metrics.queue_wait_s == 0.0
    assert report.duration_s == 5.0
    assert report.ttft_percentile(99) == 0.0


def test_run_reclaims_pages_on_mid_run_abort(tmp_store_root):
    """A compute failure mid-run must reclaim every in-flight request's
    pages (engine closes the cache on the error path) and leave the
    session serviceable for the next run."""
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    dec = OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3), decode=spec)
    s = dec.session
    calls = {"n": 0}
    real_step = s._jit_block_step

    def flaky_step(params, h, k, v, cache_len, **kw):
        calls["n"] += 1
        if calls["n"] == 7:                    # mid-decode, requests active
            raise RuntimeError("injected step failure")
        return real_step(params, h, k, v, cache_len, **kw)

    s._jit_block_step = flaky_step
    with pytest.raises(RuntimeError, match="injected"):
        _engine(dec).run(_requests([(4, 6, 0.0), (5, 6, 0.0)]))
    assert s.pool.in_use_payload == 0          # weights AND kv pages back
    assert dec.kv_stats is not None            # abort still snapshots stats
    s._jit_block_step = real_step
    report = _engine(dec).run(_requests([(4, 2, 0.0)]))
    assert report.requests[0].state is RequestState.DONE
    dec.close()


def test_decoder_close_idempotent_stats_survive(tmp_store_root):
    """Teardown hardening: close() twice is fine, the stats properties
    answer with the final pre-teardown snapshot instead of raising, and
    compute entry points refuse cleanly."""
    spec = DecodeSpec(batch=2, max_seq=16, bucket=8)
    dec = OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3), decode=spec)
    prompts = np.tile(_prompt(4)[None, :], (2, 1))
    dec.generate(prompts, 2)
    live = dec.fetch_stats
    dec.close()
    dec.close()                                # idempotent
    assert dec.closed
    assert dec.fetch_stats == live             # snapshot, not a raise
    assert set(dec.kv_overlap_stats) == {"kv_stage_gets", "kv_stage_hits",
                                         "kv_stage_wait_s"}
    with pytest.raises(RuntimeError, match="closed"):
        dec.generate(prompts, 1)
    with pytest.raises(RuntimeError, match="closed"):
        dec.step_logits(prompts)


def test_request_and_scheduler_validation():
    from repro.serve import FifoScheduler
    with pytest.raises(ValueError, match="non-empty"):
        Request(rid="a", prompt=np.zeros((0,), np.int32), max_new_tokens=1)
    with pytest.raises(ValueError, match="non-empty"):
        Request(rid="a", prompt=np.zeros((2, 2), np.int32), max_new_tokens=1)
    with pytest.raises(TypeError, match="integer"):
        Request(rid="a", prompt=np.ones(3, np.float32), max_new_tokens=1)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(rid="a", prompt=np.ones(3, np.int32), max_new_tokens=0)
    dup = _requests([(3, 1, 0.0)]) + [Request(rid="r0",
                                              prompt=np.ones(3, np.int32),
                                              max_new_tokens=1)]
    with pytest.raises(ValueError, match="duplicate"):
        FifoScheduler(dup)
