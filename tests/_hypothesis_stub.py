"""Minimal in-repo fallback for ``hypothesis`` (loaded by conftest.py only
when the real package is absent).

The container this repo targets has no network access, so dev-only deps may
be missing.  The property tests in this suite use a small slice of the
hypothesis API — ``given``, ``settings``, ``HealthCheck`` and the
``integers`` / ``floats`` / ``lists`` / ``tuples`` / ``sampled_from``
strategies — which this stub reimplements as deterministic seeded random
sampling (boundary-biased, no shrinking).  With real hypothesis installed
(see requirements-dev.txt) the stub is never imported.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

__version__ = "0.0.0-repro-stub"

_DEFAULT_MAX_EXAMPLES = 25


class HealthCheck:
    """Name-compatible sentinel namespace; the stub has no health checks."""

    function_scoped_fixture = "function_scoped_fixture"
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


class _Strategy:
    """A draw function ``rng -> value`` with hypothesis-like combinators."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                value = self._draw(rng)
                if pred(value):
                    return value
            raise ValueError("stub strategy filtered out every draw")
        return _Strategy(draw)


strategies = types.ModuleType("hypothesis.strategies")


def _strategy(fn):
    setattr(strategies, fn.__name__, fn)
    return fn


@_strategy
def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2 ** 63) if min_value is None else int(min_value)
    hi = (2 ** 63) - 1 if max_value is None else int(max_value)
    edges = sorted({lo, hi, min(max(0, lo), hi), min(max(1, lo), hi)})

    def draw(rng):
        r = rng.random()
        if r < 0.2:                       # boundary bias, like hypothesis
            return rng.choice(edges)
        if r < 0.5 and hi - lo > 4096:    # log-uniform for huge ranges
            span = hi - lo
            return lo + min(span, int(span ** rng.random()))
        return rng.randint(lo, hi)
    return _Strategy(draw)


@_strategy
def floats(min_value=None, max_value=None, **_kw) -> _Strategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng):
        if rng.random() < 0.2:
            return rng.choice((lo, hi))
        return lo + (hi - lo) * rng.random()
    return _Strategy(draw)


@_strategy
def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: rng.choice(pool))


@_strategy
def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int | None = None, **_kw) -> _Strategy:
    cap = (min_size + 8) if max_size is None else max_size

    def draw(rng):
        n = rng.randint(min_size, cap)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


@_strategy
def tuples(*parts: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(p.draw(rng) for p in parts))


@_strategy
def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


@_strategy
def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


@_strategy
def one_of(*options: _Strategy) -> _Strategy:
    pool = list(options)
    return _Strategy(lambda rng: rng.choice(pool).draw(rng))


class settings:
    """Decorator; only ``max_examples`` is honoured by the stub."""

    def __init__(self, max_examples: int | None = None, deadline=None,
                 suppress_health_check=(), **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._stub_max_examples = self.max_examples
        return fn


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test over ``max_examples`` deterministic random draws.

    Positional strategies bind to the *rightmost* parameters of the test
    function (hypothesis semantics, so pytest fixtures stay leftmost); the
    wrapper's signature drops strategy-bound parameters so pytest injects
    only real fixtures.
    """

    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        bound = dict(zip(names[len(names) - len(arg_strategies):],
                         arg_strategies, strict=True))
        bound.update(kw_strategies)
        unknown = set(bound) - set(names)
        if unknown:
            raise TypeError(f"@given strategies {sorted(unknown)} do not "
                            f"match parameters of {fn.__name__}")

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            import random
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {name: strat.draw(rng)
                         for name, strat in bound.items()}
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(
            parameters=[sig.parameters[p] for p in names if p not in bound])
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)  # marker
        return wrapper
    return deco
