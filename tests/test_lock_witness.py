"""Dynamic lock-order witness (:mod:`repro.core.lock_witness`): the
acquisition graph, cycle detection, Condition compatibility, and the
install/uninstall swap."""

import threading

import numpy as np
import pytest

from repro.core import lock_witness
from repro.core.lock_witness import LockOrderError, WitnessLock


@pytest.fixture(autouse=True)
def fresh_graph():
    """The witness graph is process-global; isolate each test."""
    lock_witness.reset()
    yield
    lock_witness.reset()


def test_ab_ba_inversion_is_a_cycle():
    """The classic deadlock shape MUST be flagged: path 1 takes A then B,
    path 2 takes B then A.  Each path alone ran fine — the witness exists
    precisely because the unlucky interleaving may never occur in CI."""
    a = WitnessLock("siteA")
    b = WitnessLock("siteB")
    with a, b:
        pass
    lock_witness.check()          # A -> B alone is acyclic
    with b, a:
        pass
    with pytest.raises(LockOrderError, match="siteA|siteB"):
        lock_witness.check()


def test_consistent_nesting_across_threads_is_clean():
    a = WitnessLock("outer")
    b = WitnessLock("inner")

    def worker():
        for _ in range(10):
            with a, b:
                pass

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert lock_witness.edges() == {"outer": {"inner"}}
    lock_witness.check()


def test_three_lock_cycle_detected():
    """Inversions need not be pairwise: A->B, B->C, C->A deadlocks three
    threads with no two of them in direct opposition."""
    a, b, c = WitnessLock("sA"), WitnessLock("sB"), WitnessLock("sC")
    for first, second in ((a, b), (b, c), (c, a)):
        with first, second:
            pass
    with pytest.raises(LockOrderError):
        lock_witness.check()


def test_same_site_nesting_is_ignored():
    """Two locks from one creation site (a per-instance lock of the same
    class, or ``[Lock() for ...]``) are one node: ordering inside a
    homogeneous group is an instance-level protocol the site-keyed graph
    cannot judge, so it must not false-positive."""
    a = WitnessLock("same")
    b = WitnessLock("same")
    with a, b:
        pass
    with b, a:
        pass
    assert lock_witness.edges() == {}
    lock_witness.check()


def test_non_lifo_release_keeps_stack_straight():
    """The pipeline drops locks mid-scope (kv_cache._spill releases the
    cache lock around its store write): release order is not LIFO, and
    the held-stack bookkeeping must still attribute later acquires to
    the locks actually held."""
    a, b, c = WitnessLock("nlA"), WitnessLock("nlB"), WitnessLock("nlC")
    a.acquire()
    b.acquire()
    a.release()          # out of order: b remains the only held lock
    c.acquire()          # edge must be b -> c, NOT a -> c
    c.release()
    b.release()
    assert lock_witness.edges() == {"nlA": {"nlB"}, "nlB": {"nlC"}}
    lock_witness.check()


def test_condition_over_witness_lock_works():
    """threading.Condition accepts a WitnessLock as its underlying lock
    (the install() swap wraps every Condition this way): wait/notify
    across threads must behave normally and record the cv's site."""
    cv = threading.Condition(WitnessLock("cv-site"))
    ready = []

    def waiter():
        with cv:
            while not ready:
                cv.wait(timeout=5.0)

    t = threading.Thread(target=waiter)
    t.start()
    with cv:
        ready.append(1)
        cv.notify_all()
    t.join(timeout=5.0)
    assert not t.is_alive()
    lock_witness.check()


def test_install_swaps_and_uninstall_restores():
    # under --lock-witness the conftest installed session-wide; start
    # from the uninstalled state either way and restore on the way out
    was_installed = lock_witness.installed()
    if was_installed:
        lock_witness.uninstall()
    try:
        real_lock = threading.Lock
        assert not lock_witness.installed()
        lock_witness.install()
        try:
            assert lock_witness.installed()
            assert isinstance(threading.Lock(), WitnessLock)
            cv = threading.Condition()
            with cv:        # the swapped Condition wraps a WitnessLock
                pass
        finally:
            lock_witness.uninstall()
        assert threading.Lock is real_lock
        assert not isinstance(threading.Lock(), WitnessLock)
    finally:
        if was_installed:
            lock_witness.install()


def test_witnessed_offload_stack_is_cycle_free(tmp_store_root, rng):
    """Run a real slice of the pipeline — pool + swapper + paged KV cache
    with spills — under the witness and require a cycle-free graph.  This
    is the dynamic complement of the static no-blocking-under-lock
    checker over the exact code the PR 5 races lived in."""
    was_installed = lock_witness.installed()  # no-op under --lock-witness
    lock_witness.install()
    try:
        from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                                MemoryTracker, ParameterSwapper, PoolCensus,
                                ShapeClass)
        from repro.core.kv_cache import SpillableKVCache
        from repro.core.nvme import FilesystemEngine

        page_shape = (2, 1, 2, 1, 2)
        nbytes = int(np.prod(page_shape)) * 4
        census = PoolCensus((ShapeClass("w", 256 * 4, 2),),
                            inflight_blocks=2).with_kv(nbytes, 2)
        pool = AdaptiveBufferPool(
            census, AlignmentFreeAllocator(tracker=MemoryTracker(),
                                           component="pinned",
                                           backing="numpy"))
        store = FilesystemEngine(tmp_store_root)
        swapper = ParameterSwapper(store, pool, class_of={"t0": "w"})
        store.write("t0", rng.standard_normal(256).astype(np.float32))
        kv = SpillableKVCache(["a", "b", "c"], page_shape, 4, np.float32,
                              pool, store, resident_limit=2)
        try:
            k = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
            swapper.prefetch("t0", np.float32, (256,))
            kv.write_prefill("a", k, k)       # spills through the budget
            kv.write_prefill("b", k, k)
            kv.prefetch_window("a", 3)        # async refill
            kv.gather_window("a", 3)          # waits it out under pins
            swapper.get("t0", np.float32, (256,)).release()
        finally:
            kv.close()
            swapper.drain()
            pool.close()
            store.close()
        assert lock_witness.edges()           # the run recorded something
        lock_witness.check()
    finally:
        if not was_installed:
            lock_witness.uninstall()
