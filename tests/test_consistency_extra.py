"""Deeper cross-path consistency tests (beyond the per-arch smokes):

* MLA decode == parallel forward (the compressed-latent cache is easy to
  get subtly wrong),
* PaliGemma bidirectional-prefix mask semantics,
* whisper decode == decoder_forward with cross-attention caches,
* pool census == model adapter census (the two census paths agree),
* rolled scan == python-unrolled forward (the calibration instrument is
  numerically the same program).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build
from repro.models import whisper as whs

KEY = jax.random.PRNGKey(0)


def test_mla_decode_matches_parallel_forward():
    cfg = ARCHS["deepseek-v3-671b"].reduced()
    # ample router capacity: prefill drops over-capacity tokens (a batched
    # approximation decode doesn't share), which is a semantic difference,
    # not an MLA-cache bug — neutralize it for the equivalence check
    cfg = dataclasses.replace(
        cfg, mtp=False, n_layers=2,
        moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    impl = build(cfg, compute_dtype=jnp.float32)
    params = impl.init_params(KEY)
    s = 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, cfg.vocab)
    full_logits = impl.prefill_fn(params, {"tokens": tokens})
    cache = impl.init_cache(1, s, dtype=jnp.float32)
    step = jax.jit(impl.decode_fn)
    for t in range(s):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[0, 0]), np.asarray(full_logits[0, t]),
            rtol=2e-3, atol=2e-3)


def test_paligemma_prefix_is_bidirectional():
    """Within the image prefix, later positions must influence earlier
    ones (bidirectional); text positions must stay causal."""
    cfg = ARCHS["paligemma-3b"].reduced()
    impl = build(cfg, compute_dtype=jnp.float32)
    params = impl.init_params(KEY)
    b, s_text = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s_text), 0,
                                cfg.vocab)
    img = jax.random.normal(jax.random.PRNGKey(3),
                            (b, cfg.prefix_len, cfg.d_model))
    base = impl.prefill_fn(params, {"tokens": tokens, "image_embeds": img})
    # perturb the LAST image token: the FIRST prefix position's output
    # must change (bidirectional prefix)...
    img2 = img.at[:, -1].add(1.0)
    out2 = impl.prefill_fn(params, {"tokens": tokens, "image_embeds": img2})
    # ...and so must the text logits (text attends to the prefix)
    assert float(jnp.abs(base - out2).max()) > 1e-6
    # perturbing the LAST TEXT token must not change earlier text logits
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
    out3 = impl.prefill_fn(params, {"tokens": tokens2, "image_embeds": img})
    np.testing.assert_allclose(np.asarray(base[:, :-1]),
                               np.asarray(out3[:, :-1]), atol=1e-5)


def test_whisper_decode_matches_parallel():
    cfg = ARCHS["whisper-tiny"].reduced()
    impl = build(cfg, compute_dtype=jnp.float32)
    params = impl.init_params(KEY)
    b, s = 1, 6
    frames = jax.random.normal(jax.random.PRNGKey(4),
                               (b, cfg.encoder_seq, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)
    full = impl.prefill_fn(params, {"frames": frames, "tokens": tokens})

    memory = whs.encode(cfg, params, frames)
    cache = impl.init_cache(b, s, dtype=jnp.float32)
    cache = whs.prefill_cross_cache(cfg, params, memory, cache)
    for t in range(s):
        logits, cache = whs.whisper_decode_step(
            cfg, params, cache, tokens[:, t:t + 1], jnp.int32(t),
            compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(logits[0, 0]),
                                   np.asarray(full[0, t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-4b", "jamba-v0.1-52b",
                                  "xlstm-1.3b"])
def test_unrolled_matches_rolled_forward(arch):
    """The dry-run calibration instrument (python-unrolled) must be the
    same function as the deployable scan."""
    cfg = ARCHS[arch].reduced()
    impl_r = build(cfg, compute_dtype=jnp.float32, unroll=False)
    impl_u = build(cfg, compute_dtype=jnp.float32, unroll=True)
    params = impl_r.init_params(KEY)
    batch = {"tokens": jnp.full((2, 32), 3, jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    l_r = impl_r.loss_fn(params, batch)
    l_u = impl_u.loss_fn(params, batch)
    np.testing.assert_allclose(float(l_r), float(l_u), rtol=1e-6)


def test_census_paths_agree():
    """ModelConfig.pool_census and the adapter's census describe the same
    streamed tensors for a homogeneous config."""
    from repro.core.model_adapter import make_offloadable_lm
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="c", family="dense", n_layers=3, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)
    model = make_offloadable_lm(cfg, KEY)
    adapter_census = model.census(inflight_blocks=1, bytes_per_elem=2)
    config_census = cfg.pool_census(inflight_blocks=1)
    a = {c.name: c for c in adapter_census.classes}
    c = {c.name: c for c in config_census.classes}
    for cls in ("ffn", "kv_proj", "qo_proj"):
        assert a[cls].nbytes == c[cls].nbytes, cls
        assert a[cls].per_block == c[cls].per_block, cls
