from repro.core import DynamicLossScaler


def test_backoff_on_overflow():
    s = DynamicLossScaler(scale=1024.0)
    assert not s.update(True)          # overflow -> skip step
    assert s.scale == 512.0
    assert s.n_overflows == 1


def test_growth_after_interval():
    s = DynamicLossScaler(scale=8.0, growth_interval=3)
    for _ in range(2):
        assert s.update(False)
    assert s.scale == 8.0
    assert s.update(False)
    assert s.scale == 16.0


def test_overflow_resets_growth_counter():
    s = DynamicLossScaler(scale=8.0, growth_interval=2)
    s.update(False)
    s.update(True)
    s.update(False)
    assert s.scale == 4.0              # halved once, not yet regrown


def test_scale_bounds():
    s = DynamicLossScaler(scale=2.0, min_scale=1.0)
    for _ in range(10):
        s.update(True)
    assert s.scale == 1.0
    s2 = DynamicLossScaler(scale=2.0 ** 23, growth_interval=1,
                           max_scale=2.0 ** 24)
    for _ in range(5):
        s2.update(False)
    assert s2.scale == 2.0 ** 24
