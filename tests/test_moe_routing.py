"""Property-based lockdown of the MoE router + sort-based dispatch.

These are the invariants the expert-paging path leans on: the executor's
host-side fetch decision reads the router's top-k indices, and the staged
(E, ...) stacks are only bit-identical to all-resident residency if the
combine provably never reads an unrouted expert's row.  Runs under real
``hypothesis`` when installed and under the deterministic in-repo stub
otherwise (tests/_hypothesis_stub.py).

* **router_topk** — weights are normalized over the chosen k (sum to 1),
  every chosen index is a true top-k member of the softmax row, and the
  pinned-``idx`` path of :func:`moe_ffn` regathers bitwise-identical
  weights;
* **_positions_in_expert** — the sort-based rank matches a numpy oracle
  (first-come rank within each expert id) across duplicate-heavy
  assignments;
* **capacity drops** — which (token, choice) pairs a capacity factor
  keeps is a pure function of the assignment (deterministic at chunk
  boundaries), and dropped pairs contribute exactly zero to the output.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import _positions_in_expert, moe_ffn, router_topk

SET = settings(max_examples=25, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def _cfg(n_experts=8, top_k=2, capacity_factor=1.25):
    return ModelConfig(
        name="prop-moe", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=32, vocab=64,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                      capacity_factor=capacity_factor))


def _params(key, cfg):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "moe.w_router": jax.random.normal(ks[0], (d, e.n_experts),
                                          jnp.float32) * 0.2,
        "moe.w_gate": jax.random.normal(
            ks[1], (e.n_experts, d, e.d_ff_expert), jnp.float32) * 0.2,
        "moe.w_up": jax.random.normal(
            ks[2], (e.n_experts, d, e.d_ff_expert), jnp.float32) * 0.2,
        "moe.w_down": jax.random.normal(
            ks[3], (e.n_experts, e.d_ff_expert, d), jnp.float32) * 0.2,
    }


# -- router_topk -------------------------------------------------------------

@SET
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 48),
       n_experts=st.integers(2, 16), top_k=st.integers(1, 4))
def test_router_topk_invariants(seed, t, n_experts, top_k):
    top_k = min(top_k, n_experts)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, n_experts),
                               jnp.float32) * 3.0
    w, idx, aux = router_topk(logits, top_k)
    w, idx = np.asarray(w), np.asarray(idx)
    # normalized over the chosen k
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    assert (w >= 0).all()
    # every chosen index is a true top-k member of its softmax row
    probs = np.asarray(jax.nn.softmax(logits.astype(jnp.float32), axis=-1))
    kth = np.sort(probs, axis=-1)[:, -top_k]
    assert (np.take_along_axis(probs, idx, axis=-1)
            >= kth[:, None] - 1e-12).all()
    # indices are distinct per token (top_k never repeats a column)
    for row in idx:
        assert len(set(row.tolist())) == top_k
    assert np.isfinite(float(aux)) and float(aux) >= 0


@SET
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 24))
def test_pinned_idx_path_matches_topk_bitwise(seed, t):
    """moe_ffn(idx=...) — the expert-paging path — must regather weights
    bitwise equal to the top-k values and produce the identical output."""
    cfg = _cfg()
    key = jax.random.PRNGKey(seed)
    params = _params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (1, t, cfg.d_model), jnp.float32)
    out_free, aux_free = moe_ffn(params, x, cfg)
    xf = x.reshape(t, cfg.d_model)
    logits = xf @ params["moe.w_router"]
    _w, idx, _aux = router_topk(logits, cfg.moe.top_k)
    out_pin, aux_pin = moe_ffn(params, x, cfg, idx=idx)
    np.testing.assert_array_equal(np.asarray(out_free), np.asarray(out_pin))
    np.testing.assert_array_equal(np.asarray(aux_free), np.asarray(aux_pin))


# -- _positions_in_expert ----------------------------------------------------

def _positions_oracle(flat_e: np.ndarray) -> np.ndarray:
    """First-come rank of each entry within its expert id (numpy)."""
    seen: dict[int, int] = {}
    pos = np.zeros_like(flat_e)
    for i, e in enumerate(flat_e.tolist()):
        pos[i] = seen.get(e, 0)
        seen[e] = pos[i] + 1
    return pos


@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 96),
       n_experts=st.integers(1, 8))
def test_positions_in_expert_matches_oracle(seed, n, n_experts):
    rng = np.random.default_rng(seed)
    # duplicate-heavy: a few experts soak up most assignments
    flat = rng.choice(n_experts, size=n,
                      p=np.ones(n_experts) / n_experts).astype(np.int32)
    got = np.asarray(_positions_in_expert(jnp.asarray(flat), n))
    np.testing.assert_array_equal(got, _positions_oracle(flat))


def test_positions_in_expert_all_same_expert():
    """Worst-case duplicates: every assignment lands on one expert."""
    flat = np.zeros(64, np.int32)
    got = np.asarray(_positions_in_expert(jnp.asarray(flat), 64))
    np.testing.assert_array_equal(got, np.arange(64))


# -- capacity drops ----------------------------------------------------------

def _kept_mask(flat_e: np.ndarray, capacity: int) -> np.ndarray:
    pos = _positions_oracle(flat_e)
    return pos < capacity


@SET
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(4, 32))
def test_capacity_drop_determinism_at_chunk_boundaries(seed, t):
    """The kept set is a pure function of the assignment — two identical
    calls (and the low-capacity config straddling the capacity boundary
    exactly) agree bitwise, so capacity drops cannot break the routed vs
    all-resident equivalence."""
    cfg = _cfg(capacity_factor=0.5)   # forces drops at the chunk boundary
    key = jax.random.PRNGKey(seed)
    params = _params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 2),
                          (1, t, cfg.d_model), jnp.float32)
    out1, aux1 = moe_ffn(params, x, cfg)
    out2, aux2 = moe_ffn(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(aux1), np.asarray(aux2))


@SET
@given(seed=st.integers(0, 2**31 - 1))
def test_dropped_tokens_contribute_zero(seed):
    """A (token, choice) pair past capacity adds exactly nothing: zeroing
    the dropped pairs' weights by hand reproduces the module's output."""
    cfg = _cfg(n_experts=4, top_k=2, capacity_factor=0.25)
    t = 16
    key = jax.random.PRNGKey(seed)
    params = _params(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 3),
                          (1, t, cfg.d_model), jnp.float32)
    out, _aux = moe_ffn(params, x, cfg)

    xf = np.asarray(x.reshape(t, cfg.d_model))
    # logits via the same jax matmul as models.layers.dense — bitwise equal,
    # so the oracle's top-k selection cannot flip on numpy rounding
    logits = x.reshape(t, cfg.d_model) @ params["moe.w_router"]
    w, idx, _ = router_topk(logits, cfg.moe.top_k)
    w, idx = np.asarray(w), np.asarray(idx)
    capacity = int(max(cfg.moe.top_k * t // cfg.moe.n_experts
                       * cfg.moe.capacity_factor, 4))
    flat_e = idx.reshape(-1)
    kept = _kept_mask(flat_e, capacity)
    # oracle combine: per-expert dense FFN applied to each kept pair
    y = np.zeros_like(xf)
    gate = np.asarray(params["moe.w_gate"])
    up = np.asarray(params["moe.w_up"])
    down = np.asarray(params["moe.w_down"])
    token_of = np.repeat(np.arange(t), cfg.moe.top_k)
    for p, (tok, e) in enumerate(zip(token_of, flat_e)):
        if not kept[p]:
            continue   # dropped: contributes exactly zero
        h = xf[tok]
        hid = (h @ gate[e])
        hid = hid / (1 + np.exp(-hid)) * (h @ up[e])   # silu(g) * u
        y[tok] += w.reshape(-1)[p] * (hid @ down[e])
    np.testing.assert_allclose(np.asarray(out)[0], y, atol=2e-4)
