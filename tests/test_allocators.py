"""Pinned allocators: pow2 baseline vs alignment-free (paper §III-B/§IV-C)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AlignmentFreeAllocator, MemoryTracker,
                        PowerOfTwoCachingAllocator, next_power_of_two,
                        align_up, DMA_ALIGNMENT)


def test_pow2_rounding_doubles_large_requests():
    t = MemoryTracker()
    a = PowerOfTwoCachingAllocator(tracker=t, component="p")
    # the paper's example: a 2.1 GiB request reserves 4 GiB
    req = int(2.1 * 2**30)
    buf = a.alloc(req)
    assert buf.capacity == 4 * 2**30
    assert buf.capacity - buf.size > 1.8 * 2**30
    buf.free()


def test_alignment_free_wastes_at_most_one_page():
    t = MemoryTracker()
    a = AlignmentFreeAllocator(tracker=t, component="p")
    for req in (1, 4095, 4096, 4097, int(2.1 * 2**30)):
        buf = a.alloc(req)
        assert buf.capacity - buf.size < DMA_ALIGNMENT
        assert buf.capacity % DMA_ALIGNMENT == 0
        buf.free()


def test_tracker_accounting_and_peak():
    t = MemoryTracker()
    a = PowerOfTwoCachingAllocator(tracker=t, component="x", caching=False)
    b1 = a.alloc(1000)
    b2 = a.alloc(3000)
    assert t.live_requested == 4000
    assert t.live_allocated == 1024 + 4096
    b1.free()
    assert t.live_requested == 3000
    assert t.peak_allocated == 1024 + 4096
    b2.free()
    t.assert_quiescent()


def test_double_free_raises():
    a = AlignmentFreeAllocator(tracker=MemoryTracker(), component="p")
    buf = a.alloc(100)
    buf.free()
    with pytest.raises(ValueError, match="double free"):
        buf.free()


def test_caching_reuses_numpy_backing():
    a = PowerOfTwoCachingAllocator(tracker=MemoryTracker(), component="p",
                                   backing="numpy")
    b1 = a.alloc(1000)
    base1 = b1._full_array
    b1.free()
    b2 = a.alloc(900)   # same pow2 class (1024) -> reuses the cached block
    assert b2._full_array is base1
    b2.free()


def test_numpy_backing_view_roundtrip():
    a = AlignmentFreeAllocator(tracker=MemoryTracker(), component="p",
                               backing="numpy")
    buf = a.alloc(64 * 4)
    v = buf.view(np.float32, (8, 8))
    v[:] = np.arange(64).reshape(8, 8)
    assert v[3, 4] == 28
    buf.free()


@given(st.integers(min_value=1, max_value=2**40))
def test_pow2_props(n):
    p = next_power_of_two(n)
    assert p >= n and p < 2 * n + 1 and (p & (p - 1)) == 0


@given(st.integers(min_value=1, max_value=2**40))
def test_align_props(n):
    a = align_up(n, DMA_ALIGNMENT)
    assert a >= n and a - n < DMA_ALIGNMENT and a % DMA_ALIGNMENT == 0


@settings(max_examples=30)
@given(st.lists(st.integers(min_value=DMA_ALIGNMENT, max_value=1 << 28),
                min_size=1, max_size=30))
def test_waste_ordering_property(sizes):
    """Alignment-free never reserves more than pow2 for page-sized-or-larger
    requests (the offloading workload: the paper's §III-B buffers are
    hundreds of MiB; sub-page allocations stay on the default allocator)."""
    t1, t2 = MemoryTracker(), MemoryTracker()
    a1 = PowerOfTwoCachingAllocator(tracker=t1, component="x", caching=False)
    a2 = AlignmentFreeAllocator(tracker=t2, component="x")
    for s in sizes:
        a1.alloc(s)
        a2.alloc(s)
    assert t2.live_allocated <= t1.live_allocated
    assert t2.live_allocated - t2.live_requested < DMA_ALIGNMENT * len(sizes)
