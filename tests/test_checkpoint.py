"""Checkpointing through the tensor stores."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DirectNVMeEngine, FilesystemEngine
from repro.core.checkpoint import (load_pytree, restore_trainer_step,
                                   save_pytree, snapshot_trainer)


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "nested": {"b": jax.random.normal(k2, (4,)),
                       "c": jnp.arange(6, dtype=jnp.int32).reshape(2, 3)},
            "groups": [jnp.ones((2, 5), jnp.bfloat16)]}


def test_pytree_roundtrip_direct(tmp_path):
    store = DirectNVMeEngine(str(tmp_path), n_devices=2,
                             device_capacity=1 << 22)
    tree = _tree(jax.random.PRNGKey(0))
    save_pytree(store, "ckpt0", tree)
    like = jax.eval_shape(lambda: tree)
    restored = load_pytree(store, "ckpt0", like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))
    store.close()


def test_pytree_roundtrip_filesystem(tmp_path):
    store = FilesystemEngine(str(tmp_path), fsync=False)
    tree = _tree(jax.random.PRNGKey(1))
    save_pytree(store, "ck", tree)
    restored = load_pytree(store, "ck", jax.eval_shape(lambda: tree))
    np.testing.assert_allclose(np.asarray(tree["a"]),
                               np.asarray(restored["a"]))
    store.close()


def test_trainer_resume(tmp_path):
    """Resume continues the exact trajectory: train 4 steps straight vs
    2 steps + snapshot + resume + 2 steps."""
    from repro.configs.base import ModelConfig
    from repro.core import OffloadedTrainer, memascend_policy
    from repro.core.model_adapter import make_offloadable_lm
    from repro.data import DataLoader, SyntheticTextDataset

    cfg = ModelConfig(name="ck", family="dense", n_layers=2, d_model=48,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab=128)

    def batches(n):
        dl = DataLoader(SyntheticTextDataset(vocab=128, seed=5), batch=2,
                        seq_len=16)
        return [dl.next_batch() for _ in range(n)]

    bs = batches(4)
    # straight 4 steps
    tr = OffloadedTrainer(make_offloadable_lm(cfg, jax.random.PRNGKey(0)),
                          memascend_policy(str(tmp_path / "a"), lr=1e-3))
    straight = [tr.train_step(b["tokens"], b["labels"])["loss"] for b in bs]
    tr.close()

    # 2 steps, snapshot, "restart" (fresh trainer objects over the SAME
    # store root would re-register params; instead simulate resume by
    # restoring scalar state on the live trainer after scale perturbation)
    tr2 = OffloadedTrainer(make_offloadable_lm(cfg, jax.random.PRNGKey(0)),
                           memascend_policy(str(tmp_path / "b"), lr=1e-3))
    part1 = [tr2.train_step(b["tokens"], b["labels"])["loss"] for b in bs[:2]]
    snapshot_trainer(tr2)
    tr2.scaler.scale = 123.0           # clobber, then restore
    tr2.optimizer.step_count = 999
    state = restore_trainer_step(tr2)
    assert state["optimizer_step"] == 2 and tr2.scaler.scale == 1.0
    part2 = [tr2.train_step(b["tokens"], b["labels"])["loss"] for b in bs[2:]]
    tr2.close()
    np.testing.assert_allclose(straight, part1 + part2, atol=1e-6)
