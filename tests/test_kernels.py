"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# overflow_check
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("n", [1, 127, 128, 129, 65_536, 100_001])
def test_overflow_shape_dtype_sweep(dtype, n, rng):
    x = jnp.asarray(rng.standard_normal(n), dtype)
    assert bool(ops.overflow_check(x)) == bool(ref.ref_overflow_check(x))
    x = x.at[n // 2].set(jnp.inf)
    assert bool(ops.overflow_check(x))
    x = x.at[n // 2].set(jnp.nan)
    assert bool(ops.overflow_check(x))


@pytest.mark.parametrize("shape", [(4, 4), (3, 5, 7), (2, 2, 2, 2)])
def test_overflow_nd_shapes(shape, rng):
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    assert not bool(ops.overflow_check(x))
    x = x.reshape(-1).at[0].set(-jnp.inf).reshape(shape)
    assert bool(ops.overflow_check(x))


@settings(max_examples=40, deadline=None)
@given(n=st.integers(min_value=1, max_value=20_000),
       pos=st.floats(min_value=0, max_value=1),
       kind=st.sampled_from(["none", "inf", "-inf", "nan", "max"]),
       block_m=st.sampled_from([8, 64, 512]))
def test_overflow_property(n, pos, kind, block_m):
    rng = np.random.default_rng(42)
    x = rng.standard_normal(n).astype(np.float32)
    if kind in ("inf", "-inf", "nan"):
        x[int(pos * (n - 1))] = {"inf": np.inf, "-inf": -np.inf,
                                 "nan": np.nan}[kind]
    elif kind == "max":
        x[int(pos * (n - 1))] = np.finfo(np.float32).max  # must NOT trigger
    expected = kind in ("inf", "-inf", "nan")
    got = bool(ops.overflow_check(jnp.asarray(x), block_m=block_m))
    assert got == expected


# ---------------------------------------------------------------------------
# fused_adam
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16,), (100, 3), (8, 8, 9), (2048,)])
@pytest.mark.parametrize("step", [1, 10, 1000])
def test_adam_shape_step_sweep(shape, step, rng):
    p = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    m = jnp.asarray(rng.standard_normal(shape) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(rng.standard_normal(shape)) * 0.01, jnp.float32)
    kw = dict(lr=3e-3, weight_decay=0.05)
    out_k = ops.fused_adam(p, g, m, v, step, **kw)
    out_r = ref.ref_fused_adam(p, g, m, v, step, **kw)
    for a, b in zip(out_k[:3], out_r[:3], strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(out_k[3]).view(np.uint16),
        np.asarray(out_r[3]).view(np.uint16))   # bf16 bit-exact


def test_adam_multi_step_trajectory(rng):
    shape = (512,)
    p = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    g0 = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    m = jnp.zeros(shape); v = jnp.zeros(shape)
    pr, mr, vr = p, m, v
    for t in range(1, 6):
        g = g0 * (0.9 ** t)
        p, m, v, _ = ops.fused_adam(p, g, m, v, t, lr=1e-2)
        pr, mr, vr, _ = ref.ref_fused_adam(pr, g, mr, vr, t, lr=1e-2)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pr), rtol=1e-4,
                               atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=5000),
       lr=st.floats(min_value=1e-5, max_value=1e-1),
       step=st.integers(min_value=1, max_value=10_000),
       seed=st.integers(min_value=0, max_value=2**31))
def test_adam_property(n, lr, step, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.zeros(n); v = jnp.zeros(n)
    p2, m2, v2, w16 = ops.fused_adam(p, g, m, v, step, lr=lr)
    pr, mr, vr, _ = ref.ref_fused_adam(p, g, m, v, step, lr=lr)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), rtol=1e-4,
                               atol=1e-7)
    # v is a variance: always >= 0
    assert float(jnp.min(v2)) >= 0.0


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 64, 128])
def test_swa_sweep(dtype, h, kh, window, rng):
    b, s, d = 2, 256, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kh, s, d)), dtype)
    out = ops.swa_attention(q, k, v, window=window, block_q=64, block_k=64)
    expected = ref.ref_swa_attention(q, k, v, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), atol=tol)


def test_swa_non_causal(rng):
    b, h, s, d = 1, 2, 128, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    out = ops.swa_attention(q, k, v, window=0, causal=False, block_q=64,
                            block_k=64)
    expected = ref.ref_swa_attention(q, k, v, window=0, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5)


def test_swa_window_equals_full_when_window_ge_seq(rng):
    b, h, s, d = 1, 2, 128, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    full = ops.swa_attention(q, k, v, window=0, block_q=64, block_k=64)
    wide = ops.swa_attention(q, k, v, window=s, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(wide), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([64, 128, 256]),
       window=st.sampled_from([0, 32, 64]),
       blocks=st.sampled_from([(32, 32), (64, 32), (64, 64)]),
       seed=st.integers(min_value=0, max_value=2**31))
def test_swa_block_shape_invariance(s, window, blocks, seed):
    """Kernel output must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(seed)
    b, h, d = 1, 2, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    bq, bk = blocks
    out = ops.swa_attention(q, k, v, window=window, block_q=bq, block_k=bk)
    expected = ref.ref_swa_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=3e-5)
