import importlib.util
import os
import sys

# Smoke tests and benches must see exactly ONE device; the 512-device flag
# belongs to the dry-run process only (see launch/dryrun.py).
assert "--xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally"

# Property tests use hypothesis when available (requirements-dev.txt); in
# hermetic containers fall back to the deterministic in-repo stub so the
# suite still collects and runs (see tests/_hypothesis_stub.py).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_store_root(tmp_path):
    return str(tmp_path / "store")
