import collections
import importlib.util
import os
import sys
import threading

# Smoke tests and benches must see exactly ONE device; the 512-device flag
# belongs to the dry-run process only (see launch/dryrun.py).
assert "--xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally"

# Property tests use hypothesis when available (requirements-dev.txt); in
# hermetic containers fall back to the deterministic in-repo stub so the
# suite still collects and runs (see tests/_hypothesis_stub.py).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

import numpy as np
import pytest

# Named worker threads the offload stack may spin up: session pipeline
# workers ("offload-h2d", "offload-gradwrite", "offload-optim",
# "offload-optim-prefetch"), the Direct NVMe I/O pool ("direct-nvme"), and
# every store's lazy async executor ("<Engine>-aio").
_WORKER_PREFIXES = ("offload-", "direct-nvme")


def _worker_threads() -> collections.Counter:
    return collections.Counter(
        t.name for t in threading.enumerate()
        if t.name.startswith(_WORKER_PREFIXES) or "-aio" in t.name)


@pytest.fixture(autouse=True)
def worker_thread_leak_guard():
    """Suite-wide thread-leak guard: any test that leaves a named pipeline
    or I/O worker running has leaked a session, store, or SerialWorker.
    Replaces the ad-hoc per-test thread censuses that used to live in
    test_overlap_executor.py and test_nvme.py."""
    before = _worker_threads()
    yield
    leaked = _worker_threads() - before
    assert not leaked, (
        f"test leaked worker threads: {sorted(leaked.elements())} — close "
        f"every OffloadSession, TensorStore, and SerialWorker it opened")


def pytest_addoption(parser):
    parser.addoption(
        "--lock-witness", action="store_true", default=False,
        help="wrap threading.Lock/Condition in the dynamic lock-order "
             "witness (repro.core.lock_witness): record the acquisition "
             "graph across the whole run and fail the first test whose "
             "execution completes a lock-order cycle")


def pytest_configure(config):
    if config.getoption("--lock-witness"):
        # Install before any test module imports the offload stack so
        # every lock the pipeline creates is witnessed.  (Locks created
        # during this import itself — e.g. the module-level
        # GLOBAL_TRACKER — predate the swap and are invisible.)
        from repro.core import lock_witness
        lock_witness.install()


def pytest_unconfigure(config):
    if config.getoption("--lock-witness"):
        from repro.core import lock_witness
        lock_witness.uninstall()


@pytest.fixture(autouse=True)
def lock_order_witness(request):
    """With ``--lock-witness``: check the accumulated acquisition graph
    after every test.  Edges accumulate across tests on purpose — an
    inversion whose two halves run in *different* tests is still a real
    deadlock in any process that exercises both paths."""
    if not request.config.getoption("--lock-witness"):
        yield
        return
    from repro.core import lock_witness
    yield
    lock_witness.check()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_store_root(tmp_path):
    return str(tmp_path / "store")
