import os

# Smoke tests and benches must see exactly ONE device; the 512-device flag
# belongs to the dry-run process only (see launch/dryrun.py).
assert "--xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run XLA_FLAGS globally"

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tmp_store_root(tmp_path):
    return str(tmp_path / "store")
