"""OffloadPolicy: registry round-trips, builder chaining, validation."""

import pytest

from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        DirectNVMeEngine, FilesystemEngine, OffloadPolicy,
                        memascend_policy, policy_names)
from repro.core.optimizer import AdamConfig


def test_registry_names():
    names = policy_names()
    assert {"memascend", "zero-infinity", "memascend-bf16"} <= set(names)
    assert OffloadPolicy.names() == names


def test_preset_round_trip(tmp_path):
    built = (OffloadPolicy.preset("memascend")
             .with_store(str(tmp_path / "a")).with_adam(lr=1e-3).build())
    direct = memascend_policy(str(tmp_path / "b"), lr=1e-3)
    assert built.name == direct.name
    assert built.allocator_cls is direct.allocator_cls
    assert built.pool_cls is direct.pool_cls
    assert built.fused_overflow == direct.fused_overflow
    assert built.adam == direct.adam
    store = built.store_factory()
    assert isinstance(store, DirectNVMeEngine)
    store.close()


def test_preset_bf16_and_baseline(tmp_path):
    bf16 = (OffloadPolicy.preset("memascend-bf16")
            .with_store(str(tmp_path / "bf")).build())
    assert bf16.adam.state_dtype == "bfloat16"
    assert bf16.name == "memascend-bf16"   # registry name round-trips
    base = (OffloadPolicy.preset("zero-infinity")
            .with_store(str(tmp_path / "z")).build())
    store = base.store_factory()
    assert isinstance(store, FilesystemEngine)
    store.close()


def test_unknown_preset():
    with pytest.raises(KeyError, match="unknown offload policy"):
        OffloadPolicy.preset("warp-drive")


def test_builder_requires_store():
    with pytest.raises(ValueError, match="no store"):
        OffloadPolicy.preset("memascend").build()


def test_builder_store_exclusive(tmp_path):
    with pytest.raises(ValueError, match="exactly one"):
        OffloadPolicy.preset("memascend").with_store(
            str(tmp_path), factory=lambda: None)


def test_builder_store_kwargs_reach_preset_engine(tmp_path):
    p = (OffloadPolicy.preset("memascend")
         .with_store(str(tmp_path), n_devices=4).build())
    store = p.store_factory()
    assert isinstance(store, DirectNVMeEngine)
    assert store.n_devices == 4
    store.close()


def test_builder_unknown_store_kwarg_fails_at_build(tmp_path):
    # zero-infinity's factory funnels unknown kwargs into AdamConfig; the
    # builder must surface that as its own error, not a deep TypeError
    with pytest.raises(ValueError, match="zero-infinity.*rejected"):
        (OffloadPolicy.preset("zero-infinity")
         .with_store(str(tmp_path), fsync=False).build())


def test_builder_rejects_misrouted_options(tmp_path):
    # options must go through the method that names their component
    with pytest.raises(ValueError, match="non-Adam option"):
        OffloadPolicy.preset("memascend").with_adam(n_devices=4)
    with pytest.raises(ValueError, match="use with_adam"):
        OffloadPolicy.preset("memascend").with_store(str(tmp_path), lr=0.1)


def test_builder_store_kwargs_forbidden_with_factory():
    with pytest.raises(ValueError, match="only apply with"):
        OffloadPolicy.preset("memascend").with_store(
            factory=lambda: None, n_devices=4)


def test_builder_overrides(tmp_path):
    p = (OffloadPolicy.preset("memascend").with_store(str(tmp_path))
         .with_inflight_blocks(3).with_lookahead(2)
         .with_overrides(offload_checkpoints=False).build())
    assert p.inflight_blocks == 3 and p.lookahead == 2
    assert not p.offload_checkpoints


def test_validation_inflight_blocks(tmp_path):
    with pytest.raises(ValueError, match="inflight_blocks"):
        (OffloadPolicy.preset("memascend").with_store(str(tmp_path))
         .with_inflight_blocks(0).build())


def test_validation_lookahead_bounded(tmp_path):
    # lookahead beyond the pool's prefetch depth would oversubscribe slots
    with pytest.raises(ValueError, match="lookahead"):
        (OffloadPolicy.preset("memascend").with_store(str(tmp_path))
         .with_lookahead(5).build())


def test_validation_classes_and_dtypes(tmp_path):
    good = memascend_policy(str(tmp_path))
    with pytest.raises(ValueError, match="allocator_cls"):
        good.replace(allocator_cls=dict)
    with pytest.raises(ValueError, match="pool_cls"):
        good.replace(pool_cls=int)
    with pytest.raises(ValueError, match="state_dtype"):
        good.replace(adam=AdamConfig(state_dtype="float8"))
    with pytest.raises(ValueError, match="compute_dtype"):
        good.replace(adam=AdamConfig(compute_dtype="int4"))
    # replace() with valid changes keeps the rest intact
    deeper = good.replace(inflight_blocks=4, lookahead=4)
    assert deeper.pool_cls is AdaptiveBufferPool
    assert deeper.allocator_cls is AlignmentFreeAllocator
