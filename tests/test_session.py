"""OffloadSession: lifecycle, error-path drain, lookahead pipelining, and
the weight-streamed decode (serve) path."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import (DecodeSpec, OffloadPolicy, OffloadSession,
                        memascend_policy)
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset
from repro.serve import OffloadedDecoder

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _batch(batch=4, seq=32, seed=1):
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=seed), batch=batch,
                    seq_len=seq)
    return dl.next_batch()


class _RecordingSwapper:
    """Delegating wrapper that logs (op, key) event order."""

    def __init__(self, inner):
        self._inner = inner
        self.events = []

    def prefetch(self, key, dtype, shape, **kw):
        self.events.append(("prefetch", key))
        return self._inner.prefetch(key, dtype, shape, **kw)

    def get(self, key, dtype, shape, **kw):
        self.events.append(("get", key))
        return self._inner.get(key, dtype, shape, **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def first(self, op, unit):
        return next(i for i, (o, k) in enumerate(self.events)
                    if o == op and k.startswith(unit + "/"))


# -- lifecycle ---------------------------------------------------------------

def test_context_manager_frees_everything(tmp_store_root):
    b = _batch()
    with OffloadSession(_model(), memascend_policy(tmp_store_root,
                                                   lr=1e-3)) as s:
        m = s.train_step(b["tokens"], b["labels"])
        assert np.isfinite(m["loss"])
        tracker = s.tracker
        assert tracker.component("pinned").live_allocated > 0
    # __exit__ returned the pool arena, the flat buffer, and every staging
    # byte; the swapper has nothing in flight.
    tracker.assert_quiescent()
    assert len(s.swapper._inflight) == 0
    s.close()   # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        s.train_step(b["tokens"], b["labels"])


def test_error_path_drains_inflight_and_checkpoints(tmp_store_root):
    b = _batch()
    s = OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3))
    calls = {"n": 0}
    real_block = s._jit_block

    def flaky_block(params, h):
        calls["n"] += 1
        if calls["n"] == 2:     # fail mid-forward, prefetches in flight
            raise RuntimeError("injected block failure")
        return real_block(params, h)

    s._jit_block = flaky_block
    with pytest.raises(RuntimeError, match="injected"):
        s.train_step(b["tokens"], b["labels"])
    # drain ran: no outstanding reads, every pool slot returned, and the
    # host-held activation checkpoints were freed.
    assert len(s.swapper._inflight) == 0
    assert s.pool.in_use_payload == 0
    assert s.tracker.component(
        "activation_checkpoints").live_allocated == 0
    s.close()
    s.tracker.assert_quiescent()


def test_close_runs_every_step_despite_failure(tmp_store_root):
    """A failure mid-close (e.g. an interrupt re-raised out of drain) must
    not skip the remaining cleanup steps: the store still closes and the
    original failure propagates."""
    s = OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3))
    s.swapper.drain = lambda: (_ for _ in ()).throw(
        KeyboardInterrupt("injected"))
    store_closed = []
    real_close = s.store.close
    def closing():
        store_closed.append(True)
        real_close()
    s.store.close = closing
    with pytest.raises(KeyboardInterrupt, match="injected"):
        s.close()
    assert store_closed and s.pool.in_use_payload == 0
    s.tracker.assert_quiescent()
    s.close()   # idempotent after a failed close


def test_init_failure_releases_store_and_arena(tmp_store_root):
    """A constructor failure after the store opened (e.g. disk-full while
    seeding optimizer state) must release everything already acquired —
    __enter__ never runs, so nobody else can close()."""
    from repro.core import FilesystemEngine

    class _FailingStore:
        def __init__(self, inner):
            self._inner = inner
            self.closed = False

        def write(self, *a, **kw):
            raise IOError("injected disk full")

        def close(self):
            self.closed = True
            self._inner.close()

        def __getattr__(self, name):
            return getattr(self._inner, name)

    failing = _FailingStore(FilesystemEngine(tmp_store_root))
    policy = (OffloadPolicy.preset("memascend")
              .with_store(factory=lambda: failing).with_adam(lr=1e-3).build())
    from repro.core.memory_tracker import MemoryTracker
    tracker = MemoryTracker()
    with pytest.raises(IOError, match="injected"):
        OffloadSession(_model(), policy, tracker=tracker)
    assert failing.closed
    tracker.assert_quiescent()   # pinned arena returned


def test_growth_step_unscales_with_pre_growth_scale(tmp_store_root):
    """On a loss-scale growth step the grads in the flat buffer carry the
    OLD scale; the optimizer must unscale with that, not the doubled
    post-update scale (regression: updates were 2x too small every
    growth_interval steps)."""
    policy = (OffloadPolicy.preset("memascend").with_store(tmp_store_root)
              .with_adam(lr=1e-3, compute_dtype="float16").build())
    b = _batch()
    with OffloadSession(_model(), policy) as s:
        s.scaler.scale = 1024.0
        s.scaler.growth_interval = 1    # next good step doubles the scale
        seen = {}
        real_compute = s.optimizer.compute_subgroup
        def recording_compute(staged, grad):
            seen[staged.key] = np.asarray(grad, dtype=np.float32)
            return real_compute(staged, grad)
        s.optimizer.compute_subgroup = recording_compute
        m = s.train_step(b["tokens"], b["labels"])
        s.synchronize()   # full overlap: Adam streams on the worker
        assert m["applied"] and s.scaler.scale == 2048.0
        key = "embed/embed"
        off, size, shape = s._flat_offsets[key]
        scaled = s.flat[off:off + size].reshape(shape)
        np.testing.assert_allclose(seen[key], scaled / 1024.0, rtol=1e-6)


# -- lookahead pipelining ----------------------------------------------------

def test_lookahead_prefetches_next_block_before_current_get(tmp_store_root):
    # overlap="sync" keeps every swapper event on the executor thread so
    # the interleaving is deterministic; the window logic under test is
    # identical in the overlap modes (covered by test_overlap_executor.py,
    # which asserts outcomes rather than cross-thread event order).
    policy = (OffloadPolicy.preset("memascend").with_store(tmp_store_root)
              .with_lookahead(2).with_overlap("sync").build())
    b = _batch()
    with OffloadSession(_model(), policy) as s:
        rec = _RecordingSwapper(s.swapper)
        s.swapper = rec
        s.eval_loss(b["tokens"], b["labels"])
    # block_001's SSD read was issued before we blocked on block_000
    assert rec.first("prefetch", "block_001") < rec.first("get", "block_000")


def test_lookahead_one_is_synchronous(tmp_store_root):
    policy = (OffloadPolicy.preset("memascend").with_store(tmp_store_root)
              .with_lookahead(1).with_overlap("sync").build())
    b = _batch()
    with OffloadSession(_model(), policy) as s:
        assert s.lookahead == 1
        rec = _RecordingSwapper(s.swapper)
        s.swapper = rec
        s.eval_loss(b["tokens"], b["labels"])
    # no cross-unit overlap: block_001 is only touched after block_000's get
    assert rec.first("prefetch", "block_001") > rec.first("get", "block_000")


def test_deep_lookahead_still_prefetches_backward_refetch(tmp_store_root):
    """Lookahead deep enough to reach a unit's backward re-fetch while its
    forward ticket is still in flight must not alias onto that ticket:
    every get() should find a genuinely issued read (regression — the
    window used to advance past the duplicate, degrading the backward
    fetch to a synchronous read)."""
    policy = (OffloadPolicy.preset("memascend").with_store(tmp_store_root)
              .with_inflight_blocks(3).with_lookahead(3).build())
    b = _batch()
    with OffloadSession(_model(), policy) as s:
        s.train_step(b["tokens"], b["labels"])
        assert s.swapper.stats.sync_fallbacks == 0


def test_train_metrics_report_fetch_wait(tmp_store_root):
    b = _batch()
    with OffloadSession(_model(), memascend_policy(tmp_store_root,
                                                   lr=1e-3)) as s:
        m = s.train_step(b["tokens"], b["labels"])
    assert m["fetch_wait_s"] >= 0.0
    assert m["prefetch_hits"] > 0    # lookahead had reads in flight


# -- serve mode + offloaded decode ------------------------------------------

def test_serve_mode_streams_weights_only(tmp_store_root):
    model = _model()
    policy = memascend_policy(tmp_store_root, lr=1e-3)
    with OffloadSession(model, policy, mode="serve") as s:
        assert s.flat is None and s.optimizer is None
        # only .compute tensors were written — no master/m/v on the store
        keys = s.store.keys()
        assert keys and all(k.endswith(".compute") for k in keys)
        tokens = _batch(batch=2, seq=8)["tokens"]
        logits = s.decode_logits(tokens)
        assert logits.shape == (2, 8, CFG.vocab)
        with pytest.raises(RuntimeError, match="train-mode"):
            s.train_step(tokens, tokens)
        with pytest.raises(RuntimeError, match="master"):
            s.master_param("embed", "embed")
    s.tracker.assert_quiescent()


def test_decode_matches_train_session_weights(tmp_store_root):
    """Serve-mode registration feeds the same compute weights the train
    session streams: identical logits through the same decode plan."""
    tokens = _batch(batch=2, seq=8)["tokens"]
    with OffloadSession(_model(), memascend_policy(
            tmp_store_root + "t", lr=1e-3)) as st:
        logits_train = st.decode_logits(tokens)
    with OffloadSession(_model(), memascend_policy(
            tmp_store_root + "s", lr=1e-3), mode="serve") as ss:
        logits_serve = ss.decode_logits(tokens)
    np.testing.assert_array_equal(logits_train, logits_serve)


def test_offloaded_decoder_greedy_generate(tmp_store_root):
    model = _model()
    policy = memascend_policy(tmp_store_root, lr=1e-3)
    prompts = np.asarray(_batch(batch=2, seq=6)["tokens"])
    with OffloadedDecoder(model, policy) as dec:
        gen = dec.generate(prompts, 3)
        assert gen.shape == (2, 3)
        # greedy decode is deterministic: replay step-by-step
        ctx = prompts
        for t in range(3):
            expect = np.argmax(dec.step_logits(ctx), axis=-1)
            np.testing.assert_array_equal(gen[:, t], expect)
            ctx = np.concatenate([ctx, expect[:, None].astype(np.int32)],
                                 axis=1)
        assert dec.fetch_stats["n_gets"] > 0
    dec.session.tracker.assert_quiescent()


# -- expert paging equivalence (paged MoE) -----------------------------------

MOE_CFG = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                      moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32))


def _moe_session(root, mode, overlap, **kw):
    model = make_offloadable_lm(MOE_CFG, jax.random.PRNGKey(0),
                                expert_paging=mode)
    policy = memascend_policy(root, lr=1e-2).replace(
        expert_paging=mode, expert_page_slots=8, overlap=overlap)
    return OffloadSession(model, policy, **kw)


def _moe_batch():
    rng = np.random.default_rng(7)
    return (rng.integers(0, MOE_CFG.vocab, (2, 16)).astype(np.int32),
            rng.integers(0, MOE_CFG.vocab, (2, 16)).astype(np.int32))


@pytest.mark.parametrize("overlap", ["sync", "h2d", "full"])
def test_moe_routed_paging_losses_bit_identical(tmp_store_root, overlap):
    """Routed-only expert residency vs staging every expert: the losses
    must be BIT-identical under every overlap mode — unrouted experts'
    stack rows are zero and never read by the combine, and both modes run
    the identical jitted program — while the routed arm must move strictly
    fewer expert bytes out of the page cache."""
    tokens, labels = _moe_batch()
    out = {}
    for mode in ("all", "routed"):
        with _moe_session(tmp_store_root + mode, mode, overlap) as s:
            out[mode] = ([s.train_step(tokens, labels)["loss"]
                          for _ in range(3)], s.overlap_snapshot())
        s.tracker.assert_quiescent()
    assert out["all"][0] == out["routed"][0], (
        f"{overlap}: routed-paging drifted from all-resident: "
        f"{out['routed'][0]} vs {out['all'][0]}")
    assert all(np.isfinite(x) for x in out["all"][0])
    routed_b = out["routed"][1]["expert_fetch_bytes"]
    all_b = out["all"][1]["expert_fetch_bytes"]
    assert 0 < routed_b < all_b


def test_moe_routed_decode_tokens_identical(tmp_store_root):
    """Greedy decode through the paged serve path (prefill + cached
    steps): token-identical between routed and all-resident residency."""
    tokens, _ = _moe_batch()
    toks = {}
    for mode in ("all", "routed"):
        with _moe_session(tmp_store_root + mode, mode, "full",
                          decode=DecodeSpec(batch=2, max_seq=64)) as s:
            s.train_step(tokens, tokens)
            kv = s.open_kv_cache()
            try:
                logits = s.prefill(kv, tokens[:, :8])
                seq = [np.argmax(logits, axis=-1).astype(np.int32)]
                for _ in range(6):
                    logits = s.decode_step(kv, seq[-1][:, None])
                    seq.append(np.argmax(logits, axis=-1).astype(np.int32))
            finally:
                kv.close()
            toks[mode] = np.stack(seq, axis=1)
        s.tracker.assert_quiescent()
    np.testing.assert_array_equal(toks["all"], toks["routed"])


def test_moe_prestage_hits_after_first_step(tmp_store_root):
    """Step 2+ prestages the previous step's routed set inside the fetch
    window; with identical batches and lr=0 (weights frozen, routing
    repeats exactly) every executor expert-stage get must be a hit, and
    fetch waits/refills must be accounted."""
    tokens, labels = _moe_batch()
    model = make_offloadable_lm(MOE_CFG, jax.random.PRNGKey(0),
                                expert_paging="routed")
    policy = memascend_policy(tmp_store_root, lr=0.0).replace(
        expert_paging="routed", expert_page_slots=8, overlap="full")
    with OffloadSession(model, policy) as s:
        for _ in range(3):
            m = s.train_step(tokens, labels)
        snap = s.overlap_snapshot()
        assert snap["expert_stage_gets"] > 0
        assert snap["expert_stage_hits"] == snap["expert_stage_gets"]
        assert "expert_fetch_wait_s" in m
        stats = s.expert_cache_stats()
        assert stats["refills"] > 0
    s.tracker.assert_quiescent()
