"""Overflow check: chained baseline vs MemAscend's fused pass (§III-C/IV-D)."""

import numpy as np
import ml_dtypes
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (MemoryTracker, baseline_overflow_check,
                        fused_overflow_check)
from repro.core.overflow import (baseline_overflow_check_jnp,
                                 fused_overflow_check_jnp)

BF16 = np.dtype(ml_dtypes.bfloat16)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, BF16])
@pytest.mark.parametrize("bad", [None, np.inf, -np.inf, np.nan])
def test_equivalence_all_dtypes(dtype, bad, rng):
    g = rng.standard_normal(10_000).astype(dtype)
    if bad is not None:
        g[rng.integers(0, g.size)] = bad
    expected = bad is not None
    t = MemoryTracker()
    assert fused_overflow_check(g, tracker=t) == expected
    if dtype == np.float32:
        assert baseline_overflow_check(g, tracker=t) == expected


def test_baseline_peak_is_2_25x(rng):
    """The paper's Fig. 3: chained check peaks at 2.25x the flat buffer."""
    g = rng.standard_normal(1 << 20).astype(np.float32)
    t = MemoryTracker()
    baseline_overflow_check(g, tracker=t)
    extra = t.component("overflow_tmp").peak_allocated
    assert extra == pytest.approx(1.25 * g.nbytes)   # +abs(1.0x) +mask(.25x)


def test_fused_peak_is_negligible(rng):
    g = rng.standard_normal(1 << 22).astype(np.float32)
    t = MemoryTracker()
    fused_overflow_check(g, tracker=t)
    extra = t.component("overflow_tmp").peak_allocated
    assert extra <= 4 * (1 << 20)    # one chunk, ~4 MiB vs 16 MiB payload


def test_fused_latency_beats_baseline(rng):
    import time
    g = rng.standard_normal(1 << 22).astype(np.float32)
    t = MemoryTracker()
    t0 = time.perf_counter(); baseline_overflow_check(g, tracker=t)
    base = time.perf_counter() - t0
    t0 = time.perf_counter(); fused_overflow_check(g, tracker=t)
    fused = time.perf_counter() - t0
    # soft bound: fused must not be slower; paper reports ~97% reduction
    assert fused < base * 1.5


class _CountingNumpy:
    """Module-local numpy proxy: counts ``np.any`` calls made by the
    overflow module only (a global ``np.any`` patch would race worker
    threads of neighbouring machinery)."""

    def __init__(self):
        self.n_any = 0

    def __getattr__(self, name):
        return getattr(np, name)

    def any(self, *args, **kwargs):
        self.n_any += 1
        return np.any(*args, **kwargs)


def test_early_exit_on_first_chunk(rng, monkeypatch):
    """Early exit is asserted structurally (chunks visited), not by
    wall-clock — the old timing comparison flaked under scheduler noise."""
    from repro.core import overflow as ovf
    g = rng.standard_normal(1 << 22).astype(np.float32)
    proxy = _CountingNumpy()
    monkeypatch.setattr(ovf, "np", proxy)
    g[17] = np.inf
    assert fused_overflow_check(g)
    early_chunks = proxy.n_any
    proxy.n_any = 0
    g[17] = 0.0
    assert not fused_overflow_check(g)
    full_chunks = proxy.n_any
    assert early_chunks == 1            # stopped inside the first chunk
    assert full_chunks == (1 << 22) // (1 << 20)   # scanned all 4


def test_jnp_variants_agree(rng):
    import jax.numpy as jnp
    g = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    assert not bool(fused_overflow_check_jnp(g))
    assert not bool(baseline_overflow_check_jnp(g))
    g = g.at[100].set(jnp.nan)
    assert bool(fused_overflow_check_jnp(g))
    assert bool(baseline_overflow_check_jnp(g))
    gb = jnp.asarray(rng.standard_normal(4096), jnp.bfloat16).at[5].set(
        jnp.inf)
    assert bool(fused_overflow_check_jnp(gb))


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=100_000),
       st.sampled_from(["none", "inf", "-inf", "nan"]),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_fused_matches_numpy_semantics(n, kind, seed):
    rng = np.random.default_rng(seed)
    g = rng.standard_normal(n).astype(np.float32) * 1e3
    if kind != "none":
        g[rng.integers(0, n)] = {"inf": np.inf, "-inf": -np.inf,
                                 "nan": np.nan}[kind]
    expected = bool(np.isinf(g).any() or np.isnan(g).any())
    assert fused_overflow_check(g) == expected


def test_subnormals_and_extremes_dont_trigger():
    g = np.array([0.0, -0.0, np.finfo(np.float32).max,
                  np.finfo(np.float32).min, np.finfo(np.float32).tiny,
                  1e-45], np.float32)   # 1e-45 = subnormal
    assert not fused_overflow_check(g)
