"""Parameter buffer pools: fragmentation of fixed vs adaptive (§III-A/IV-B)."""

import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        FixedBufferPool, MemoryTracker, PoolCensus,
                        ShapeClass)
from repro.configs import ARCHS, PAPER_MODELS


def _alloc(t=None):
    return AlignmentFreeAllocator(tracker=t or MemoryTracker(),
                                  component="pool")


CENSUS = PoolCensus((
    ShapeClass("embed", 1_000_000, 0, 2),
    ShapeClass("ffn", 100_000, 3),
    ShapeClass("kv", 4_000, 2),
    ShapeClass("qo", 40_000, 2),
), inflight_blocks=2)


def test_fixed_pool_sized_by_largest_tensor():
    pool = FixedBufferPool(CENSUS, _alloc())
    assert pool.pool_bytes == 1_000_000 * CENSUS.total_slots
    buf = pool.acquire("kv", 4_000)
    assert buf.capacity == 1_000_000      # the fragmentation mechanism
    buf.release()
    pool.close()


def test_adaptive_pool_sized_by_class():
    pool = AdaptiveBufferPool(CENSUS, _alloc())
    expected = (2 * 1_000_000 + 6 * 100_000 + 4 * 4_000 + 4 * 40_000)
    assert pool.pool_bytes == expected
    buf = pool.acquire("kv", 4_000)
    assert buf.capacity == 4_000
    buf.release()
    pool.close()


def test_adaptive_rejects_unknown_class_and_oversize():
    pool = AdaptiveBufferPool(CENSUS, _alloc())
    with pytest.raises(KeyError):
        pool.acquire("nope", 10)
    with pytest.raises(ValueError, match="exceeds slot"):
        pool.acquire("kv", 5_000)
    pool.close()


def test_fragmentation_metric():
    pool = FixedBufferPool(CENSUS, _alloc())
    bufs = [pool.acquire("ffn", 100_000) for _ in range(3)]
    for b in bufs:
        b.release()
    # peak payload 300k vs pool 10M
    assert pool.fragmentation() > 0.9
    pool.close()


def test_same_tag_double_checkout_keeps_both_records():
    """Two concurrent checkouts under one tag (a unit's forward ticket
    still staging while its backward re-fetch is issued inside a deep
    lookahead window): the live-metadata hashtable must track both, and
    releasing the first must drop *that* buffer's record, not the tag
    (regression: a plain {tag: buf} map lost the first record and the
    first release popped the wrong one)."""
    pool = AdaptiveBufferPool(CENSUS, _alloc())
    a = pool.acquire("ffn", 90_000, tag="block_0/w")
    b = pool.acquire("ffn", 80_000, tag="block_0/w")
    assert pool._live["block_0/w"] == [a, b]
    a.release()
    assert pool._live["block_0/w"] == [b]     # b's record survived
    assert pool.in_use_payload == 80_000      # accounting tracked per-buf
    b.release()
    assert "block_0/w" not in pool._live
    assert pool.in_use_payload == 0
    # all slots back: a third acquire of every slot succeeds immediately
    bufs = [pool.acquire("ffn", 100_000, timeout=0.5) for _ in range(6)]
    for buf in bufs:
        buf.release()
    pool.close()


def test_blocking_acquire_backpressure():
    census = PoolCensus((ShapeClass("ffn", 100, 1),), inflight_blocks=1)
    pool = AdaptiveBufferPool(census, _alloc())
    b1 = pool.acquire("ffn", 100)

    def releaser():
        time.sleep(0.1)
        b1.release()

    threading.Thread(target=releaser).start()
    b2 = pool.acquire("ffn", 50, timeout=5.0)   # blocks until release
    assert b2.capacity == 100
    b2.release()
    pool.close()


def test_exhaustion_times_out():
    census = PoolCensus((ShapeClass("ffn", 100, 1),), inflight_blocks=1)
    pool = AdaptiveBufferPool(census, _alloc())
    b1 = pool.acquire("ffn", 100)
    with pytest.raises(TimeoutError):
        pool.acquire("ffn", 100, timeout=0.05)
    b1.release()
    pool.close()


def test_numpy_backed_slots_are_disjoint():
    t = MemoryTracker()
    alloc = AlignmentFreeAllocator(tracker=t, component="pool",
                                   backing="numpy")
    pool = AdaptiveBufferPool(CENSUS, alloc)
    b1 = pool.acquire("ffn", 64)
    b2 = pool.acquire("ffn", 64)
    v1, v2 = b1.view(np.uint8, (64,)), b2.view(np.uint8, (64,))
    v1[:] = 1
    v2[:] = 2
    assert v1[0] == 1 and v2[0] == 2
    b1.release(); b2.release()
    pool.close()


@pytest.mark.parametrize("arch", list(ARCHS))
def test_arch_census_adaptive_saves(arch):
    """Adaptive pool never exceeds fixed pool; big win on real censuses."""
    census = ARCHS[arch].pool_census()
    fixed = FixedBufferPool(census, _alloc())
    adaptive = AdaptiveBufferPool(census, _alloc())
    assert adaptive.pool_bytes <= fixed.pool_bytes
    fixed.close(); adaptive.close()


def test_paper_fragmentation_magnitude():
    """Order-of-magnitude check against the paper: ~70% fragmentation for a
    Llama-3-8B-class census under the fixed pool."""
    census = PAPER_MODELS["llama3.1-8b"].pool_census()
    fixed = FixedBufferPool(census, _alloc())
    adaptive = AdaptiveBufferPool(census, _alloc())
    saving = 1 - adaptive.pool_bytes / fixed.pool_bytes
    assert saving > 0.5, f"expected >50% pool saving, got {saving:.1%}"
    fixed.close(); adaptive.close()


@settings(max_examples=25, deadline=None)
@given(st.lists(
    st.tuples(st.integers(min_value=1, max_value=1 << 20),   # nbytes
              st.integers(min_value=0, max_value=4),          # per_block
              st.integers(min_value=0, max_value=2)),         # standalone
    min_size=1, max_size=6))
def test_pool_size_property(classes):
    if not any(pb + sa > 0 for _, pb, sa in classes):
        classes = classes + [(64, 1, 0)]
    census = PoolCensus(tuple(
        ShapeClass(f"c{i}", n, pb, sa)
        for i, (n, pb, sa) in enumerate(classes)), inflight_blocks=2)
    fixed = FixedBufferPool(census, _alloc())
    adaptive = AdaptiveBufferPool(census, _alloc())
    # invariant: adaptive <= fixed; both hold every slot
    assert adaptive.pool_bytes <= fixed.pool_bytes
    assert sum(adaptive._total_slots.values()) == census.total_slots
    fixed.close(); adaptive.close()
