"""Parameter swapper: prefetch pipeline over the buffer pool."""

import numpy as np
import pytest

from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        DirectNVMeEngine, MemoryTracker, ParameterSwapper,
                        PoolCensus, ShapeClass)


@pytest.fixture
def setup(tmp_store_root, rng):
    store = DirectNVMeEngine(tmp_store_root, n_devices=2,
                             device_capacity=1 << 24)
    census = PoolCensus((ShapeClass("w", 4096 * 4, 2),), inflight_blocks=2)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(), component="pool",
                                   backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    tensors = {f"t{i}": rng.standard_normal(4096).astype(np.float32)
               for i in range(6)}
    for k, v in tensors.items():
        store.write(k, v)
    swapper = ParameterSwapper(store, pool,
                               class_of={k: "w" for k in tensors})
    yield store, pool, swapper, tensors
    swapper.drain()
    pool.close()
    store.close()


def test_prefetch_then_get(setup):
    store, pool, swapper, tensors = setup
    swapper.prefetch("t0", np.float32, (4096,))
    ticket = swapper.get("t0", np.float32, (4096,))
    np.testing.assert_array_equal(ticket.buf.view(np.float32, (4096,)),
                                  tensors["t0"])
    ticket.release()


def test_get_without_prefetch(setup):
    store, pool, swapper, tensors = setup
    ticket = swapper.get("t3", np.float32, (4096,))
    np.testing.assert_array_equal(ticket.buf.view(np.float32, (4096,)),
                                  tensors["t3"])
    ticket.release()


def test_prefetch_idempotent(setup):
    store, pool, swapper, tensors = setup
    a = swapper.prefetch("t1", np.float32, (4096,))
    b = swapper.prefetch("t1", np.float32, (4096,))
    assert a is b
    t = swapper.get("t1", np.float32, (4096,))
    t.release()


def test_get_releases_slot_when_read_fails(setup):
    """A read that fails after get() popped the ticket is invisible to
    drain(); get() itself must return the pool slot (regression: the slot
    leaked for the session lifetime)."""
    store, pool, swapper, tensors = setup
    with pytest.raises(KeyError, match="not in location"):
        swapper.get("nope", np.float32, (4096,), class_name="w")
    assert pool.in_use_payload == 0


def test_stats_hit_fallback_discrimination(setup):
    """prefetch_hits counts reads already complete at get() time; a get
    with nothing in flight is a sync_fallback — the two must discriminate
    pipelined from synchronous access."""
    store, pool, swapper, tensors = setup
    t = swapper.prefetch("t0", np.float32, (4096,))
    t.future.result()                      # read fully landed before get
    swapper.get("t0", np.float32, (4096,)).release()
    assert swapper.stats.prefetch_hits == 1
    assert swapper.stats.sync_fallbacks == 0
    swapper.get("t1", np.float32, (4096,)).release()   # never prefetched
    assert swapper.stats.prefetch_hits == 1
    assert swapper.stats.sync_fallbacks == 1


def test_claim_split_get_records_stats_from_waiter(setup):
    """The H2D worker's split get: claim() takes ticket ownership without
    blocking; the waiter reports through record_get() and the ledger ends
    identical to a plain get()."""
    store, pool, swapper, tensors = setup
    swapper.prefetch("t2", np.float32, (4096,))
    ticket, hit, fallback = swapper.claim("t2", np.float32, (4096,))
    assert not fallback
    assert not swapper.in_flight("t2")       # ownership moved to the caller
    view = ticket.wait()
    np.testing.assert_array_equal(view, tensors["t2"])
    swapper.record_get(hit=hit, fallback=fallback, wait_seconds=0.25)
    ticket.release()
    st = swapper.stats
    assert st.n_gets == 1 and st.sync_fallbacks == 0
    assert st.wait_seconds == 0.25
    # claim with nothing in flight = the sync-fallback path, same as get()
    ticket, hit, fallback = swapper.claim("t4", np.float32, (4096,))
    assert fallback and not hit
    ticket.wait()
    swapper.record_get(hit=hit, fallback=fallback, wait_seconds=0.0)
    ticket.release()
    assert swapper.stats.sync_fallbacks == 1


def test_drain_releases_all_slots_despite_failed_read(setup):
    """drain() must return every in-flight slot even when one read failed —
    it runs on error paths where stopping early would leak the rest."""
    store, pool, swapper, tensors = setup
    swapper.prefetch("nope", np.float32, (4096,), class_name="w")
    swapper.prefetch("t0", np.float32, (4096,))
    swapper.drain()      # must not raise, must not stop at the failed read
    assert pool.in_use_payload == 0


def test_pipeline_over_all_tensors(setup):
    """Stream 6 tensors through a 4-slot pool with prefetch depth 2."""
    store, pool, swapper, tensors = setup
    keys = list(tensors)
    swapper.prefetch(keys[0], np.float32, (4096,))
    for i, k in enumerate(keys):
        if i + 1 < len(keys):
            swapper.prefetch(keys[i + 1], np.float32, (4096,))
        ticket = swapper.get(k, np.float32, (4096,))
        np.testing.assert_array_equal(
            ticket.buf.view(np.float32, (4096,)), tensors[k])
        ticket.release()
    assert pool.in_use_payload == 0


def test_assert_not_in_flight_guards_store_writers(tmp_store_root, rng):
    """The Adam commit's compute-weight write path uses this guard: a
    write over a key with an unconsumed prefetched read must be refused
    (the pread could race the pwrite and serve half-old bytes)."""
    store = DirectNVMeEngine(tmp_store_root, n_devices=1,
                             device_capacity=1 << 24)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pool", backing="numpy")
    census = PoolCensus((ShapeClass("w", 1024 * 4, 2),), inflight_blocks=2)
    pool = AdaptiveBufferPool(census, alloc)
    x = rng.standard_normal(1024).astype(np.float32)
    store.write("k", x)
    sw = ParameterSwapper(store, pool, class_of={"k": "w"})
    sw.assert_not_in_flight("k")          # nothing issued: fine
    sw.prefetch("k", np.float32, (1024,))
    with pytest.raises(RuntimeError, match="in flight"):
        sw.assert_not_in_flight("k")
    t = sw.get("k", np.float32, (1024,))  # consume the read
    t.release()
    sw.assert_not_in_flight("k")          # consumed: fine again
    sw.drain()
    pool.close()
    store.close()


def test_write_guard_covers_claimed_but_still_reading_window(
        tmp_store_root, rng):
    """claim() pops the ticket out of _inflight while the pread may still
    be copying — the guard must keep firing until the read future
    completes (it follows the future, not the ticket)."""
    import threading
    store = DirectNVMeEngine(tmp_store_root, n_devices=1,
                             device_capacity=1 << 24)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pool", backing="numpy")
    census = PoolCensus((ShapeClass("w", 1024 * 4, 2),), inflight_blocks=2)
    pool = AdaptiveBufferPool(census, alloc)
    x = rng.standard_normal(1024).astype(np.float32)
    store.write("k", x)
    sw = ParameterSwapper(store, pool, class_of={"k": "w"})
    release_read = threading.Event()
    real_read = store.read

    def gated_read(key, out):
        release_read.wait(timeout=30)
        return real_read(key, out)

    store.read = gated_read
    ticket, _hit, _fb = sw.claim("k", np.float32, (1024,))
    assert len(sw._inflight) == 0          # claimed: ticket popped
    with pytest.raises(RuntimeError, match="in flight"):
        sw.assert_not_in_flight("k")       # ...but the pread still runs
    release_read.set()
    ticket.wait()
    sw.record_get(hit=False, fallback=True, wait_seconds=0.0)
    sw.assert_not_in_flight("k")           # read complete: write is safe
    ticket.release()
    sw.drain()
    pool.close()
    store.close()
