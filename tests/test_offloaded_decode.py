"""Cached offloaded decode: the spill-able KV cache, bucketed compile-once
stepping, token-identical equivalence with the uncached path, and the
validated token contract."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (ComputeOp, DecodeSpec, FetchOp, KVReadOp, KVWriteOp,
                        OffloadSession, PlanError, ReleaseOp, SpillableKVCache,
                        StreamPlan, memascend_policy)
from repro.core.buffer_pool import (KV_CLASS, AdaptiveBufferPool, PoolCensus,
                                    ShapeClass)
from repro.core.model_adapter import make_offloadable_lm
from repro.core.nvme import FilesystemEngine
from repro.core.pinned_alloc import AlignmentFreeAllocator
from repro.serve import OffloadedDecoder

CFG = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _prompts(batch=2, seq=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(3, CFG.vocab, size=(batch, seq), dtype=np.int32)


# -- equivalence with the uncached path ---------------------------------------

@pytest.mark.parametrize("spec_kw", [
    {},                        # every page resident
    {"resident_blocks": 2},    # layer-equivalent budget
    {"resident_pages": 2},     # minimum paged budget: heavy spill traffic
    {"page_tokens": 4, "resident_pages": 3},   # pages finer than buckets
    {"page_tokens": 32, "resident_blocks": 2},  # whole-layer pages (PR 2)
])
def test_cached_matches_uncached_argmax(tmp_store_root, spec_kw):
    """Cached decode (all-resident AND spilling, across page sizes and
    budgets) emits token-identical greedy output to the full-prefix
    re-run path on a fixed prompt set."""
    prompts = _prompts()
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8, **spec_kw)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "u",
                                                     lr=1e-3)) as dec:
        uncached = dec.generate(prompts, 8)
    np.testing.assert_array_equal(cached, uncached)


def test_use_cache_false_forces_uncached_path(tmp_store_root):
    prompts = _prompts()
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 4)
        uncached = dec.generate(prompts, 4, use_cache=False)
        np.testing.assert_array_equal(cached, uncached)
        assert dec.kv_stats is not None   # the cached run recorded stats


# -- bucketing: boundary crossings + compile-once ------------------------------

def test_bucket_boundary_crossing_stays_exact(tmp_store_root):
    """Generation crossing several time buckets (prompt pad, then two
    device-cache growths) matches the uncached path token for token."""
    prompts = _prompts(seq=3)
    spec = DecodeSpec(batch=2, max_seq=16, bucket=4)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 12)   # positions 3..14, buckets 4/8/12
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "u",
                                                     lr=1e-3)) as dec:
        uncached = dec.generate(prompts, 12)
    np.testing.assert_array_equal(cached, uncached)


def test_page_eviction_across_bucket_boundaries_stays_exact(tmp_store_root):
    """The page-table edge case: a minimum (2-slot) page budget forces
    evictions at every bucket/page boundary crossing while generation
    grows a fresh tail page — output must stay token-identical, and the
    paged spill traffic must be real (dirty writes AND free clean drops)."""
    prompts = _prompts(seq=3)
    spec = DecodeSpec(batch=2, max_seq=16, bucket=4, resident_pages=2)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 12)
        stats = dec.kv_stats
        assert stats["spills"] > 0 and stats["clean_drops"] > 0
        assert stats["refills"] > 0
        assert stats["spill_bytes"] < stats["refill_bytes"]  # clean drops
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "u",
                                                     lr=1e-3)) as dec:
        uncached = dec.generate(prompts, 12)
    np.testing.assert_array_equal(cached, uncached)


def test_second_sequence_over_reused_slots_stays_exact(tmp_store_root):
    """Page slots recycled across sequences (the 'one slot budget backs
    several short sequences' property): a second generate() with a
    different prompt set must not see the first sequence's K/V."""
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8, resident_pages=2)
    p1, p2 = _prompts(seed=0), _prompts(seed=7)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        dec.generate(p1, 6)                  # dirties + spills slots
        second = dec.generate(p2, 6)         # reuses the same slots
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "u",
                                                     lr=1e-3)) as dec:
        uncached = dec.generate(p2, 6)
    np.testing.assert_array_equal(second, uncached)


def test_sync_and_full_overlap_decode_token_identical(tmp_store_root):
    """The KVReadOp split changes WHERE the gather + H2D run (inline on
    the compute thread vs staged on the worker), never the data: sync and
    full overlap must emit identical tokens, and only full uses staging."""
    from repro.core import OffloadPolicy

    spec = DecodeSpec(batch=2, max_seq=32, bucket=8, resident_pages=2)
    prompts = _prompts()

    def policy(sub, overlap):
        return (OffloadPolicy.preset("memascend")
                .with_store(tmp_store_root + sub).with_adam(lr=1e-3)
                .with_overlap(overlap).build())

    with OffloadedDecoder(_model(), policy("s", "sync"), decode=spec) as dec:
        sync_tokens = dec.generate(prompts, 8)
        assert dec.kv_overlap_stats["kv_stage_gets"] == 0   # inline path
    with OffloadedDecoder(_model(), policy("f", "full"), decode=spec) as dec:
        full_tokens = dec.generate(prompts, 8)
        assert dec.kv_overlap_stats["kv_stage_gets"] == 21  # 3 blocks x 7
    np.testing.assert_array_equal(sync_tokens, full_tokens)


def test_kv_h2d_runs_on_staging_worker_under_full_overlap(tmp_store_root):
    """The PR-3 leg extended to serving: under overlap="full" every decode
    step's KV window gather (page refill waits + host copies) runs on the
    H2D staging worker, never the compute thread, and the KVReadOps are
    served from staged futures."""
    import threading

    from repro.core.kv_cache import SpillableKVCache as KVC

    spec = DecodeSpec(batch=2, max_seq=32, bucket=8, resident_pages=2)
    policy = memascend_policy(tmp_store_root, lr=1e-3)
    assert policy.overlap == "full"
    gather_threads = []
    real_gather = KVC.gather_window

    def probe(self, unit, extent):
        gather_threads.append(threading.current_thread().name)
        return real_gather(self, unit, extent)

    with OffloadedDecoder(_model(), policy, decode=spec) as dec:
        try:
            KVC.gather_window = probe
            dec.generate(_prompts(), 6)
        finally:
            KVC.gather_window = real_gather
        snap = dec.session.overlap_snapshot()
    assert gather_threads and set(gather_threads) == {"offload-h2d"}
    # every block_step KVRead was served from the staging pipeline:
    # 3 blocks x 5 cached steps
    assert snap["kv_stage_gets"] == len(gather_threads) == 15
    assert snap["kv_stage_wait_seconds"] >= 0.0


def test_zero_retraces_after_first_token_per_bucket(tmp_store_root):
    """Each bucket traces once: a warm repeat of the same generation —
    which revisits every bucket — compiles nothing new, and within one
    bucket every step after the first reuses the trace."""
    prompts = _prompts(seq=3)
    spec = DecodeSpec(batch=2, max_seq=32, bucket=4)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        s = dec.session
        dec.generate(prompts, 10)
        warm = s.decode_compiles()
        dec.generate(prompts, 10)
        assert s.decode_compiles() == warm

        # step-by-step inside one fresh bucket: only the crossing retraces
        kv = s.open_kv_cache()
        try:
            logits = s.prefill(kv, prompts)           # length 3, bucket 4
            nxt = np.argmax(logits, axis=-1).astype(np.int32)[:, None]
            s.decode_step(kv, nxt)                    # length 3 -> 4
            s.decode_step(kv, nxt)                    # crosses into bucket 8
            after_crossing = s.decode_compiles()
            for _ in range(3):                        # stays inside bucket 8
                s.decode_step(kv, nxt)
            assert s.decode_compiles() == after_crossing
        finally:
            kv.close()


# -- the paged KV cache itself -------------------------------------------------

def _kv_fixture(tmp_store_root, units=("a", "b", "c"), resident=2,
                page_shape=(2, 1, 2, 1, 2), max_seq=4):
    """Paged cache over a real pool + store: pages of 2 tokens, 4-token
    capacity (2 pages per unit)."""
    from repro.core import MemoryTracker
    nbytes = int(np.prod(page_shape)) * 4
    census = PoolCensus((ShapeClass("w", 64, per_block=1),),
                        inflight_blocks=1).with_kv(nbytes, resident)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pinned", backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    store = FilesystemEngine(tmp_store_root)
    kv = SpillableKVCache(list(units), page_shape, max_seq, np.float32,
                          pool, store, resident_limit=resident)
    return kv, pool, store


def test_kv_page_spill_refill_round_trip(tmp_store_root):
    """Data written before a page spill comes back bit-identical through
    the real store — token-exact at page granularity, and only dirty
    pages pay a write."""
    kv, pool, store = _kv_fixture(tmp_store_root)
    rng = np.random.default_rng(0)
    k = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
    v = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
    # 3 units x 2 pages through a 2-slot budget: spill-after-use evicts
    kv.write_prefill("a", k, v)                # dirties pages 0 and 1
    assert kv.stats.spills >= 1
    assert store.contains("kv/a/p0000") and store.contains("kv/a/p0001")
    kg, vg = kv.gather_window("a", 3)          # sync page refills from SSD
    np.testing.assert_array_equal(kg, k)
    np.testing.assert_array_equal(vg, v)
    assert kv.stats.refills == 2 and kv.stats.sync_refills == 2
    assert kv.stats.spill_bytes == kv.stats.spills * kv.page_nbytes
    # the refilled pages are clean now: re-evicting them writes nothing
    spills_before, drops_before = kv.stats.spills, kv.stats.clean_drops
    kv.write_prefill("b", k, v)                # pushes a's pages back out
    assert kv.stats.spills == spills_before + 2   # b's own dirty pages
    assert kv.stats.clean_drops == drops_before + 2   # a's clean pages
    kv.close()
    assert pool.in_use_payload == 0
    kv.close()   # idempotent


def test_kv_prefetch_window_overlaps_and_hits(tmp_store_root):
    kv, pool, _store = _kv_fixture(tmp_store_root, resident=3)
    z = np.zeros((1, 4, 1, 2), np.float32)
    for u in ("a", "b", "c"):
        kv.write_prefill(u, z, z)              # all spilled (keep budget 1)
    kv.prefetch_window("b", 2)                 # page 0 only
    kg, _vg = kv.gather_window("b", 2)
    assert kg.shape == (1, 2, 1, 2)
    assert kv.stats.prefetch_refills == 1
    kv.prefetch_window("b", 2)                 # resident: no-op
    assert kv.stats.prefetch_refills == 1
    kv.close()
    assert pool.in_use_payload == 0


def test_kv_cache_full_and_length_bounds(tmp_store_root):
    kv, _pool, _store = _kv_fixture(tmp_store_root, units=("a",), resident=2)
    kv.set_length(4)
    one = np.zeros((1, 1, 1, 2), np.float32)
    with pytest.raises(ValueError, match="full"):
        kv.append("a", one, one)
    with pytest.raises(ValueError, match="outside"):
        kv.set_length(5)
    kv.close()


def test_kv_resident_limit_validation(tmp_store_root):
    with pytest.raises(ValueError, match="resident_limit"):
        _kv_fixture(tmp_store_root, units=("a", "b", "c"), resident=1)


def test_kv_eviction_at_page_boundary_appends(tmp_store_root):
    """Appends crossing a page boundary materialize the fresh tail page,
    spill the full cold page, and a gather stitches both back exactly."""
    kv, pool, store = _kv_fixture(tmp_store_root, units=("a", "b"),
                                  resident=2)
    rng = np.random.default_rng(1)
    toks = [(rng.standard_normal((1, 1, 1, 2), dtype=np.float32),
             rng.standard_normal((1, 1, 1, 2), dtype=np.float32))
            for _ in range(3)]
    for _t, (k1, v1) in enumerate(toks):       # positions 0, 1, then 2:
        for u in ("a", "b"):                   # 2 -> second page of each
            kv.append(u, k1, v1)
        kv.advance()
    assert kv.length == 3
    assert store.contains("kv/a/p0000")        # cold page 0 spilled
    for u in ("a", "b"):
        kg, vg = kv.gather_window(u, 3)
        np.testing.assert_array_equal(
            kg, np.concatenate([k for k, _ in toks], axis=1))
        np.testing.assert_array_equal(
            vg, np.concatenate([v for _, v in toks], axis=1))
    kv.close()
    assert pool.in_use_payload == 0


def test_kv_slot_reuse_reads_zero_not_stale(tmp_store_root):
    """A page slot recycled from a previous sequence must read as zeros:
    stale K/V would poison the masked softmax (0 x NaN) and leak state
    across requests sharing the slot budget."""
    kv, pool, store = _kv_fixture(tmp_store_root, units=("a", "b"),
                                  resident=2)
    junk = np.full((1, 4, 1, 2), 7.5, np.float32)
    kv.write_prefill("a", junk, junk)
    kv.close()                                 # sequence 1 done, slots back
    kv2 = SpillableKVCache(["a", "b"], (2, 1, 2, 1, 2), 4, np.float32,
                           pool, store, resident_limit=2)
    one = np.ones((1, 1, 1, 2), np.float32)
    kv2.append("a", one, one)                  # page 0 reuses a slot
    kg, vg = kv2.gather_window("a", 2)
    np.testing.assert_array_equal(kg[:, 0], one[:, 0])
    assert (kg[:, 1:] == 0).all() and (vg[:, 1:] == 0).all()  # not 7.5
    kv2.close()
    assert pool.in_use_payload == 0


def test_kv_gather_zero_pads_unmaterialized_pages(tmp_store_root):
    """Windows can extend past the pages that exist (bucket > page size):
    the gather zero-fills them instead of wasting slots on garbage."""
    kv, _pool, _store = _kv_fixture(tmp_store_root, units=("a",),
                                    resident=2)
    one = np.ones((1, 1, 1, 2), np.float32)
    kv.append("a", one, one)                   # only page 0 materializes
    kg, vg = kv.gather_window("a", 4)          # full-capacity window
    assert kg.shape == (1, 4, 1, 2)
    assert (kg[:, 1:] == 0).all() and (vg[:, 1:] == 0).all()
    kv.close()


def test_h2d_copy_survives_source_buffer_reuse(tmp_store_root):
    """The H2D materialization barrier: a pool slot is reacquired (and
    overwritten by the next unit's SSD pread) the moment it is released,
    so ``_h2d_copy`` must have fully read the host view *before it
    returns* — ``copy=True`` alone dispatches asynchronously.  Without the
    barrier, decode computes with another tensor's weights (caught live as
    nondeterministic logits at bench scale)."""
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve") as s:
        src = np.arange(4096, dtype=np.float32)
        view = src[256:2304]                 # a slot-interior view, as used
        dev = s._h2d_copy(view)
        expect = view.copy()
        view[:] = -1.0                       # slot recycled: pread lands
        np.testing.assert_array_equal(np.asarray(dev), expect)


# -- pool integration ----------------------------------------------------------

def test_session_census_reserves_kv_slots(tmp_store_root):
    spec = DecodeSpec(batch=2, max_seq=16, bucket=8, resident_blocks=2)
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve", decode=spec) as s:
        stats = s.pool.stats()
        # page-granular AND per-slot: 2 layer-equivalents x (16/8 =) 2 pages
        # per seq x batch 2 slots; each page holds one request's rows
        assert stats["slots"][KV_CLASS] == 8
        expected = 2 * 1 * 8 * CFG.n_kv_heads * CFG.head_dim * 2  # bf16 page
        assert stats["slot_size"][KV_CLASS] == expected


def test_session_census_reserves_explicit_page_budget(tmp_store_root):
    spec = DecodeSpec(batch=2, max_seq=16, bucket=8, page_tokens=4,
                      resident_pages=3)
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve", decode=spec) as s:
        stats = s.pool.stats()
        # resident_pages caps the per-request budget; the census scales it
        # by the batch's slot count
        assert stats["slots"][KV_CLASS] == 3 * 2
        expected = 2 * 1 * 4 * CFG.n_kv_heads * CFG.head_dim * 2  # bf16 page
        assert stats["slot_size"][KV_CLASS] == expected


def test_pool_slots_released_on_mid_generate_failure(tmp_store_root):
    """A block_step failure mid-generate must leak nothing: weight slots
    drain via the executor's error path, KV slots via generate's finally."""
    prompts = _prompts()
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8, resident_blocks=2)
    dec = OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                      lr=1e-3), decode=spec)
    s = dec.session
    calls = {"n": 0}
    real_step = s._jit_block_step

    def flaky_step(params, h, k, v, cache_len, **kw):
        calls["n"] += 1
        if calls["n"] == 4:     # second decode step, mid-stack
            raise RuntimeError("injected step failure")
        return real_step(params, h, k, v, cache_len, **kw)

    s._jit_block_step = flaky_step
    with pytest.raises(RuntimeError, match="injected"):
        dec.generate(prompts, 8)
    assert s.pool.in_use_payload == 0          # weights AND kv slots back
    assert len(s.swapper._inflight) == 0
    assert dec.kv_stats is not None
    # the session is still usable: a fresh cache can be opened
    s._jit_block_step = real_step
    gen = dec.generate(prompts, 2)
    assert gen.shape == (2, 2)
    dec.close()
    s.tracker.assert_quiescent()


def test_only_one_open_kv_cache(tmp_store_root):
    spec = DecodeSpec(batch=1, max_seq=8, bucket=8)
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve", decode=spec) as s:
        kv = s.open_kv_cache()
        with pytest.raises(RuntimeError, match="already open"):
            s.open_kv_cache()
        kv.close()
        s.open_kv_cache().close()


# -- the validated token contract ---------------------------------------------

def test_token_contract_rejects_bad_inputs(tmp_store_root):
    spec = DecodeSpec(batch=2, max_seq=16, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        good = _prompts(seq=4)
        with pytest.raises(TypeError, match="integer"):
            dec.step_logits(good.astype(np.float32))
        with pytest.raises(ValueError, match=r"\(batch, time\)"):
            dec.step_logits(good[0])
        with pytest.raises(ValueError, match="negative"):
            dec.generate(good - 500, 2)
        with pytest.raises(ValueError, match="new_tokens"):
            dec.generate(good, 0)
        with pytest.raises(ValueError, match="batch"):
            dec.generate(_prompts(batch=3, seq=4), 2)
        with pytest.raises(ValueError, match="max_seq"):
            dec.generate(good, 13)
        # int64 ids are fine — converted, not rejected
        gen = dec.generate(good.astype(np.int64), 2)
        assert gen.dtype == np.int32 and gen.shape == (2, 2)


def test_use_cache_requires_decode_spec(tmp_store_root):
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3)) as dec:
        assert dec.decode_spec is None
        with pytest.raises(RuntimeError, match="DecodeSpec"):
            dec.generate(_prompts(), 2, use_cache=True)


def test_decoder_rejects_session_plus_decode(tmp_store_root):
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve") as s, \
            pytest.raises(ValueError, match="decode="):
        OffloadedDecoder(None, None, session=s,
                         decode=DecodeSpec(batch=1, max_seq=8, bucket=8))


def test_decode_spec_validation():
    with pytest.raises(ValueError, match="resident_blocks"):
        DecodeSpec(batch=1, max_seq=8, bucket=8, resident_blocks=1)
    with pytest.raises(ValueError, match="bucket"):
        DecodeSpec(batch=1, max_seq=8, bucket=16)
    with pytest.raises(ValueError, match="batch"):
        DecodeSpec(batch=0, max_seq=8, bucket=8)
    spec = DecodeSpec(batch=1, max_seq=20, bucket=8)
    assert spec.bucket_len(1) == 8
    assert spec.bucket_len(8) == 8
    assert spec.bucket_len(9) == 16
    assert spec.bucket_len(17) == 20   # clamped to capacity
    with pytest.raises(ValueError, match="exceeds"):
        spec.bucket_len(21)


def test_decode_spec_page_knobs():
    # defaults: pages are bucket-sized
    spec = DecodeSpec(batch=1, max_seq=20, bucket=8)
    assert spec.page_size == 8 and spec.pages_per_seq == 3
    assert spec.page_budget(n_blocks=4) == 12       # all resident
    assert DecodeSpec(batch=1, max_seq=20, bucket=8,
                      resident_blocks=2).page_budget(4) == 6
    assert DecodeSpec(batch=1, max_seq=20, bucket=8,
                      resident_pages=5).page_budget(4) == 5
    # page finer than bucket, and whole-layer pages (the PR-2 ablation)
    assert DecodeSpec(batch=1, max_seq=16, bucket=8,
                      page_tokens=4).pages_per_seq == 4
    assert DecodeSpec(batch=1, max_seq=16, bucket=8,
                      page_tokens=16).pages_per_seq == 1
    with pytest.raises(ValueError, match="align"):
        DecodeSpec(batch=1, max_seq=16, bucket=8, page_tokens=6)
    with pytest.raises(ValueError, match="page_tokens"):
        DecodeSpec(batch=1, max_seq=16, bucket=8, page_tokens=32)
    with pytest.raises(ValueError, match="resident_pages"):
        DecodeSpec(batch=1, max_seq=16, bucket=8, resident_pages=1)
    with pytest.raises(ValueError, match="not both"):
        DecodeSpec(batch=1, max_seq=16, bucket=8, resident_blocks=2,
                   resident_pages=4)


def test_session_requires_cached_applies(tmp_store_root):
    headless = dataclasses.replace(_model(), block_step=None)
    with pytest.raises(ValueError, match="cached-decode applies"):
        OffloadSession(headless, memascend_policy(tmp_store_root, lr=1e-3),
                       mode="serve",
                       decode=DecodeSpec(batch=1, max_seq=8, bucket=8))


# -- plan validator: the KV lifecycle ------------------------------------------

def test_validator_step_without_kv_read():
    with pytest.raises(PlanError, match="no KV read"):
        StreamPlan("bad", (FetchOp("u"), ComputeOp("u", "block_step"),
                           KVWriteOp("u"), ReleaseOp("u")))


def test_validator_double_kv_read():
    with pytest.raises(PlanError, match="double KV read"):
        StreamPlan("bad", (KVReadOp("u"), KVReadOp("u")))


def test_validator_kv_write_without_produce():
    with pytest.raises(PlanError, match="no K/V produced"):
        StreamPlan("bad", (KVWriteOp("u"),))


def test_validator_kv_write_mode_must_match_producer():
    with pytest.raises(PlanError, match="does not match its producing"):
        StreamPlan("bad", (FetchOp("u"),
                           ComputeOp("u", "block_prefill"),
                           KVWriteOp("u", "step"), ReleaseOp("u")))
    with pytest.raises(PlanError, match="does not match its producing"):
        StreamPlan("bad", (FetchOp("u"), KVReadOp("u"),
                           ComputeOp("u", "block_step"),
                           KVWriteOp("u", "prefill"), ReleaseOp("u")))
    with pytest.raises(PlanError, match="unknown KV write mode"):
        StreamPlan("bad", (FetchOp("u"),
                           ComputeOp("u", "block_prefill"),
                           KVWriteOp("u", "scatter"), ReleaseOp("u")))


def test_validator_kv_read_never_consumed():
    with pytest.raises(PlanError, match="never consumed"):
        StreamPlan("bad", (KVReadOp("u"),))


def test_validator_kv_never_written():
    with pytest.raises(PlanError, match="never written"):
        StreamPlan("bad", (FetchOp("u"),
                           ComputeOp("u", "block_prefill"),
                           ReleaseOp("u")))
