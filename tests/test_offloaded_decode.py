"""Cached offloaded decode: the spill-able KV cache, bucketed compile-once
stepping, token-identical equivalence with the uncached path, and the
validated token contract."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (ComputeOp, DecodeSpec, FetchOp, KVReadOp, KVWriteOp,
                        OffloadSession, PlanError, ReleaseOp, SpillableKVCache,
                        StreamPlan, memascend_policy)
from repro.core.buffer_pool import (KV_CLASS, AdaptiveBufferPool, PoolCensus,
                                    ShapeClass)
from repro.core.model_adapter import make_offloadable_lm
from repro.core.nvme import FilesystemEngine
from repro.core.pinned_alloc import AlignmentFreeAllocator
from repro.serve import OffloadedDecoder

CFG = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _prompts(batch=2, seq=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(3, CFG.vocab, size=(batch, seq), dtype=np.int32)


# -- equivalence with the uncached path ---------------------------------------

@pytest.mark.parametrize("resident_blocks", [None, 2])
def test_cached_matches_uncached_argmax(tmp_store_root, resident_blocks):
    """Cached decode (all-resident AND spilling) emits token-identical
    greedy output to the full-prefix re-run path on a fixed prompt set."""
    prompts = _prompts()
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8,
                      resident_blocks=resident_blocks)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "u",
                                                     lr=1e-3)) as dec:
        uncached = dec.generate(prompts, 8)
    np.testing.assert_array_equal(cached, uncached)


def test_use_cache_false_forces_uncached_path(tmp_store_root):
    prompts = _prompts()
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 4)
        uncached = dec.generate(prompts, 4, use_cache=False)
        np.testing.assert_array_equal(cached, uncached)
        assert dec.kv_stats is not None   # the cached run recorded stats


# -- bucketing: boundary crossings + compile-once ------------------------------

def test_bucket_boundary_crossing_stays_exact(tmp_store_root):
    """Generation crossing several time buckets (prompt pad, then two
    device-cache growths) matches the uncached path token for token."""
    prompts = _prompts(seq=3)
    spec = DecodeSpec(batch=2, max_seq=16, bucket=4)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "c",
                                                     lr=1e-3),
                          decode=spec) as dec:
        cached = dec.generate(prompts, 12)   # positions 3..14, buckets 4/8/12
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root + "u",
                                                     lr=1e-3)) as dec:
        uncached = dec.generate(prompts, 12)
    np.testing.assert_array_equal(cached, uncached)


def test_zero_retraces_after_first_token_per_bucket(tmp_store_root):
    """Each bucket traces once: a warm repeat of the same generation —
    which revisits every bucket — compiles nothing new, and within one
    bucket every step after the first reuses the trace."""
    prompts = _prompts(seq=3)
    spec = DecodeSpec(batch=2, max_seq=32, bucket=4)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        s = dec.session
        dec.generate(prompts, 10)
        warm = s.decode_compiles()
        dec.generate(prompts, 10)
        assert s.decode_compiles() == warm

        # step-by-step inside one fresh bucket: only the crossing retraces
        kv = s.open_kv_cache()
        try:
            logits = s.prefill(kv, prompts)           # length 3, bucket 4
            nxt = np.argmax(logits, axis=-1).astype(np.int32)[:, None]
            s.decode_step(kv, nxt)                    # length 3 -> 4
            s.decode_step(kv, nxt)                    # crosses into bucket 8
            after_crossing = s.decode_compiles()
            for _ in range(3):                        # stays inside bucket 8
                s.decode_step(kv, nxt)
            assert s.decode_compiles() == after_crossing
        finally:
            kv.close()


# -- the KV cache itself -------------------------------------------------------

def _kv_fixture(tmp_store_root, units=("a", "b", "c"), resident=2,
                shape=(2, 1, 4, 1, 2)):
    from repro.core import MemoryTracker
    nbytes = int(np.prod(shape)) * 4
    census = PoolCensus((ShapeClass("w", 64, per_block=1),),
                        inflight_blocks=1).with_kv(nbytes, resident)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pinned", backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    store = FilesystemEngine(tmp_store_root)
    kv = SpillableKVCache(list(units), shape, np.float32, pool, store,
                          resident_limit=resident)
    return kv, pool, store


def test_kv_spill_refill_round_trip(tmp_store_root):
    """Data written before a spill comes back bit-identical after the
    refill, through the real store."""
    kv, pool, store = _kv_fixture(tmp_store_root)
    rng = np.random.default_rng(0)
    k = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
    v = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
    # 3 units through a 2-slot budget: spill-after-use evicts immediately
    kv.write_prefill("a", k, v)
    assert kv.stats.spills >= 1 and store.contains("kv/a")
    view = kv.ensure("a")                      # sync refill from SSD
    np.testing.assert_array_equal(view[0][:, :3], k)
    np.testing.assert_array_equal(view[1][:, :3], v)
    assert kv.stats.refills == 1 and kv.stats.sync_refills == 1
    kv.close()
    assert pool.in_use_payload == 0
    kv.close()   # idempotent


def test_kv_prefetch_overlaps_and_hits(tmp_store_root):
    kv, pool, _store = _kv_fixture(tmp_store_root)
    z = np.zeros((1, 4, 1, 2), np.float32)
    for u in ("a", "b", "c"):
        kv.write_prefill(u, z, z)              # all spilled (keep budget 0)
    kv.prefetch("b")
    view = kv.ensure("b")
    assert view.shape == (2, 1, 4, 1, 2)
    assert kv.stats.prefetch_refills == 1
    kv.prefetch("b")                           # resident: no-op
    assert kv.stats.prefetch_refills == 1
    kv.close()
    assert pool.in_use_payload == 0


def test_kv_cache_full_and_length_bounds(tmp_store_root):
    kv, _pool, _store = _kv_fixture(tmp_store_root, units=("a",), resident=1)
    kv.set_length(4)
    one = np.zeros((1, 1, 1, 2), np.float32)
    with pytest.raises(ValueError, match="full"):
        kv.append("a", one, one)
    with pytest.raises(ValueError, match="outside"):
        kv.set_length(5)
    kv.close()


def test_kv_resident_limit_validation(tmp_store_root):
    with pytest.raises(ValueError, match="resident_limit"):
        _kv_fixture(tmp_store_root, units=("a", "b", "c"), resident=1)


# -- pool integration ----------------------------------------------------------

def test_session_census_reserves_kv_slots(tmp_store_root):
    spec = DecodeSpec(batch=2, max_seq=16, bucket=8, resident_blocks=2)
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve", decode=spec) as s:
        stats = s.pool.stats()
        assert stats["slots"][KV_CLASS] == 2
        expected = 2 * 2 * 16 * CFG.n_kv_heads * CFG.head_dim * 2  # bf16
        assert stats["slot_size"][KV_CLASS] == expected


def test_pool_slots_released_on_mid_generate_failure(tmp_store_root):
    """A block_step failure mid-generate must leak nothing: weight slots
    drain via the executor's error path, KV slots via generate's finally."""
    prompts = _prompts()
    spec = DecodeSpec(batch=2, max_seq=32, bucket=8, resident_blocks=2)
    dec = OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                      lr=1e-3), decode=spec)
    s = dec.session
    calls = {"n": 0}
    real_step = s._jit_block_step

    def flaky_step(params, h, k, v, cache_len):
        calls["n"] += 1
        if calls["n"] == 4:     # second decode step, mid-stack
            raise RuntimeError("injected step failure")
        return real_step(params, h, k, v, cache_len)

    s._jit_block_step = flaky_step
    with pytest.raises(RuntimeError, match="injected"):
        dec.generate(prompts, 8)
    assert s.pool.in_use_payload == 0          # weights AND kv slots back
    assert len(s.swapper._inflight) == 0
    assert dec.kv_stats is not None
    # the session is still usable: a fresh cache can be opened
    s._jit_block_step = real_step
    gen = dec.generate(prompts, 2)
    assert gen.shape == (2, 2)
    dec.close()
    s.tracker.assert_quiescent()


def test_only_one_open_kv_cache(tmp_store_root):
    spec = DecodeSpec(batch=1, max_seq=8, bucket=8)
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve", decode=spec) as s:
        kv = s.open_kv_cache()
        with pytest.raises(RuntimeError, match="already open"):
            s.open_kv_cache()
        kv.close()
        s.open_kv_cache().close()


# -- the validated token contract ---------------------------------------------

def test_token_contract_rejects_bad_inputs(tmp_store_root):
    spec = DecodeSpec(batch=2, max_seq=16, bucket=8)
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3),
                          decode=spec) as dec:
        good = _prompts(seq=4)
        with pytest.raises(TypeError, match="integer"):
            dec.step_logits(good.astype(np.float32))
        with pytest.raises(ValueError, match=r"\(batch, time\)"):
            dec.step_logits(good[0])
        with pytest.raises(ValueError, match="negative"):
            dec.generate(good - 500, 2)
        with pytest.raises(ValueError, match="new_tokens"):
            dec.generate(good, 0)
        with pytest.raises(ValueError, match="batch"):
            dec.generate(_prompts(batch=3, seq=4), 2)
        with pytest.raises(ValueError, match="max_seq"):
            dec.generate(good, 13)
        # int64 ids are fine — converted, not rejected
        gen = dec.generate(good.astype(np.int64), 2)
        assert gen.dtype == np.int32 and gen.shape == (2, 2)


def test_use_cache_requires_decode_spec(tmp_store_root):
    with OffloadedDecoder(_model(), memascend_policy(tmp_store_root,
                                                     lr=1e-3)) as dec:
        assert dec.decode_spec is None
        with pytest.raises(RuntimeError, match="DecodeSpec"):
            dec.generate(_prompts(), 2, use_cache=True)


def test_decoder_rejects_session_plus_decode(tmp_store_root):
    with OffloadSession(_model(), memascend_policy(tmp_store_root, lr=1e-3),
                        mode="serve") as s:
        with pytest.raises(ValueError, match="decode="):
            OffloadedDecoder(None, None, session=s,
                             decode=DecodeSpec(batch=1, max_seq=8, bucket=8))


def test_decode_spec_validation():
    with pytest.raises(ValueError, match="resident_blocks"):
        DecodeSpec(batch=1, max_seq=8, bucket=8, resident_blocks=1)
    with pytest.raises(ValueError, match="bucket"):
        DecodeSpec(batch=1, max_seq=8, bucket=16)
    with pytest.raises(ValueError, match="batch"):
        DecodeSpec(batch=0, max_seq=8, bucket=8)
    spec = DecodeSpec(batch=1, max_seq=20, bucket=8)
    assert spec.bucket_len(1) == 8
    assert spec.bucket_len(8) == 8
    assert spec.bucket_len(9) == 16
    assert spec.bucket_len(17) == 20   # clamped to capacity
    with pytest.raises(ValueError, match="exceeds"):
        spec.bucket_len(21)


def test_session_requires_cached_applies(tmp_store_root):
    headless = dataclasses.replace(_model(), block_step=None)
    with pytest.raises(ValueError, match="cached-decode applies"):
        OffloadSession(headless, memascend_policy(tmp_store_root, lr=1e-3),
                       mode="serve",
                       decode=DecodeSpec(batch=1, max_seq=8, bucket=8))


# -- plan validator: the KV lifecycle ------------------------------------------

def test_validator_step_without_kv_read():
    with pytest.raises(PlanError, match="no KV read"):
        StreamPlan("bad", (FetchOp("u"), ComputeOp("u", "block_step"),
                           KVWriteOp("u"), ReleaseOp("u")))


def test_validator_double_kv_read():
    with pytest.raises(PlanError, match="double KV read"):
        StreamPlan("bad", (KVReadOp("u"), KVReadOp("u")))


def test_validator_kv_write_without_produce():
    with pytest.raises(PlanError, match="no K/V produced"):
        StreamPlan("bad", (KVWriteOp("u"),))


def test_validator_kv_read_never_consumed():
    with pytest.raises(PlanError, match="never consumed"):
        StreamPlan("bad", (KVReadOp("u"),))


def test_validator_kv_never_written():
    with pytest.raises(PlanError, match="never written"):
        StreamPlan("bad", (FetchOp("u"),
                           ComputeOp("u", "block_prefill"),
                           ReleaseOp("u")))
