"""Host Adam: streamed subgroups vs in-memory reference; bf16 state mode."""

import numpy as np

from repro.core import (AdamConfig, DirectNVMeEngine, MemoryTracker,
                        OffloadedAdam, adam_update)


def reference_adam(w0, grads, cfg):
    """Plain in-memory Adam over a list of per-step grads."""
    m = np.zeros_like(w0)
    v = np.zeros_like(w0)
    w = w0.copy()
    for t, g in enumerate(grads, start=1):
        adam_update(w, g, m, v, t, cfg)
    return w


def test_streamed_matches_reference(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=2,
                           device_capacity=1 << 24)
    cfg = AdamConfig(lr=1e-2, weight_decay=0.01)
    opt = OffloadedAdam(eng, cfg, tracker=MemoryTracker())
    w0 = rng.standard_normal((64, 48)).astype(np.float32)
    grads = [rng.standard_normal((64, 48)).astype(np.float32)
             for _ in range(5)]
    opt.register("w", w0)
    for g in grads:
        opt.begin_step()
        opt.step_subgroup("w", g)
    ref = reference_adam(w0, grads, cfg)
    got = eng.read_new("w.master", np.float32, w0.shape)
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-7)
    opt.close()   # shuts the write-back executor down (leak guard)
    eng.close()


def test_bf16_state_mode_tracks_fp32(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 24)
    cfg32 = AdamConfig(lr=1e-2)
    cfg16 = AdamConfig(lr=1e-2, state_dtype="bfloat16")
    o32 = OffloadedAdam(eng, cfg32, tracker=MemoryTracker())
    o16 = OffloadedAdam(eng, cfg16, tracker=MemoryTracker())
    w0 = rng.standard_normal(2048).astype(np.float32)
    o32.register("a", w0)
    o16.register("b", w0)
    for _ in range(3):
        g = rng.standard_normal(2048).astype(np.float32)
        o32.begin_step(); w_a = o32.step_subgroup("a", g)
        o16.begin_step(); w_b = o16.step_subgroup("b", g)
    # bf16 states track fp32 within truncation error
    err = np.abs(w_a.astype(np.float32) - w_b.astype(np.float32)).max()
    assert err < 0.05
    # and cut the I/O volume roughly in half (paper Fig. 20)
    assert o16.last_io_bytes < 0.6 * o32.last_io_bytes
    o32.close()
    o16.close()
    eng.close()


def test_io_accounting_matches_formula(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 24)
    for state_dtype in ("float32", "bfloat16"):
        cfg = AdamConfig(state_dtype=state_dtype)
        opt = OffloadedAdam(eng, cfg, tracker=MemoryTracker())
        n = 4096
        opt.register(f"w-{state_dtype}", np.zeros(n, np.float32))
        opt.begin_step()
        opt.step_subgroup(f"w-{state_dtype}", np.zeros(n, np.float32))
        s = cfg.state_np_dtype.itemsize
        c = cfg.compute_np_dtype.itemsize
        assert opt.last_io_bytes == n * (6 * s + c)
        opt.close()
    eng.close()


def test_skipped_step_changes_nothing(tmp_store_root, rng):
    """Overflow-skipped steps must leave SSD state untouched (the engine
    simply doesn't call step_subgroup)."""
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 24)
    opt = OffloadedAdam(eng, AdamConfig(), tracker=MemoryTracker())
    w0 = rng.standard_normal(128).astype(np.float32)
    opt.register("w", w0)
    before = eng.read_new("w.master", np.float32, w0.shape).copy()
    opt.begin_step()   # begun but no subgroup streamed = skipped
    np.testing.assert_array_equal(
        eng.read_new("w.master", np.float32, w0.shape), before)
    opt.close()
    eng.close()


def test_split_halves_compose_to_step_subgroup(tmp_store_root, rng):
    """issue/compute/commit run separately must be byte-identical to the
    one-call step_subgroup (the pipelined executor uses the halves)."""
    cfg = AdamConfig(lr=1e-2, weight_decay=0.01)
    w0 = rng.standard_normal((32, 24)).astype(np.float32)
    grads = [rng.standard_normal((32, 24)).astype(np.float32)
             for _ in range(3)]
    masters = {}
    for mode in ("fused", "split"):
        eng = DirectNVMeEngine(f"{tmp_store_root}/{mode}", n_devices=1,
                               device_capacity=1 << 24)
        opt = OffloadedAdam(eng, cfg, tracker=MemoryTracker())
        opt.register("w", w0)
        for g in grads:
            opt.begin_step()
            if mode == "fused":
                opt.step_subgroup("w", g)
            else:
                staged = opt.issue_subgroup("w")
                opt.compute_subgroup(staged, g)
                opt.commit_subgroup(staged)
        assert opt.staging_idle()
        masters[mode] = eng.read_new("w.master", np.float32, w0.shape)
        opt.close()
        eng.close()
    np.testing.assert_array_equal(masters["fused"].view(np.uint8),
                                  masters["split"].view(np.uint8))


def test_staging_arena_charge_and_bf16_scratch(tmp_store_root, rng):
    """The double-buffered arena is one tracked allocation sized
    2 x (3 x max-subgroup fp32 + truncation scratch); the former untracked
    astype transients are gone.  bf16 state mode needs a scratch (reads
    and write-backs pass through it); pure-fp32 mode needs none."""
    for state_dtype, compute_dtype, scratch_per_elem in (
            ("float32", "float32", 0),
            ("float32", "bfloat16", 2),
            # bf16 states: 3 concurrently-written bf16 regions + compute
            ("bfloat16", "bfloat16", 3 * 2 + 2)):
        t = MemoryTracker()
        eng = DirectNVMeEngine(
            f"{tmp_store_root}/{state_dtype}-{compute_dtype}",
            n_devices=1, device_capacity=1 << 24)
        opt = OffloadedAdam(eng, AdamConfig(state_dtype=state_dtype,
                                            compute_dtype=compute_dtype),
                            tracker=t)
        opt.register("small", rng.standard_normal(100).astype(np.float32))
        opt.register("big", rng.standard_normal(1000).astype(np.float32))
        opt.begin_step()
        opt.step_subgroup("big", np.zeros(1000, np.float32))
        opt.step_subgroup("small", np.zeros(100, np.float32))
        comp = t.component("optimizer_stream")
        assert comp.peak_allocated == 2 * (3 * 1000 * 4
                                           + 1000 * scratch_per_elem)
        assert comp.n_allocs == 1           # the arena, once — not per call
        opt.close()
        assert t.component("optimizer_stream").live_allocated == 0
        t.assert_quiescent()
        eng.close()


def test_failed_issue_releases_staging_buffer(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 24)
    opt = OffloadedAdam(eng, AdamConfig(), tracker=MemoryTracker())
    opt.register("w", rng.standard_normal(64).astype(np.float32))
    real_read = eng.read

    def flaky_read(key, out):
        if key.endswith(".v"):
            raise IOError("boom")
        return real_read(key, out)

    eng.read = flaky_read
    opt.begin_step()
    import pytest
    with pytest.raises(IOError, match="boom"):
        opt.issue_subgroup("w")
    assert opt.staging_idle()
    opt.close()
    eng.close()
