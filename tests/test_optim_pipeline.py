"""The pipelined Adam stage: state-prefetch worker, double-buffered staging
arena, per-subgroup overflow screen — fault injection and resource hygiene.

Every failure mode asserted here follows the same contract: the error
surfaces exactly once (at the failed unit's next readiness gate, with
close() clean afterwards), stale compute weights are never served, and
every staged buffer goes back to the arena (tracker balance zero)."""

import threading

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, OffloadSession
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _batches(n, batch=4, seq=32, seed=1):
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=seed), batch=batch,
                    seq_len=seq)
    return [dl.next_batch() for _ in range(n)]


def _policy(root, overlap="full", **adam):
    adam.setdefault("lr", 3e-3)
    return (OffloadPolicy.preset("memascend").with_store(root)
            .with_adam(**adam).with_overlap(overlap).build())


# -- pipeline topology -------------------------------------------------------

def test_state_prefetch_worker_only_under_full(tmp_store_root):
    with OffloadSession(_model(), _policy(tmp_store_root + "f")) as s:
        assert s._optim_prefetch is not None
        assert any(t.name == "offload-optim-prefetch"
                   for t in threading.enumerate())
    with OffloadSession(_model(), _policy(tmp_store_root + "s",
                                          overlap="sync")) as s:
        assert s._optim_prefetch is None


def test_pipeline_prefetches_next_subgroup_under_compute(tmp_store_root):
    """The point of the stage: while subgroup k computes, subgroup k+1's
    issue is already queued — observed as issues submitted ahead of the
    computes that consume them."""
    b = _batches(1)[0]
    with OffloadSession(_model(), _policy(tmp_store_root)) as s:
        issues, computes = [], []
        real_issue = s.optimizer.issue_subgroup
        real_compute = s.optimizer.compute_subgroup

        def issue(key):
            issues.append(key)          # runs FIFO on the prefetch worker
            return real_issue(key)

        def compute(staged, grad):
            # _adam_issued is optimizer-worker-thread state, read here on
            # that same thread: a deterministic probe of the window depth
            computes.append((staged.key, s._adam_issued))
            return real_compute(staged, grad)

        s.optimizer.issue_subgroup = issue
        s.optimizer.compute_subgroup = compute
        s.train_step(b["tokens"], b["labels"])
        s.synchronize()
        n_sub = len(s.optimizer.subgroups)
        assert issues == [k for k, _ in computes]  # same subgroups, order
        assert len(issues) == n_sub
        # double buffering: when subgroup k computes, subgroup k+1's issue
        # has already been submitted to the state-prefetch worker
        for k, (_key, issued_then) in enumerate(computes):
            assert issued_then == min(k + 2, n_sub)
        assert s.optimizer.staging_idle()
    s.tracker.assert_quiescent()


def test_staging_arena_accounted_and_freed(tmp_store_root):
    """The arena (2 x (3 fp32 + truncation scratch) of the largest
    subgroup) is tracker-charged once, reused across steps, and freed at
    close — no per-step astype transients remain unaccounted."""
    bs = _batches(2)
    s = OffloadSession(_model(), _policy(tmp_store_root))
    for b in bs:
        s.train_step(b["tokens"], b["labels"])
    s.synchronize()
    comp = s.tracker.component("optimizer_stream")
    max_elems = max(m.size for m in s.optimizer.subgroups.values())
    scratch = max_elems * 2        # bf16 compute-weight truncation scratch
    assert comp.peak_allocated == 2 * (3 * max_elems * 4 + scratch)
    assert comp.n_allocs == 1      # one arena, not per-subgroup charges
    assert comp.live_allocated > 0
    s.close()
    assert s.tracker.component("optimizer_stream").live_allocated == 0
    s.tracker.assert_quiescent()


# -- fault injection: state-prefetch reads -----------------------------------

def test_read_failure_mid_prefetch_surfaces_once_and_frees_staging(
        tmp_store_root):
    """A store read that fails mid-prefetch: the failed unit's readiness
    future carries the error, it surfaces at that unit's next fetch gate
    (exactly once — close() stays clean afterwards), and every staged
    buffer returns to the arena."""
    bs = _batches(2)
    s = OffloadSession(_model(), _policy(tmp_store_root))
    real_read = s.store.read

    def flaky_read(key, out):
        if key == "block_001/attn.w_v.m":  # first moment, mid-unit
            raise IOError("injected state-read failure")
        return real_read(key, out)

    s.store.read = flaky_read
    s.train_step(bs[0]["tokens"], bs[0]["labels"])   # enqueues doomed stage
    with pytest.raises(IOError, match="injected state-read"):
        s.train_step(bs[1]["tokens"], bs[1]["labels"])
    assert s.optimizer.staging_idle()      # every fp32 buffer returned
    assert s.pool.in_use_payload == 0
    s.close()                              # error already delivered: clean
    s.tracker.assert_quiescent()


def test_read_failure_never_serves_stale_compute_weights(tmp_store_root):
    """After a failed prefetch the unit's weights on the store are
    pre-update; every later fetch of that unit must keep raising rather
    than silently serving them."""
    bs = _batches(2)
    s = OffloadSession(_model(), _policy(tmp_store_root))
    real_read = s.store.read
    def flaky_read(key, out):
        if key.startswith("head/") and key.endswith(".master"):
            raise IOError("injected state-read failure")
        return real_read(key, out)

    s.store.read = flaky_read
    s.train_step(bs[0]["tokens"], bs[0]["labels"])
    with pytest.raises(IOError, match="injected state-read"):
        s.eval_loss(bs[1]["tokens"], bs[1]["labels"])   # head fetch gates
    with pytest.raises(IOError, match="injected state-read"):
        s.eval_loss(bs[1]["tokens"], bs[1]["labels"])   # still poisoned
    assert s.optimizer.staging_idle()
    s.close()
    s.tracker.assert_quiescent()


# -- fault injection: write-back at commit -----------------------------------

def test_commit_write_failure_surfaces_once_and_frees_staging(
        tmp_store_root):
    """Same contract for the other half: a write-back that fails at commit
    fails the unit's readiness future (which resolves at commit, not at
    compute), surfaces at the unit's next fetch, and releases the buffer."""
    bs = _batches(2)
    s = OffloadSession(_model(), _policy(tmp_store_root))
    real_write = s.store.write

    def flaky_write(key, data):
        if key == "block_000/attn.w_o.v":
            raise IOError("injected write-back failure")
        return real_write(key, data)

    s.store.write = flaky_write
    s.train_step(bs[0]["tokens"], bs[0]["labels"])
    with pytest.raises(IOError, match="injected write-back"):
        s.train_step(bs[1]["tokens"], bs[1]["labels"])
    assert s.optimizer.staging_idle()
    assert s.pool.in_use_payload == 0
    s.close()
    s.tracker.assert_quiescent()


def test_commit_failure_poisons_step_but_not_session_teardown(
        tmp_store_root):
    """Delivery via synchronize() consumes the latched failure; the
    session then closes cleanly with the arena whole."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root))
    real_write = s.store.write

    def flaky_write(key, data):
        if key.endswith(".compute") and key.startswith("embed/"):
            raise IOError("injected compute-write failure")
        return real_write(key, data)

    s.store.write = flaky_write
    s.train_step(b["tokens"], b["labels"])
    with pytest.raises(IOError, match="injected compute-write"):
        s.synchronize()
    assert s.optimizer.staging_idle()
    s.close()
    s.tracker.assert_quiescent()


# -- per-subgroup overflow screen --------------------------------------------

def test_overflow_skips_adam_issues_and_leaves_state_untouched(
        tmp_store_root):
    """An overflow verdict (OR of the per-region screens) must skip the
    step before anything reaches the Adam pipeline: zero issues, zero
    staged buffers, masters bit-identical — nothing in flight to corrupt."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root,
                                         compute_dtype="float16"))
    before = s.master_param("embed", "embed").copy()
    issues = {"n": 0}
    real_issue = s.optimizer.issue_subgroup

    def counting_issue(key):
        issues["n"] += 1
        return real_issue(key)

    s.optimizer.issue_subgroup = counting_issue
    s.scaler.scale = 2.0 ** 40      # guarantees fp16 grad overflow
    m = s.train_step(b["tokens"], b["labels"])
    s.synchronize()
    assert m["overflowed"] and not m["applied"]
    assert issues["n"] == 0
    assert s.optimizer.staging_idle()
    after = s.master_param("embed", "embed")
    np.testing.assert_array_equal(before.view(np.uint8),
                                  after.view(np.uint8))
    s.close()
    s.tracker.assert_quiescent()


@pytest.mark.parametrize("overlap", ["sync", "full"])
def test_per_region_screen_verdict_matches_scaled_run(tmp_store_root,
                                                      overlap):
    """The per-region screen (inline under sync, writer-thread under full)
    reaches the same verdict in both modes, and a clean step reports no
    overflow."""
    b = _batches(1)[0]
    with OffloadSession(_model(), _policy(tmp_store_root + overlap, overlap,
                                          compute_dtype="float16")) as s:
        s.scaler.scale = 256.0          # modest: no overflow on this model
        m = s.train_step(b["tokens"], b["labels"])
        assert not m["overflowed"] and m["applied"]
        assert m["overflow_screen_s"] >= 0.0
        assert m["optim_prefetch_wait_s"] >= 0.0


def test_screen_runs_on_writer_thread_under_full(tmp_store_root):
    b = _batches(1)[0]
    with OffloadSession(_model(), _policy(tmp_store_root)) as s:
        screen_threads = set()
        real_screen = s._screen_unit_region

        def screen(unit):
            screen_threads.add(threading.current_thread().name)
            return real_screen(unit)

        s._screen_unit_region = screen
        s.train_step(b["tokens"], b["labels"])
        s.synchronize()
        assert screen_threads == {"offload-gradwrite"}


# -- the compute-weight write guard ------------------------------------------

def test_commit_guard_rejects_write_over_inflight_prefetch(tmp_store_root):
    """The stale-read guard on the Adam commit's compute-weight write
    path: refreshing weights whose prefetched read is still outstanding
    must fail loudly instead of racing the pread."""
    b = _batches(1)[0]
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap="sync"))
    s.train_step(b["tokens"], b["labels"])       # materialize grads + state
    cd = s.policy.adam.compute_np_dtype
    shape = s._units["embed"][1]["embed"][0]
    s.swapper.prefetch("embed/embed.compute", cd, shape)
    grad = np.zeros(shape, np.float32)
    s.optimizer.begin_step()
    with pytest.raises(RuntimeError, match="in flight"):
        s.optimizer.step_subgroup("embed/embed", grad)
    assert s.optimizer.staging_idle()            # commit released its buffer
    s.swapper.drain()
    s.close()
    s.tracker.assert_quiescent()
