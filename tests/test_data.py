import numpy as np

from repro.data import DataLoader, SyntheticTextDataset


def test_deterministic():
    a = DataLoader(SyntheticTextDataset(vocab=128, seed=7), batch=4,
                   seq_len=16).next_batch()
    b = DataLoader(SyntheticTextDataset(vocab=128, seed=7), batch=4,
                   seq_len=16).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_shapes_and_ranges():
    dl = DataLoader(SyntheticTextDataset(vocab=128, seed=0), batch=4,
                    seq_len=16)
    for _ in range(3):
        b = dl.next_batch()
        assert b["tokens"].shape == (4, 16)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
        valid = b["labels"][b["labels"] >= 0]
        assert valid.max() < 128


def test_boundary_masking():
    dl = DataLoader(SyntheticTextDataset(vocab=64, seed=0, mean_doc_len=8),
                    batch=2, seq_len=64)
    b = dl.next_batch()
    # labels never train into a BOS (document start)
    assert not (b["labels"] == dl.ds.bos).any()


def test_host_shards_disjoint():
    ds = SyntheticTextDataset(vocab=128, seed=3)
    d0 = DataLoader(ds, batch=2, seq_len=32, process_index=0,
                    process_count=2)
    d1 = DataLoader(ds, batch=2, seq_len=32, process_index=1,
                    process_count=2)
    b0, b1 = d0.next_batch(), d1.next_batch()
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # doc indices are interleaved: 0,2,4,... vs 1,3,5,...
    assert d0._next_doc % 2 == 0 and d1._next_doc % 2 == 1
