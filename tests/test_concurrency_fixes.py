"""Regression tests for the true positives the concurrency analyzer
(:mod:`tools.analyze`) surfaced in the offload pipeline.

Each test pins one fixed defect: pool-slot leaks on failed read issues
(swapper prefetch, KV window prefetch, KV ensure-page), unguarded
counter/metadata reads torn by worker threads (pool stats, store keys,
I/O ledger, memory tracker), and the optimizer's write-back executor
resurrecting after close.
"""

import threading

import numpy as np
import pytest

from repro.core import (AdamConfig, AdaptiveBufferPool,
                        AlignmentFreeAllocator, DirectNVMeEngine,
                        MemoryTracker, OffloadedAdam, ParameterSwapper,
                        PoolCensus, ShapeClass)
from repro.core.buffer_pool import PoolBuffer
from repro.core.kv_cache import SpillableKVCache
from repro.core.nvme import FilesystemEngine, IOStats


# -- swapper: failed prefetch issue must return the pool slot -----------------

def test_prefetch_releases_slot_when_issue_fails(tmp_store_root, rng):
    """A read_async that raises at issue time leaves nothing owning the
    just-acquired slot; prefetch() must release it (regression: the slot
    was checked out of the pool for the rest of the session) and undo the
    _reading guard count so store writers are not blocked forever."""
    store = DirectNVMeEngine(tmp_store_root, n_devices=1,
                             device_capacity=1 << 22)
    census = PoolCensus((ShapeClass("w", 256 * 4, 2),), inflight_blocks=2)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(), component="pool",
                                   backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    store.write("t0", rng.standard_normal(256).astype(np.float32))
    swapper = ParameterSwapper(store, pool, class_of={"t0": "w"})

    def broken_read_async(key, out):
        raise IOError("issue failed")

    store.read_async, real = broken_read_async, store.read_async
    try:
        with pytest.raises(IOError, match="issue failed"):
            swapper.prefetch("t0", np.float32, (256,))
    finally:
        store.read_async = real

    # every slot is still acquirable (nothing leaked)...
    bufs = [pool.acquire("w", 256 * 4, timeout=1.0) for _ in range(4)]
    for b in bufs:
        b.release()
    # ...and the stale-read write guard sees no phantom in-flight read
    swapper.assert_not_in_flight("t0")
    ticket = swapper.get("t0", np.float32, (256,))  # retry works
    ticket.release()
    swapper.drain()
    pool.close()
    store.close()


# -- KV cache: failed refill issues must return their slots -------------------

def _kv_fixture(root, resident=2, page_shape=(2, 1, 2, 1, 2), max_seq=4):
    nbytes = int(np.prod(page_shape)) * 4
    census = PoolCensus((ShapeClass("w", 64, per_block=1),),
                        inflight_blocks=1).with_kv(nbytes, resident)
    alloc = AlignmentFreeAllocator(tracker=MemoryTracker(),
                                   component="pinned", backing="numpy")
    pool = AdaptiveBufferPool(census, alloc)
    store = FilesystemEngine(root)
    kv = SpillableKVCache(["a", "b", "c"], page_shape, max_seq, np.float32,
                          pool, store, resident_limit=resident)
    return kv, pool, store


def test_kv_prefetch_window_releases_slot_on_failed_issue(tmp_store_root):
    """prefetch_window's async refill: a read_async raising at issue must
    release the acquired slot and keep the page in _spilled so a later
    sync gather still refills it from SSD (regression: the slot leaked
    and the page was forgotten as spilled)."""
    kv, pool, store = _kv_fixture(tmp_store_root)
    rng = np.random.default_rng(0)
    k = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
    v = rng.standard_normal((1, 3, 1, 2), dtype=np.float32)
    try:
        kv.write_prefill("a", k, v)      # 2 pages through a 2-slot budget
        kv.write_prefill("b", k, v)      # evicts a's dirty pages to SSD
        assert kv.stats.spills >= 1

        def broken_read_async(key, out):
            raise IOError("refill issue failed")

        store.read_async, real = broken_read_async, store.read_async
        try:
            with pytest.raises(IOError, match="refill issue failed"):
                kv.prefetch_window("a", 3)
        finally:
            store.read_async = real

        # the page survived as spilled: a sync gather refills it exactly
        kg, vg = kv.gather_window("a", 3)
        np.testing.assert_array_equal(kg, k)
        np.testing.assert_array_equal(vg, v)
    finally:
        kv.close()
        pool.close()
        store.close()


def test_kv_ensure_page_releases_slot_when_view_fails(tmp_store_root,
                                                      monkeypatch):
    """ensure_page acquires a slot, then views it; a failure in the view
    itself must release the slot like a failed read does (regression: the
    view ran outside the try, leaking the slot and the _in_transit count,
    which eventually wedged every later ensure in the capacity wait)."""
    kv, pool, store = _kv_fixture(tmp_store_root)
    try:
        real_view = PoolBuffer.view

        def broken_view(self, dtype, shape):
            raise RuntimeError("view blew up")

        monkeypatch.setattr(PoolBuffer, "view", broken_view)
        with pytest.raises(RuntimeError, match="view blew up"):
            kv.ensure_page("a", 0)
        monkeypatch.setattr(PoolBuffer, "view", real_view)

        # slot + transit count came back: the retry and a full-budget
        # walk across other units both succeed without a capacity wait
        kv.ensure_page("a", 0)
        kv.ensure_page("b", 0)
        kv.ensure_page("c", 0)
    finally:
        kv.close()
        pool.close()
        store.close()


# -- pool stats: coherent under concurrent churn ------------------------------

def test_pool_stats_consistent_under_concurrent_churn():
    """stats()/fragmentation() read the peak counters under the pool lock
    (regression: a mid-acquire read paired a bumped in_use with a
    not-yet-bumped peak, reporting peak < live)."""
    census = PoolCensus((ShapeClass("w", 1024, 4),), inflight_blocks=2)
    pool = AdaptiveBufferPool(
        census, AlignmentFreeAllocator(tracker=MemoryTracker(),
                                       component="pool"))
    stop = threading.Event()
    bad: list[dict] = []

    def churn():
        while not stop.is_set():
            bufs = [pool.acquire("w", 1024, timeout=5.0) for _ in range(8)]
            for b in bufs:
                b.release()

    def sample():
        while not stop.is_set():
            s = pool.stats()
            if not (0 <= s["peak_in_use_payload"] <= s["pool_bytes"]
                    and s["peak_in_use_reserved"] >= s["peak_in_use_payload"]
                    and 0.0 <= s["fragmentation"] <= 1.0):
                bad.append(s)

    threads = [threading.Thread(target=churn),
               threading.Thread(target=sample)]
    for t in threads:
        t.start()
    stop_timer = threading.Timer(0.5, stop.set)
    stop_timer.start()
    for t in threads:
        t.join()
    stop_timer.cancel()
    assert not bad, f"torn stats snapshots: {bad[:3]}"
    pool.close()


# -- store metadata: keys() vs concurrent async writes ------------------------

def test_filesystem_keys_during_concurrent_async_writes(tmp_store_root):
    """keys() snapshots _meta under the store lock (regression: dict
    iteration raised 'dictionary changed size during iteration' when a
    checkpoint enumerated keys while write_async completions landed)."""
    store = FilesystemEngine(tmp_store_root, fsync=False)
    data = np.zeros(64, np.float32)
    futures = [store.write_async(f"k{i:04d}", data) for i in range(200)]
    seen = 0
    while any(not f.done() for f in futures):
        seen = max(seen, len(store.keys()))   # must never raise
    for f in futures:
        f.result()
    assert len(store.keys()) == 200
    store.close()


# -- I/O ledger: exact totals from concurrent recorders -----------------------

def test_io_stats_exact_under_concurrent_record():
    """IOStats.record is a lock-guarded read-modify-write (regression:
    concurrent store workers tore the unguarded counters and the ledger
    drifted from the true transferred volume)."""
    stats = IOStats()

    def hammer():
        for _ in range(2000):
            stats.record("w", 3, 0.0)
            stats.record("r", 5, 0.0)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = stats.snapshot()
    assert snap["n_writes"] == 4 * 2000
    assert snap["n_reads"] == 4 * 2000
    assert snap["bytes_written"] == 4 * 2000 * 3
    assert snap["bytes_read"] == 4 * 2000 * 5


# -- optimizer: no write-back executor resurrection after close ---------------

def test_optimizer_close_does_not_resurrect_io_pool(tmp_store_root, rng):
    """After close(), both the arena and the write-back executor must stay
    down: a late commit fails loudly instead of silently recreating a
    thread nobody will ever join (regression: _pool() rebuilt the
    executor after close had shut it down and returned)."""
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 22)
    opt = OffloadedAdam(eng, AdamConfig(), tracker=MemoryTracker())
    opt.register("w", rng.standard_normal(64).astype(np.float32))
    opt.begin_step()
    opt.step_subgroup("w", np.zeros(64, np.float32))
    opt.close()
    before = {t.name for t in threading.enumerate()}
    with pytest.raises(RuntimeError, match="closed"):
        opt.issue_subgroup("w")          # arena path refuses
    with pytest.raises(RuntimeError, match="closed"):
        opt._pool()                      # executor path refuses too
    after = {t.name for t in threading.enumerate()}
    assert not [n for n in after - before if n.startswith("offload-optim-io")]
    opt.close()                          # idempotent
    eng.close()


# -- memory tracker: coherent queries under concurrent alloc/free -------------

def test_tracker_queries_consistent_under_concurrent_alloc_free():
    """The tracker's query properties lock (regression: a benchmark
    thread sampling peaks mid-alloc paired one side of the
    requested/allocated update; peak_waste went transiently negative)."""
    t = MemoryTracker()
    stop = threading.Event()
    bad: list[tuple] = []

    def churn():
        while not stop.is_set():
            hs = [t.alloc("c", 100, 160) for _ in range(50)]
            for h in hs:
                t.free(h)

    def sample():
        # peak_waste subtracts two peaks inside ONE lock hold — unlocked
        # it read them apart and went transiently negative.  (Distinct
        # properties are separate lock holds, so only per-read coherence
        # is promised, not cross-property invariants.)
        while not stop.is_set():
            waste = t.peak_waste
            live_r, live_a = t.live_requested, t.live_allocated
            if waste < 0 or live_r < 0 or live_a < 0:
                bad.append((waste, live_r, live_a))

    threads = [threading.Thread(target=churn),
               threading.Thread(target=sample)]
    for th in threads:
        th.start()
    timer = threading.Timer(0.5, stop.set)
    timer.start()
    for th in threads:
        th.join()
    timer.cancel()
    assert not bad, f"torn tracker reads: {bad[:3]}"
    t.assert_quiescent()
