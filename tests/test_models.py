"""Per-arch smoke tests (REDUCED configs): fwd/train step + decode, and
decode-vs-parallel consistency for the recurrent families."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, INPUT_SHAPES
from repro.models import build, shape_supported, variant_for_shape
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    batch = {"tokens": jnp.full((b, s), 3, jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.prefix_len:
        batch["image_embeds"] = jnp.ones((b, cfg.prefix_len, cfg.d_model),
                                         jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_train_step(arch):
    """Reduced variant: one fwd/bwd step on CPU; shapes + no NaNs."""
    cfg = ARCHS[arch].reduced()
    impl = build(cfg)
    params = impl.init_params(KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(impl.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_prefill_shapes(arch):
    cfg = ARCHS[arch].reduced()
    impl = build(cfg)
    params = impl.init_params(KEY)
    b, s = 2, 64
    batch = _batch(cfg, b, s)
    logits = jax.jit(impl.prefill_fn)(params, batch)
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    impl = build(cfg)
    params = impl.init_params(KEY)
    b, cache_len = 2, 32
    cache = impl.init_cache(b, cache_len)
    logits, cache2 = jax.jit(impl.decode_fn)(
        params, cache, jnp.full((b, 1), 3, jnp.int32), jnp.int32(cache_len - 1))
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache tree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3-4b", "starcoder2-15b"])
def test_decode_matches_parallel_forward(arch):
    """Sequential decode reproduces the parallel forward logits (dense)."""
    cfg = ARCHS[arch].reduced()
    cfg = dataclasses.replace(cfg, sliding_window=0)
    impl = build(cfg, compute_dtype=jnp.float32)
    params = impl.init_params(KEY)
    s = 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, cfg.vocab)
    full_logits = impl.prefill_fn(params, {"tokens": tokens})

    cache = impl.init_cache(1, s, dtype=jnp.float32)
    step = jax.jit(impl.decode_fn)
    for t in range(s):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[0, 0], np.float32),
            np.asarray(full_logits[0, t], np.float32),
            rtol=1e-3, atol=2e-3)


def test_mamba_decode_matches_scan(rng):
    """Streaming mamba update == chunk-parallel scan, position by position."""
    cfg = ARCHS["jamba-v0.1-52b"].reduced()
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = mamba_mod.init_mamba_params(KEY, cfg)
    b, L = 2, 32
    x = jnp.asarray(rng.standard_normal((b, L, cfg.d_model)), jnp.float32)
    y_par = mamba_mod.mamba_mixer(params, x, cfg)
    cache = {"conv": jnp.zeros((b, cfg.ssm.conv_kernel - 1,
                                cfg.ssm.d_inner(cfg.d_model)), jnp.float32),
             "ssm": jnp.zeros((b, cfg.ssm.d_inner(cfg.d_model),
                               cfg.ssm.d_state), jnp.float32)}
    outs = []
    for t in range(L):
        o, cache = mamba_mod.mamba_decode(params, x[:, t:t + 1], cfg, cache)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_decode_matches_chunked(rng):
    cfg = ARCHS["xlstm-1.3b"].reduced()
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = xlstm_mod.init_mlstm_params(KEY, cfg)
    b, L = 2, 32
    x = jnp.asarray(rng.standard_normal((b, L, cfg.d_model)), jnp.float32)
    y_par = xlstm_mod.mlstm_mixer(params, x, cfg)
    di = cfg.ssm.d_inner(cfg.d_model)
    dk = di // cfg.n_heads
    cache = {"c": jnp.zeros((b, cfg.n_heads, dk, dk), jnp.float32),
             "n": jnp.zeros((b, cfg.n_heads, dk), jnp.float32)}
    outs = []
    for t in range(L):
        o, cache = xlstm_mod.mlstm_decode(params, x[:, t:t + 1], cfg, cache)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_slstm_decode_matches_scan(rng):
    cfg = ARCHS["xlstm-1.3b"].reduced()
    params = xlstm_mod.init_slstm_params(KEY, cfg)
    b, L = 2, 16
    x = jnp.asarray(rng.standard_normal((b, L, cfg.d_model)), jnp.float32)
    y_par = xlstm_mod.slstm_mixer(params, x, cfg)
    hd = cfg.d_model // cfg.n_heads
    z = jnp.zeros((b, cfg.n_heads, hd), jnp.float32)
    cache = {"h": z, "c": z, "n": jnp.ones_like(z)}
    outs = []
    for t in range(L):
        o, cache = xlstm_mod.slstm_decode(params, x[:, t:t + 1], cfg, cache)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_changes_long_range_only(rng):
    """SW attention == full attention for positions < window."""
    from repro.models.attention import gqa_attention
    cfg = dataclasses.replace(ARCHS["qwen3-4b"].reduced(), qk_norm=False)
    from repro.models.transformer import init_layer_params
    p = init_layer_params(KEY, cfg, 0)
    x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)), jnp.float32)
    full = gqa_attention(p, x, cfg, window=0)
    sw = gqa_attention(p, x, cfg, window=16)
    np.testing.assert_allclose(np.asarray(full[:, :16]),
                               np.asarray(sw[:, :16]), atol=1e-5)
    assert float(jnp.abs(full[:, -1] - sw[:, -1]).max()) > 1e-4


def test_variant_for_shape_and_skips():
    long = INPUT_SHAPES["long_500k"]
    for arch, cfg in ARCHS.items():
        ok, reason = shape_supported(cfg, long)
        if arch == "whisper-tiny":
            assert not ok and "enc-dec" in reason
            continue
        v = variant_for_shape(cfg, long)
        if cfg.family in ("dense", "moe", "vlm", "hybrid"):
            assert v.sliding_window > 0, f"{arch} needs sub-quadratic decode"


def test_moe_router_load_balance(rng):
    """Aux loss must penalize a collapsed router more than a uniform one."""
    from repro.models.moe import router_topk
    t, e = 256, 8
    uniform = jnp.zeros((t, e))
    collapsed = jnp.zeros((t, e)).at[:, 0].set(10.0)
    _, _, aux_u = router_topk(uniform, 2)
    _, _, aux_c = router_topk(collapsed, 2)
    assert float(aux_c) > float(aux_u)


def test_moe_capacity_drops_gracefully(rng):
    """Tokens over capacity are dropped (weight 0), never corrupted."""
    import dataclasses as dc
    from repro.models.moe import moe_ffn
    from repro.models.transformer import init_layer_params
    cfg = ARCHS["phi3.5-moe-42b-a6.6b"].reduced()
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=0.1))
    p = init_layer_params(KEY, cfg, 0)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.bfloat16)
    out, aux = moe_ffn(p, x, cfg)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())


def test_moe_split_route_apply_matches_dense_block(rng):
    """The expert-paging split applies (block_route + block_moe with full
    (E, ...) stacks reassembled from the per-expert pages) must reproduce
    the plain block_apply bitwise, and routed-only stacks — zero rows for
    every unrouted expert — must reproduce the full stacks bitwise: the
    combine never reads an unrouted expert's row."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.core.model_adapter import make_offloadable_lm

    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                      moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=32))
    key = jax.random.PRNGKey(0)
    dense_m = make_offloadable_lm(cfg, key)
    paged_m = make_offloadable_lm(cfg, key, expert_paging="routed")
    dense_p = dict(dense_m.units[1].params)
    paged_p = dict(paged_m.units[1].params)

    # few tokens vs many experts so some experts stay unrouted (the zero
    # rows below must actually be exercised)
    h = jax.random.normal(jax.random.PRNGKey(3), (1, 6, cfg.d_model),
                          jnp.float32)
    want = dense_m.block_apply(dense_p, h)

    # full stacks reassembled from the split per-expert pages
    triples = paged_m.expert_meta["block_000"]["experts"]
    full = [np.stack([paged_p.pop(t[j]) for t in triples])
            for j in range(3)]
    hmid, idx = paged_m.block_route(paged_p, h)
    got_full = paged_m.block_moe(paged_p, *full, idx, hmid)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got_full))

    # routed-only stacks: unrouted experts' rows zeroed
    routed_ids = np.unique(np.asarray(idx).reshape(-1))
    routed = [np.where(np.isin(np.arange(cfg.moe.n_experts),
                               routed_ids)[:, None, None], s, 0)
              for s in full]
    assert len(routed_ids) < cfg.moe.n_experts, (
        "batch routed every expert; shrink it so zero rows are exercised")
    got_routed = paged_m.block_moe(paged_p, *routed, idx, hmid)
    np.testing.assert_array_equal(np.asarray(got_full),
                                  np.asarray(got_routed))
