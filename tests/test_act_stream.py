"""Activation-checkpoint streaming (PR 9): ActSaveOp/ActFetchOp plan
lifecycle, per-block act-policy resolution, and the executor's activation
stream under fault injection — a failed SSD write degrades to the host
tier, a failed prefetch surfaces exactly once at the ActFetchOp gate, and
an abort mid-backward drains every in-flight save/fetch, slot, and
tracker handle."""

from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (ActFetchOp, ActSaveOp, ComputeOp, FetchOp,
                        GradWriteOp, OffloadPolicy, OffloadSession,
                        PlanError, ReleaseOp, StreamPlan, compile_train,
                        resolve_act_policy)
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def _model(seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed))


def _batch(batch=2, seq=32, seed=1):
    dl = DataLoader(SyntheticTextDataset(vocab=256, seed=seed), batch=batch,
                    seq_len=seq)
    return dl.next_batch()


def _session(root, tier, overlap="full"):
    policy = (OffloadPolicy.preset("memascend").with_store(root)
              .with_adam(lr=1e-3).with_overlap(overlap)
              .with_activations(tier).build())
    return OffloadSession(_model(), policy)


def _assert_act_drained(s):
    """Abort/close invariant: the activation stream released every
    tracker handle and counted device slot."""
    assert s.tracker.component("activation_checkpoints").live_allocated == 0
    if s._device_slots is not None:
        assert s._device_slots.idle()


# -- plan validator: ActSaveOp / ActFetchOp lifecycle ------------------------

def _plan(*ops):
    return StreamPlan("t", tuple(ops))


_SAVE_CYCLE = (FetchOp("b0"),
               ComputeOp("b0", "block", save_input=True),
               ActSaveOp("b0", "ssd"),
               ReleaseOp("b0"))
_FETCH_CYCLE = (FetchOp("b0"),
                ActFetchOp("b0"),
                ComputeOp("b0", "block_bwd"),
                ReleaseOp("b0"),
                GradWriteOp("b0"))


def test_valid_act_save_fetch_cycle():
    _plan(*_SAVE_CYCLE, *_FETCH_CYCLE)   # validates in __post_init__


def test_act_save_without_checkpoint():
    with pytest.raises(PlanError, match="no saved checkpoint"):
        _plan(FetchOp("b0"), ComputeOp("b0", "block"),
              ActSaveOp("b0", "ssd"), ReleaseOp("b0"))


def test_act_save_twice():
    with pytest.raises(PlanError, match="duplicate activation save"):
        _plan(FetchOp("b0"), ComputeOp("b0", "block", save_input=True),
              ActSaveOp("b0", "ssd"), ActSaveOp("b0", "host"),
              ReleaseOp("b0"))


def test_act_save_rejects_non_offload_tier():
    with pytest.raises(PlanError, match="unknown activation save tier"):
        _plan(FetchOp("b0"), ComputeOp("b0", "block", save_input=True),
              ActSaveOp("b0", "device"), ReleaseOp("b0"))


def test_act_fetch_without_save():
    with pytest.raises(PlanError, match="without an ActSaveOp"):
        _plan(FetchOp("b0"), ComputeOp("b0", "block", save_input=True),
              ActFetchOp("b0"), ComputeOp("b0", "block_bwd"),
              ReleaseOp("b0"), GradWriteOp("b0"))


def test_block_bwd_on_offloaded_checkpoint():
    with pytest.raises(PlanError, match="before its ActFetchOp"):
        _plan(*_SAVE_CYCLE,
              FetchOp("b0"), ComputeOp("b0", "block_bwd"),
              ReleaseOp("b0"), GradWriteOp("b0"))


def test_act_save_never_fetched():
    with pytest.raises(PlanError, match="activation saves never fetched"):
        _plan(*_SAVE_CYCLE)


def test_recompute_source_must_be_device_reachable():
    # b0's checkpoint is offloaded (no ActFetchOp yet): the recompute
    # cannot peek bytes that live on the SSD
    with pytest.raises(PlanError, match="no device-reachable checkpoint"):
        _plan(*_SAVE_CYCLE,
              FetchOp("b0"),
              ComputeOp("b0", "block_recompute", recompute_for="b1"),
              ReleaseOp("b0"))


def test_recompute_target_collision():
    with pytest.raises(PlanError, match="already has a checkpoint"):
        _plan(FetchOp("b0"), ComputeOp("b0", "block", save_input=True),
              FetchOp("b1"), ComputeOp("b1", "block", save_input=True),
              ReleaseOp("b1"),
              ComputeOp("b0", "block_recompute", recompute_for="b1"),
              ReleaseOp("b0"))


def test_recompute_plan_compiles_and_validates():
    model = make_offloadable_lm(
        ModelConfig(name="tiny4", family="dense", n_layers=4, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256),
        jax.random.PRNGKey(0))
    plan = compile_train(model, act_policy="recompute")
    saves = [op for op in plan.ops if isinstance(op, ActSaveOp)]
    fetches = [op for op in plan.ops if isinstance(op, ActFetchOp)]
    recomputes = [op for op in plan.ops if isinstance(op, ComputeOp)
                  and op.kind == "block_recompute"]
    # every-other ladder over 4 blocks: even blocks save to SSD, odd
    # blocks re-run their predecessor's forward
    assert len(saves) == len(fetches) == 2
    assert all(op.tier == "ssd" for op in saves)
    assert len(recomputes) == 2
    assert all(op.recompute_for is not None and not op.save_input
               for op in recomputes)


def test_compile_train_accepts_every_policy_shape():
    model = _model()
    blocks = [f"block_{i:03d}" for i in range(CFG.n_layers)]
    for spec in (None, "host", "ssd", "device", "recompute",
                 {blocks[0]: "ssd"}, ["host", "ssd"]):
        compile_train(model, act_policy=spec)   # must validate


# -- resolve_act_policy chain rules ------------------------------------------

def test_resolve_uniform_and_every_other():
    blocks = ["a", "b", "c", "d"]
    assert resolve_act_policy(blocks, None) == ("host",) * 4
    assert resolve_act_policy(blocks, "ssd") == ("ssd",) * 4
    assert resolve_act_policy(blocks, "recompute") == (
        "ssd", "recompute", "ssd", "recompute")


def test_resolve_dict_defaults_and_unknown_name():
    blocks = ["a", "b"]
    assert resolve_act_policy(blocks, {"b": "ssd"}) == ("host", "ssd")
    with pytest.raises(PlanError, match="unknown blocks"):
        resolve_act_policy(blocks, {"nope": "ssd"})


def test_resolve_sequence_length_and_tier_checks():
    with pytest.raises(PlanError, match="entries for"):
        resolve_act_policy(["a", "b"], ["host"])
    with pytest.raises(PlanError, match="unknown act_policy tier"):
        resolve_act_policy(["a", "b"], ["host", "pmem"])


def test_resolve_block0_cannot_recompute():
    with pytest.raises(PlanError, match="block 0"):
        resolve_act_policy(["a", "b"], ["recompute", "host"])


def test_resolve_consecutive_recompute_rejected():
    with pytest.raises(PlanError, match="consecutive 'recompute'"):
        resolve_act_policy(["a", "b", "c"],
                           ["ssd", "recompute", "recompute"])


# -- executor: loss identity across tiers ------------------------------------

def test_loss_identity_across_tiers(tmp_store_root):
    """host / ssd / recompute / ssd-under-sync run the same floats in the
    same order — losses must match bit for bit."""
    losses = {}
    for name, tier, overlap in (("host", "host", "full"),
                                ("ssd", "ssd", "full"),
                                ("recompute", "recompute", "full"),
                                ("ssd_sync", "ssd", "sync")):
        with _session(f"{tmp_store_root}/{name}", tier, overlap) as s:
            run = []
            for seed in (1, 2):
                b = _batch(seed=seed)
                m = s.train_step(b["tokens"], b["labels"])
                run.append(m["loss"])
                assert m["act_fetch_wait_s"] >= 0.0
                assert m["act_save_wait_s"] >= 0.0
            losses[name] = run
        s.tracker.assert_quiescent()
    assert losses["host"] == losses["ssd"] == losses["recompute"] \
        == losses["ssd_sync"]


# -- executor: fault injection ------------------------------------------------

def test_failed_ssd_write_degrades_to_host_tier(tmp_store_root):
    """An act-store write failure must not fail the step: the host copy
    is re-marked live and the checkpoint serves from the host tier, with
    the same loss as an unbroken run."""
    with _session(f"{tmp_store_root}/clean", "ssd") as s:
        b = _batch()
        clean_loss = s.train_step(b["tokens"], b["labels"])["loss"]
    s.tracker.assert_quiescent()

    with _session(f"{tmp_store_root}/broken", "ssd") as s:
        real_write = s.store.write

        def flaky_write(key, data):
            if key.startswith("__act__/"):
                raise IOError("injected act write failure")
            return real_write(key, data)

        s.store.write = flaky_write
        b = _batch()
        m = s.train_step(b["tokens"], b["labels"])
        assert m["act_write_failures"] == CFG.n_layers
        assert m["loss"] == clean_loss
        _assert_act_drained(s)
    s.tracker.assert_quiescent()


def test_failed_act_prefetch_surfaces_once_at_gate(tmp_store_root):
    """A failed act read is delivered exactly once, at that checkpoint's
    ActFetchOp; the abort drains every slot and handle, and the session
    trains again once the store recovers."""
    with _session(f"{tmp_store_root}/s", "ssd") as s:
        real_read_async = s.store.read_async

        def failing_read_async(key, out):
            if key.startswith("__act__/"):
                f = Future()
                f.set_exception(IOError("injected act read failure"))
                return f
            return real_read_async(key, out)

        s.store.read_async = failing_read_async
        b = _batch()
        with pytest.raises(IOError, match="injected act read"):
            s.train_step(b["tokens"], b["labels"])
        assert len(s.swapper._inflight) == 0
        _assert_act_drained(s)

        s.store.read_async = real_read_async
        m = s.train_step(b["tokens"], b["labels"])   # recovered
        assert np.isfinite(m["loss"])
        _assert_act_drained(s)
    s.tracker.assert_quiescent()


def test_act_read_submit_failure_does_not_leak(tmp_store_root):
    """read_async raising *synchronously* (queue-full analogue) fails at
    the issue site — the staging buffer's tracker handle must still be
    freed (the analyzer's resource-lifecycle contract on the act path)."""
    with _session(f"{tmp_store_root}/s", "ssd") as s:
        def exploding_read_async(key, out):
            raise RuntimeError("injected submit failure")

        s.store.read_async = exploding_read_async
        b = _batch()
        with pytest.raises(RuntimeError, match="injected submit"):
            s.train_step(b["tokens"], b["labels"])
        _assert_act_drained(s)
    s.tracker.assert_quiescent()


def test_abort_mid_backward_drains_act_stream(tmp_store_root):
    """block_bwd failing mid-backward aborts with saves resolved, staged
    fetches waited out, and activation live bytes back to zero."""
    with _session(f"{tmp_store_root}/s", "ssd") as s:
        calls = {"n": 0}
        real_bwd = s._jit_block_bwd

        def flaky_bwd(*a, **kw):
            calls["n"] += 1
            if calls["n"] == 1:    # first block_bwd: acts still in flight
                raise RuntimeError("injected backward failure")
            return real_bwd(*a, **kw)

        s._jit_block_bwd = flaky_bwd
        b = _batch()
        with pytest.raises(RuntimeError, match="injected backward"):
            s.train_step(b["tokens"], b["labels"])
        assert len(s.swapper._inflight) == 0
        _assert_act_drained(s)
    s.tracker.assert_quiescent()
