"""Fault injection for the expert-paging path: a failed expert SSD read
must surface exactly once at its fetch gate with every claimed device slot
and page pin released, and an abort mid-step must drain in-flight expert
stages back to a quiescent session.

Every scenario finishes with a RECOVERY step — the strongest leak probe:
a leaked ``__expert__`` device slot wedges the next stage's acquire, a
leaked page pin blows up the optimizer's ``invalidate_unit``, and a torn
``expert_slots_out`` counter deadlocks the on-demand fetch, so a clean
follow-up ``train_step`` after the fault proves all three ledgers healed.
Runs under the suite-wide worker-thread leak guard and the
``--lock-witness`` CI matrix.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import DecodeSpec, OffloadSession, memascend_policy
from repro.core.model_adapter import make_offloadable_lm

CFG = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                  moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32))


def _model(mode="routed", seed=0):
    return make_offloadable_lm(CFG, jax.random.PRNGKey(seed),
                               expert_paging=mode)


def _policy(root, mode="routed", overlap="full"):
    return memascend_policy(root, lr=1e-2).replace(
        expert_paging=mode, expert_page_slots=8, overlap=overlap)


def _batch(seed=1):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, CFG.vocab, (2, 16)).astype(np.int32),
            rng.integers(0, CFG.vocab, (2, 16)).astype(np.int32))


class _FaultyRead:
    """Store wrapper whose ``read`` raises for expert compute pages while
    ``armed``, counting how many times the fault actually fired."""

    def __init__(self, inner, *, fail_on_call=1):
        self._inner = inner
        self.armed = True
        self.fired = 0
        self._calls = 0
        self._fail_on = fail_on_call

    def read(self, key, view):
        if self.armed and "moe.expert" in key:
            self._calls += 1
            if self._calls >= self._fail_on:
                self.fired += 1
                raise IOError(f"injected expert read failure: {key}")
        return self._inner.read(key, view)

    def __getattr__(self, name):
        return getattr(self._inner, name)


# -- failed expert SSD read ---------------------------------------------------

@pytest.mark.parametrize("overlap", ["sync", "full"])
def test_failed_expert_read_surfaces_once_and_releases(tmp_store_root,
                                                       overlap):
    """The very first expert page read fails: the error must surface
    exactly once at the fetch gate (the staging worker holds no device
    slot — stacks build precedes the acquire) and leave no slot, pin, or
    counter behind, proven by a clean recovery step."""
    tokens, labels = _batch()
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap=overlap))
    try:
        faulty = _FaultyRead(s.store)
        s.store = faulty
        s._expert_cache.store = faulty
        with pytest.raises(IOError, match="injected expert read"):
            s.train_step(tokens, labels)
        assert faulty.fired == 1, (
            "fault must fire once and propagate, not be retried/swallowed")
        # drain left nothing claimed (sync mode has no device-slot budget)
        assert len(s.swapper._inflight) == 0
        assert s._device_slots is None or s._device_slots.idle()
        assert s.tracker.component(
            "activation_checkpoints").live_allocated == 0
        # recovery: with the fault disarmed the same session trains —
        # a leaked __expert__ slot or pin would wedge or raise here
        faulty.armed = False
        m = s.train_step(tokens, labels)
        assert np.isfinite(m["loss"])
    finally:
        s.close()
    s.tracker.assert_quiescent()


def test_failed_read_mid_gather_unpins_earlier_pages(tmp_store_root):
    """Failure on the THIRD expert page read: the pages already gathered
    into the stack were pinned and must be unpinned on the error path, or
    the optimizer's invalidate_unit (and close) would refuse."""
    tokens, labels = _batch()
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap="full"))
    try:
        faulty = _FaultyRead(s.store, fail_on_call=3)
        s.store = faulty
        s._expert_cache.store = faulty
        with pytest.raises(IOError, match="injected expert read"):
            s.train_step(tokens, labels)
        assert faulty.fired == 1
        assert s._device_slots.idle()
        faulty.armed = False
        losses = [s.train_step(tokens, labels)["loss"] for _ in range(2)]
        assert all(np.isfinite(x) for x in losses)
    finally:
        s.close()
    s.tracker.assert_quiescent()


def test_failed_read_on_prestaged_step_drops_staged_slot(tmp_store_root):
    """Fault armed only from the SECOND step: step 1 seeds the routing
    prior, so step 2's window prestages expert stacks whose build fails on
    the staging worker.  The failure must surface at that step's fetch
    gate and still release the EXPERT_CLASS budget."""
    tokens, labels = _batch()
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap="full"))
    try:
        faulty = _FaultyRead(s.store)
        faulty.armed = False
        s.store = faulty
        s._expert_cache.store = faulty
        m = s.train_step(tokens, labels)         # seeds _expert_prior
        assert np.isfinite(m["loss"])
        # evict every cached page so step 2 must hit SSD again
        for unit in s._expert_meta:
            s._expert_cache.invalidate_unit(unit)
        faulty.armed = True
        with pytest.raises(IOError, match="injected expert read"):
            s.train_step(tokens, labels)
        assert faulty.fired >= 1
        assert s._device_slots.idle()
        assert len(s.swapper._inflight) == 0
        faulty.armed = False
        m = s.train_step(tokens, labels)
        assert np.isfinite(m["loss"])
    finally:
        s.close()
    s.tracker.assert_quiescent()


# -- abort mid-step -----------------------------------------------------------

def test_abort_mid_step_drains_expert_stages(tmp_store_root):
    """A compute failure while later units' expert prestages are still in
    flight on the staging worker: the abort drain must consume those
    futures and return their __expert__ slots, leaving live_allocated==0
    for the step's transient components and a session that still trains."""
    tokens, labels = _batch()
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap="full"))
    try:
        m = s.train_step(tokens, labels)   # warm: prior + prestage window
        assert np.isfinite(m["loss"])
        calls = {"n": 0}
        real_moe = s._jit_block_moe

        def flaky_moe(*a):
            calls["n"] += 1
            if calls["n"] == 1:    # first MoE block of step 2: the next
                raise RuntimeError("injected moe failure")  # stage in flight
            return real_moe(*a)

        s._jit_block_moe = flaky_moe
        with pytest.raises(RuntimeError, match="injected moe"):
            s.train_step(tokens, labels)
        s._jit_block_moe = real_moe
        assert len(s.swapper._inflight) == 0
        assert s._device_slots.idle(), "abort leaked an __expert__ slot"
        # only cache-resident pages may still hold pool buffers
        assert len(s._expert_cache.resident_pages) <= 8
        assert s.tracker.component(
            "activation_checkpoints").live_allocated == 0
        m = s.train_step(tokens, labels)
        assert np.isfinite(m["loss"])
    finally:
        s.close()
    s.tracker.assert_quiescent()


def test_abort_during_decode_releases_expert_slots(tmp_store_root):
    """Same drain contract on the serve path: a failing decode step with
    expert stacks staged must release them and leave the KV cache usable."""
    tokens, labels = _batch()
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap="full"),
                       decode=DecodeSpec(batch=2, max_seq=64))
    try:
        s.train_step(tokens, labels)
        kv = s.open_kv_cache()
        try:
            logits = s.prefill(kv, tokens[:, :8])
            nxt = np.argmax(logits, axis=-1).astype(np.int32)[:, None]
            real_step = s._jit_step_route
            calls = {"n": 0}

            def flaky_step(*a, **kw):
                calls["n"] += 1
                if calls["n"] == 1:
                    raise RuntimeError("injected decode failure")
                return real_step(*a, **kw)

            s._jit_step_route = flaky_step
            with pytest.raises(RuntimeError, match="injected decode"):
                s.decode_step(kv, nxt)
            s._jit_step_route = real_step
            assert s._device_slots.idle()
            # the same KV cache still decodes after the drain
            out = s.decode_step(kv, nxt)
            assert out.shape[0] == 2
        finally:
            kv.close()
    finally:
        s.close()
    s.tracker.assert_quiescent()


def test_close_with_fault_still_quiesces(tmp_store_root):
    """Closing right after a failed step runs every teardown step: the
    expert cache closes (dropping resident pages), the arena returns, and
    the tracker ends quiescent."""
    tokens, labels = _batch()
    s = OffloadSession(_model(), _policy(tmp_store_root, overlap="h2d"))
    faulty = _FaultyRead(s.store)
    s.store = faulty
    s._expert_cache.store = faulty
    with pytest.raises(IOError, match="injected expert read"):
        s.train_step(tokens, labels)
    s.close()
    s.tracker.assert_quiescent()
    assert s.pool.in_use_payload == 0
