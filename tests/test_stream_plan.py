"""StreamPlan IR: compilers produce lifecycle-valid schedules; the
validator rejects anything violating checkout→compute→release (§IV-A)."""

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core import (ComputeOp, FetchOp, GradWriteOp, OptimStepOp,
                        OverflowCheckOp, PlanError, ReleaseOp,
                        StreamPlan, compile_decode, compile_eval,
                        compile_train)
from repro.core.model_adapter import make_offloadable_lm

CFG = ModelConfig(name="tiny", family="dense", n_layers=3, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


@pytest.fixture(scope="module")
def model():
    return make_offloadable_lm(CFG, jax.random.PRNGKey(0))


def test_train_plan_structure(model):
    plan = compile_train(model)
    blocks = [f"block_{i:03d}" for i in range(CFG.n_layers)]
    # forward fetch order, then head, then reverse blocks, then embed again
    assert plan.fetch_order == tuple(
        ["embed"] + blocks + ["head"] + blocks[::-1] + ["embed"])
    # every unit's grads are written exactly once
    writes = [op.unit for op in plan.ops if isinstance(op, GradWriteOp)]
    assert sorted(writes) == sorted(["embed", "head"] + blocks)
    # forward blocks checkpoint their inputs; backward blocks restore them
    fwd = [op for op in plan.ops
           if isinstance(op, ComputeOp) and op.kind == "block"]
    bwd = [op for op in plan.ops
           if isinstance(op, ComputeOp) and op.kind == "block_bwd"]
    assert all(op.save_input for op in fwd)
    assert len(fwd) == len(bwd) == CFG.n_layers


def test_eval_and_decode_plans(model):
    ev = compile_eval(model)
    assert ev.fetch_order[0] == "embed" and ev.fetch_order[-1] == "head"
    assert not any(isinstance(op, GradWriteOp) for op in ev.ops)
    assert not any(isinstance(op, ComputeOp) and op.save_input
                   for op in ev.ops)
    dec = compile_decode(model)
    assert dec.fetch_order == ev.fetch_order
    kinds = [op.kind for op in dec.ops if isinstance(op, ComputeOp)]
    assert kinds[-1] == "head_logits"


def test_decode_requires_head_logits(model):
    import dataclasses
    headless = dataclasses.replace(model, head_logits=None)
    with pytest.raises(PlanError, match="head_logits"):
        compile_decode(headless)


def test_validator_compute_before_fetch():
    with pytest.raises(PlanError, match="non-resident"):
        StreamPlan("bad", (ComputeOp("u", "block"),))


def test_validator_double_fetch():
    with pytest.raises(PlanError, match="already-resident"):
        StreamPlan("bad", (FetchOp("u"), FetchOp("u")))


def test_validator_leaked_fetch():
    with pytest.raises(PlanError, match="never released"):
        StreamPlan("bad", (FetchOp("u"),))


def test_validator_release_non_resident():
    with pytest.raises(PlanError, match="release of non-resident"):
        StreamPlan("bad", (ReleaseOp("u"),))


def test_validator_grad_write_without_grads():
    with pytest.raises(PlanError, match="no grads produced"):
        StreamPlan("bad", (FetchOp("u"), ComputeOp("u", "block"),
                           ReleaseOp("u"), GradWriteOp("u")))


def test_validator_bwd_without_checkpoint():
    with pytest.raises(PlanError, match="no saved checkpoint"):
        StreamPlan("bad", (FetchOp("u"), ComputeOp("u", "block_bwd"),
                           ReleaseOp("u"), GradWriteOp("u")))


def test_validator_leaked_checkpoint():
    with pytest.raises(PlanError, match="never restored"):
        StreamPlan("bad", (FetchOp("u"),
                           ComputeOp("u", "block", save_input=True),
                           ReleaseOp("u")))


def test_validator_double_checkpoint():
    with pytest.raises(PlanError, match="already has a saved checkpoint"):
        StreamPlan("bad", (FetchOp("u"),
                           ComputeOp("u", "block", save_input=True),
                           ComputeOp("u", "block", save_input=True),
                           ReleaseOp("u")))


def test_validator_unknown_kind():
    with pytest.raises(PlanError, match="unknown compute kind"):
        StreamPlan("bad", (FetchOp("u"), ComputeOp("u", "frobnicate"),
                           ReleaseOp("u")))


# -- overflow + optimizer ops (the in-plan training tail) --------------------

def _graded_unit(unit="u"):
    """fetch → block_bwd-style grad producer → release → grad write."""
    return (FetchOp(unit), ComputeOp(unit, "head_loss_grad"),
            ReleaseOp(unit), GradWriteOp(unit))


def test_train_plan_has_overflow_then_optim_in_next_fetch_order(model):
    plan = compile_train(model)
    blocks = [f"block_{i:03d}" for i in range(CFG.n_layers)]
    kinds = [type(op).__name__ for op in plan.ops]
    # exactly one overflow check, after every grad write
    assert kinds.count("OverflowCheckOp") == 1
    check_at = kinds.index("OverflowCheckOp")
    assert all(i < check_at for i, op in enumerate(plan.ops)
               if isinstance(op, GradWriteOp))
    # optimizer steps trail it, ordered by the NEXT step's fetch order so
    # cross-step pipelining unblocks the earliest-needed weights first
    optim = [op.unit for op in plan.ops if isinstance(op, OptimStepOp)]
    assert optim == ["embed"] + blocks + ["head"]
    assert all(isinstance(op, OptimStepOp) for op in plan.ops[check_at + 1:])


def test_validator_duplicate_overflow_check():
    with pytest.raises(PlanError, match="duplicate overflow check"):
        StreamPlan("bad", _graded_unit() + (OverflowCheckOp(),
                                            OverflowCheckOp()))


def test_validator_overflow_check_needs_written_grads():
    with pytest.raises(PlanError, match="no grads written"):
        StreamPlan("bad", (OverflowCheckOp(),))


def test_validator_overflow_check_with_unwritten_grads():
    with pytest.raises(PlanError, match="unwritten grads"):
        StreamPlan("bad", _graded_unit("u") + (
            FetchOp("v"), ComputeOp("v", "head_loss_grad"), ReleaseOp("v"),
            OverflowCheckOp(), GradWriteOp("v")))


def test_validator_grad_write_after_overflow_check():
    # (same shape as above but the message for the *write* must also fire
    # when the producer wrote before the check and a second unit after it)
    with pytest.raises(PlanError, match="unwritten grads|after the overflow"):
        StreamPlan("bad", _graded_unit("u")
                   + (FetchOp("v"), ComputeOp("v", "head_loss_grad"),
                      ReleaseOp("v"))
                   + (OverflowCheckOp(), GradWriteOp("v")))


def test_validator_optim_before_overflow_check():
    with pytest.raises(PlanError, match="before the overflow check"):
        StreamPlan("bad", _graded_unit() + (OptimStepOp("u"),))


def test_validator_optim_needs_written_grads():
    with pytest.raises(PlanError, match="no written grads"):
        StreamPlan("bad", _graded_unit("u") + (OverflowCheckOp(),
                                               OptimStepOp("v")))


def test_validator_duplicate_optim_step():
    with pytest.raises(PlanError, match="duplicate optimizer step"):
        StreamPlan("bad", _graded_unit() + (OverflowCheckOp(),
                                            OptimStepOp("u"),
                                            OptimStepOp("u")))


def test_validator_optim_while_resident():
    with pytest.raises(PlanError, match="resident"):
        StreamPlan("bad", _graded_unit("u") + (
            OverflowCheckOp(), FetchOp("u"), OptimStepOp("u"),
            ReleaseOp("u")))


# -- per-region overflow screen (OverflowCheckOp.regions) --------------------

def test_train_plan_screens_every_written_region_in_write_order(model):
    plan = compile_train(model)
    check = next(op for op in plan.ops if isinstance(op, OverflowCheckOp))
    writes = [op.unit for op in plan.ops if isinstance(op, GradWriteOp)]
    assert list(check.regions) == writes
    blocks = [f"block_{i:03d}" for i in range(CFG.n_layers)]
    assert list(check.regions) == ["head"] + blocks[::-1] + ["embed"]


def test_validator_regions_must_match_write_order():
    with pytest.raises(PlanError, match="per-region screen order"):
        StreamPlan("bad", _graded_unit("u") + _graded_unit("v")
                   + (OverflowCheckOp(regions=("v", "u")),))


def test_validator_regions_must_cover_every_written_unit():
    with pytest.raises(PlanError, match="per-region screen order"):
        StreamPlan("bad", _graded_unit("u") + _graded_unit("v")
                   + (OverflowCheckOp(regions=("u",)),))


def test_validator_regions_reject_unwritten_unit():
    with pytest.raises(PlanError, match="per-region screen order"):
        StreamPlan("bad", _graded_unit("u")
                   + (OverflowCheckOp(regions=("u", "ghost")),))


def test_validator_regions_reject_duplicates():
    with pytest.raises(PlanError, match="per-region screen order"):
        StreamPlan("bad", _graded_unit("u") + _graded_unit("v")
                   + (OverflowCheckOp(regions=("u", "u", "v")),))


def test_validator_empty_regions_keep_whole_buffer_scan_valid():
    # the chained-baseline policy's legacy barrier scan: still a valid plan
    plan = StreamPlan("ok", _graded_unit("u") + (OverflowCheckOp(),))
    check = plan.ops[-1]
    assert check.regions == ()
