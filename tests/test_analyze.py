"""The concurrency-contract static analyzer (:mod:`tools.analyze`):
fixture corpus (must-flag / must-pass), suppression scoping, baseline
round-trip, and the src/repro clean gate."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))  # `tools` lives at the repo root

from tools.analyze import Finding, Project, run_checkers  # noqa: E402

FIXTURES = REPO / "tools" / "analyze" / "fixtures"

# filename -> exact multiset of checker ids the corpus file must produce
MUST_FLAG = {
    "evict_during_copy.py": ["lock-blocking", "lock-blocking"],
    "pool_oversubscription.py": ["lock-discipline", "lock-discipline",
                                 "resource-lifecycle"],
    "affinity_cross_call.py": ["thread-affinity", "thread-affinity"],
    "act_d2h_on_executor.py": ["thread-affinity", "thread-affinity"],
    "holds_contract.py": ["lock-blocking"],
    "annotations.py": ["annotation", "annotation"],
    "expert_fetch_under_lock.py": ["lock-blocking", "lock-blocking"],
}


def _findings(path: Path) -> list[Finding]:
    return run_checkers(Project.load([path], root=REPO))


def test_corpus_is_complete():
    present = {p.name for p in (FIXTURES / "must_flag").glob("*.py")}
    assert present == set(MUST_FLAG), (
        "every must_flag fixture needs an expectation here (and vice versa)")


@pytest.mark.parametrize("name", sorted(MUST_FLAG))
def test_must_flag(name):
    found = _findings(FIXTURES / "must_flag" / name)
    assert sorted(f.checker for f in found) == sorted(MUST_FLAG[name]), (
        "\n".join(f.format() for f in found) or "(no findings)")


@pytest.mark.parametrize("path", sorted(
    (FIXTURES / "must_pass").glob("*.py")), ids=lambda p: p.name)
def test_must_pass(path):
    found = _findings(path)
    assert not found, "\n".join(f.format() for f in found)


# -- the two historical PR 5 races, pinned by message ------------------------

def test_evict_during_copy_race_is_store_io_under_lock():
    found = _findings(FIXTURES / "must_flag" / "evict_during_copy.py")
    spill = [f for f in found if f.symbol == "EvictingCache.spill"]
    assert len(spill) == 1
    assert "store I/O" in spill[0].message
    assert "self._lock" in spill[0].message


def test_pool_oversubscription_race_is_leak_plus_unguarded_counter():
    found = _findings(FIXTURES / "must_flag" / "pool_oversubscription.py")
    by = {f.checker: f for f in found}
    assert "can leak" in by["resource-lifecycle"].message
    assert "self.pool.acquire" in by["resource-lifecycle"].message
    assert "without holding self._lock" in by["lock-discipline"].message
    # both declaration syntaxes produced a finding: trailing comment
    # (in_flight) and the GUARDED_BY registry (pending)
    fields = {f.message.split()[2] for f in found
              if f.checker == "lock-discipline"}
    assert fields == {"self.in_flight", "self.pending"}


# -- suppression scoping ------------------------------------------------------

def test_suppression_is_checker_scoped(tmp_path):
    """An ignore[] for one checker must not silence another on the same
    line: strip the lifecycle suppression's checker id to lock-blocking
    and the lifecycle finding reappears."""
    src = (FIXTURES / "must_pass" / "suppressed.py").read_text()
    broken = src.replace("ignore[resource-lifecycle]", "ignore[lock-blocking]")
    p = tmp_path / "mis_suppressed.py"
    p.write_text(broken)
    found = _findings(p)
    assert [f.checker for f in found] == ["resource-lifecycle"]


# -- finding identity ---------------------------------------------------------

def test_fingerprint_ignores_line_numbers():
    a = Finding("m.py", 10, "lock-blocking", "C.f", "blocking call X")
    b = Finding("m.py", 99, "lock-blocking", "C.f", "blocking call X")
    c = Finding("m.py", 10, "lock-blocking", "C.f", "blocking call Y")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != c.fingerprint


# -- CLI: baseline round-trip and the clean-tree gate -------------------------

def _run_cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.analyze", *argv],
        cwd=cwd, capture_output=True, text=True)


def test_cli_flags_corpus_and_baseline_accepts_it(tmp_path):
    target = str(FIXTURES / "must_flag" / "evict_during_copy.py")
    baseline = tmp_path / "baseline.json"

    raw = _run_cli(target, "--no-baseline")
    assert raw.returncode == 1, raw.stdout + raw.stderr
    assert "lock-blocking" in raw.stdout

    wrote = _run_cli(target, "--baseline", str(baseline), "--write-baseline")
    assert wrote.returncode == 0, wrote.stdout + wrote.stderr
    assert len(json.loads(baseline.read_text())["findings"]) == 2

    accepted = _run_cli(target, "--baseline", str(baseline))
    assert accepted.returncode == 0, accepted.stdout + accepted.stderr
    assert "2 baselined" in accepted.stderr


def test_src_repro_is_clean():
    """The acceptance gate: zero unsuppressed findings in the shipped
    pipeline, without leaning on the committed baseline (which is empty
    and must stay that way)."""
    res = _run_cli("src/repro", "--no-baseline")
    assert res.returncode == 0, res.stdout + res.stderr
    committed = json.loads(
        (REPO / "tools" / "analyze" / "baseline.json").read_text())
    assert committed["findings"] == []
