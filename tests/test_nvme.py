"""Tensor stores: per-tensor-file baseline vs direct-LBA engine (§III-D/IV-E)."""

import threading

import numpy as np
import ml_dtypes
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import DirectNVMeEngine, FilesystemEngine


def make_engines(root):
    return [
        FilesystemEngine(root + "/fs", fsync=False),
        DirectNVMeEngine(root + "/raw", n_devices=3,
                         device_capacity=1 << 26, min_stripe=1 << 12),
    ]


@pytest.mark.parametrize("engine_idx", [0, 1])
def test_roundtrip_and_update(engine_idx, tmp_store_root, rng):
    st_ = make_engines(tmp_store_root)[engine_idx]
    x = rng.standard_normal((333, 57)).astype(np.float32)
    st_.write("w/a", x)
    assert st_.contains("w/a")
    np.testing.assert_array_equal(st_.read_new("w/a", np.float32, x.shape), x)
    x2 = x * -1
    st_.write("w/a", x2)   # in-place update (same LBA extents)
    np.testing.assert_array_equal(st_.read_new("w/a", np.float32, x.shape), x2)
    st_.close()


@pytest.mark.parametrize("engine_idx", [0, 1])
def test_bfloat16_roundtrip(engine_idx, tmp_store_root, rng):
    st_ = make_engines(tmp_store_root)[engine_idx]
    x = rng.standard_normal(1000).astype(ml_dtypes.bfloat16)
    st_.write("bf", x)
    got = st_.read_new("bf", ml_dtypes.bfloat16, x.shape)
    np.testing.assert_array_equal(got.view(np.uint16), x.view(np.uint16))
    st_.close()


def test_striping_extents_disjoint(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=2,
                           device_capacity=1 << 24, min_stripe=1 << 12)
    big = rng.integers(0, 255, size=1 << 20, dtype=np.uint8)
    eng.write("big", big)
    _, _, extents = eng._locations["big"]
    assert len(extents) == 2                      # striped across devices
    assert {e.device for e in extents} == {0, 1}
    # write a second tensor; no overlap on any device
    eng.write("big2", big)
    _, _, e2 = eng._locations["big2"]
    for a in extents:
        for b in e2:
            if a.device == b.device:
                assert a.offset + a.length <= b.offset or \
                    b.offset + b.length <= a.offset
    np.testing.assert_array_equal(eng.read_new("big", np.uint8, big.shape),
                                  big)
    eng.close()


def test_capacity_exhaustion(tmp_store_root):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 16)
    eng.write("a", np.zeros(1 << 14, np.uint8))
    with pytest.raises(IOError, match="full"):
        for i in range(10):
            eng.write(f"b{i}", np.zeros(1 << 14, np.uint8))
    eng.close()


def test_size_change_rejected(tmp_store_root):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 24)
    eng.write("a", np.zeros(100, np.float32))
    with pytest.raises(ValueError, match="size change"):
        eng.write("a", np.zeros(200, np.float32))
    eng.close()


def test_concurrent_distinct_tensors(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=2,
                           device_capacity=1 << 26)
    data = {f"t{i}": rng.standard_normal(10_000).astype(np.float32)
            for i in range(8)}
    threads = [threading.Thread(target=eng.write, args=(k, v))
               for k, v in data.items()]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for k, v in data.items():
        np.testing.assert_array_equal(eng.read_new(k, np.float32, v.shape), v)
    eng.close()


def test_async_api(tmp_store_root, rng):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=2,
                           device_capacity=1 << 24)
    x = rng.standard_normal(5000).astype(np.float32)
    eng.write_async("x", x).result()
    out = np.empty_like(x)
    eng.read_async("x", out).result()
    np.testing.assert_array_equal(out, x)
    eng.close()


def test_io_stats_volume(tmp_store_root):
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1,
                           device_capacity=1 << 24)
    x = np.zeros(1000, np.float32)
    eng.write("x", x)
    eng.read_new("x", np.float32, x.shape)
    assert eng.stats.bytes_written == 4000
    assert eng.stats.bytes_read == 4000
    eng.close()


def test_close_shuts_down_async_pool_threads(tmp_store_root, rng):
    """Every engine's lazily-created async executor must die with close():
    the base class owns the shutdown, so a FilesystemEngine (which adds no
    close() of its own) no longer leaks up to 4 '-aio' threads per
    open/close cycle.  (The census itself is conftest.py's autouse
    worker_thread_leak_guard; this test just exercises the cycles.)"""
    x = rng.standard_normal(1000).astype(np.float32)
    for cycle in range(3):
        for eng in make_engines(tmp_store_root + f"/c{cycle}"):
            eng.write_async("t", x).result()     # spin the lazy pool up
            out = np.empty_like(x)
            eng.read_async("t", out).result()
            np.testing.assert_array_equal(out, x)
            eng.close()


def test_async_pool_not_shared_across_instances(tmp_store_root, rng):
    """The executor must be per-instance state, not a mutated class
    attribute: closing one store cannot tear down another's I/O threads."""
    a = FilesystemEngine(tmp_store_root + "/a", fsync=False)
    b = FilesystemEngine(tmp_store_root + "/b", fsync=False)
    x = rng.standard_normal(100).astype(np.float32)
    a.write_async("t", x).result()
    b.write_async("t", x).result()
    assert a._async_pool is not b._async_pool
    a.close()
    out = np.empty_like(x)
    b.read_async("t", out).result()       # b's pool survived a.close()
    np.testing.assert_array_equal(out, x)
    b.close()


def test_concurrent_small_writes_round_robin_no_lost_updates(
        tmp_store_root, rng):
    """Small (sub-min_stripe) tensors placed from concurrent write_async
    workers: the round-robin bump is a read-modify-write that must be
    atomic (lost updates skewed device balance), and every extent must
    stay disjoint per device."""
    eng = DirectNVMeEngine(tmp_store_root, n_devices=3,
                           device_capacity=1 << 24, min_stripe=1 << 20)
    n = 48
    data = {f"t{i}": rng.standard_normal(256).astype(np.float32)
            for i in range(n)}
    futures = [eng.write_async(k, v) for k, v in data.items()]
    for f in futures:
        f.result()
    assert eng._rr == n                  # no lost round-robin increments
    by_dev: dict[int, list] = {}
    for key in data:
        (_, _, extents) = eng._locations[key]
        assert len(extents) == 1         # small tensors never stripe
        by_dev.setdefault(extents[0].device, []).append(extents[0])
    for extents in by_dev.values():
        extents.sort(key=lambda e: e.offset)
        for a, b in zip(extents, extents[1:], strict=False):
            assert a.offset + a.length <= b.offset
    for k, v in data.items():
        np.testing.assert_array_equal(eng.read_new(k, np.float32, v.shape), v)
    eng.close()


def test_short_read_raises_descriptive_ioerror(tmp_store_root):
    """A truncated region read must fail as IOError naming the device and
    offset, not as an opaque ValueError from the stripe-buffer assignment."""
    cap = 1 << 16
    eng = DirectNVMeEngine(tmp_store_root, n_devices=1, device_capacity=cap)
    x = np.zeros(1000, np.float32)
    eng.write("t", x)
    dtype, shape, extents = eng._locations["t"]
    from repro.core.nvme import Extent
    # point the extent at the very end of the preallocated region: pread
    # comes back short instead of failing outright
    eng._locations["t"] = (dtype, shape,
                           [Extent(0, cap - 64, extents[0].length)])
    with pytest.raises(IOError, match="short pread on device 0"):
        eng.read_new("t", np.float32, x.shape)
    eng.close()


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(shape=st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                      max_size=3),
       dtype=st.sampled_from([np.float32, np.float16, np.int32, np.uint8]),
       seed=st.integers(min_value=0, max_value=2**31))
def test_roundtrip_property(tmp_path_factory, shape, dtype, seed):
    root = str(tmp_path_factory.mktemp("prop"))
    eng = DirectNVMeEngine(root, n_devices=2, device_capacity=1 << 22)
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(shape) * 100).astype(dtype)
    eng.write("t", x)
    np.testing.assert_array_equal(eng.read_new("t", dtype, tuple(shape)), x)
    eng.close()
