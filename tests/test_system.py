"""End-to-end system behaviour: the full MemAscend stack working together,
reproducing the paper's headline claims at container scale."""

import jax
import numpy as np

from repro.configs import ARCHS, PAPER_MODELS
from repro.configs.base import ModelConfig
from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        FixedBufferPool, MemoryTracker,
                        OffloadedTrainer, PowerOfTwoCachingAllocator,
                        memascend_policy, zero_infinity_policy)
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset


def test_full_stack_memascend_vs_baseline(tmp_path):
    """The paper's end-to-end claim, at container scale: same losses,
    substantially lower peak host memory, lower overflow-check cost."""
    cfg = ModelConfig(name="sys", family="dense", n_layers=3, d_model=96,
                      n_heads=4, n_kv_heads=2, d_ff=192, vocab=512)

    def run(policy):
        model = make_offloadable_lm(cfg, jax.random.PRNGKey(7))
        tr = OffloadedTrainer(model, policy)
        dl = DataLoader(SyntheticTextDataset(vocab=512, seed=3), batch=4,
                        seq_len=48)
        losses = []
        for _ in range(6):
            b = dl.next_batch()
            losses.append(tr.train_step(b["tokens"], b["labels"])["loss"])
        peak = tr.tracker.peak_allocated
        overflow_peak = tr.tracker.component("overflow_tmp").peak_allocated
        tr.close()
        return losses, peak, overflow_peak

    l_m, peak_m, ovf_m = run(memascend_policy(str(tmp_path / "m"), lr=1e-3))
    l_z, peak_z, ovf_z = run(zero_infinity_policy(str(tmp_path / "z"),
                                                  lr=1e-3))
    np.testing.assert_allclose(l_m, l_z, atol=1e-6)        # Fig. 19
    assert peak_m < 0.8 * peak_z                            # Fig. 15 (scaled)
    # Fig. 13: fused check is chunk-bounded (<=4 MiB) regardless of model
    # size, while baseline scales at 1.25x the flat buffer; at this tiny
    # scale the flat buffer is smaller than one chunk, so assert the bound
    # and the ordering rather than the at-scale 10x ratio.
    assert ovf_m <= 4 << 20
    assert ovf_m < ovf_z


def test_peak_memory_accounting_at_paper_scale():
    """Run the ALLOCATION POLICIES (accounting mode, no real buffers) at the
    paper's 8B scale and check the waste ordering it reports."""
    cfg = PAPER_MODELS["llama3.1-8b"]
    census = cfg.pool_census(inflight_blocks=2, shards=2)  # 2-GPU setup

    def peak_for(alloc_cls, pool_cls):
        t = MemoryTracker()
        alloc = alloc_cls(tracker=t, component="pinned")
        pool = pool_cls(census, alloc)
        # gradient flat buffer, fp32, whole model (paper §III-C)
        flat = alloc.alloc(cfg.param_count() * 4 // 2)     # per-rank shard
        pool.close(); flat.free()
        return t.peak_allocated

    baseline = peak_for(PowerOfTwoCachingAllocator, FixedBufferPool)
    memascend = peak_for(AlignmentFreeAllocator, AdaptiveBufferPool)
    saving = 1 - memascend / baseline
    # paper: ~50.9% peak saving for Llama3.1-8B (Fig. 15); accept a band
    assert saving > 0.30, f"saving {saving:.1%}"


def test_leak_free_after_training(tmp_path):
    cfg = ModelConfig(name="leak", family="dense", n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=1, d_ff=128, vocab=128)
    model = make_offloadable_lm(cfg, jax.random.PRNGKey(0))
    tr = OffloadedTrainer(model, memascend_policy(str(tmp_path), lr=1e-3))
    dl = DataLoader(SyntheticTextDataset(vocab=128, seed=0), batch=2,
                    seq_len=16)
    for _ in range(2):
        b = dl.next_batch()
        tr.train_step(b["tokens"], b["labels"])
    tr.close()
    tr.tracker.assert_quiescent()     # every byte returned


def test_moe_census_pool_pressure():
    """Fig. 18: MoE models magnify the fixed pool's waste (many small
    experts vs one giant embedding slot)."""
    cfg = ARCHS["deepseek-v3-671b"]
    census = cfg.pool_census()
    t1, t2 = MemoryTracker(), MemoryTracker()
    fixed = FixedBufferPool(
        census, AlignmentFreeAllocator(tracker=t1, component="p"))
    adaptive = AdaptiveBufferPool(
        census, AlignmentFreeAllocator(tracker=t2, component="p"))
    saving = 1 - adaptive.pool_bytes / fixed.pool_bytes
    assert saving > 0.6, f"MoE pool saving {saving:.1%}"
    fixed.close(); adaptive.close()
