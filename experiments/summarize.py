"""Render dry-run + roofline tables and splice them into EXPERIMENTS.md.

Usage: PYTHONPATH=src python experiments/summarize.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.roofline import load_records, report  # noqa: E402

ROOT = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(ROOT, "dryrun")
EXP = os.path.join(ROOT, "..", "EXPERIMENTS.md")
GiB = 1 << 30


def dryrun_table(mesh: str) -> str:
    lines = [
        f"### Dry-run — {mesh} mesh",
        "",
        "| arch | shape | kind | compile s | args GiB/chip | temp GiB/chip |"
        " flops/chip | coll GB (ag/ar/rs/a2a/cp) | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    try:
        recs = load_records(OUT, mesh)
    except FileNotFoundError:
        return f"### Dry-run — {mesh} mesh\n\n(not yet run)"
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | skipped: {r['reason'][:60]}… |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | ERROR |")
            continue
        m = r["memory"]
        b = r["collectives"]["bytes"]
        coll = "/".join(f"{b.get(k, 0) / 1e9:.0f}" for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['compile_seconds']:.0f} | "
            f"{m.get('argument_size_in_bytes', 0) / GiB:.2f} | "
            f"{m.get('temp_size_in_bytes', 0) / GiB:.1f} | "
            f"{r['cost'].get('flops', 0):.2e} | {coll} | ok |")
    return "\n".join(lines)


def splice(marker: str, content: str, text: str) -> str:
    tag = f"<!-- {marker} -->"
    if tag not in text:
        raise SystemExit(f"marker {marker} missing")
    return text.replace(tag, tag + "\n\n" + content)


def main() -> None:
    with open(EXP) as fh:
        text = fh.read()
    # remove previously spliced content: keep everything up to each marker
    for marker in ("DRYRUN-TABLE", "ROOFLINE-TABLE"):
        tag = f"<!-- {marker} -->"
        if tag in text:
            head, _, rest = text.partition(tag)
            # find the next --- separator after the tag
            nxt = rest.find("\n---")
            tail = rest[nxt:] if nxt >= 0 else ""
            text = head + tag + tail
    dr = []
    rf = []
    for mesh in ("pod", "multipod"):
        if os.path.isdir(os.path.join(OUT, mesh)):
            dr.append(dryrun_table(mesh))
            if mesh == "pod":   # roofline table is single-pod per assignment
                rf.append(report(OUT, mesh))
    text = splice("DRYRUN-TABLE", "\n\n".join(dr), text)
    text = splice("ROOFLINE-TABLE", "\n\n".join(rf), text)
    with open(EXP, "w") as fh:
        fh.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
