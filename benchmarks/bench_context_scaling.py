"""Paper Figs. 9/16: peak memory vs context length; max context under a
128 GiB cap.  Paper: 16,384 (baseline) -> 131,072 (MemAscend) on Qwen2.5-7B."""

from __future__ import annotations

from repro.configs import PAPER_MODELS

from .common import emit, gib, time_us
from .memory_model import GIB, estimate_peak, max_context_under

CONTEXTS = (4096, 16384, 32768, 65536, 131072)
LIMIT = 128 * GIB


def run() -> None:
    for name in ("llama3.1-8b", "qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b"):
        cfg = PAPER_MODELS[name]
        for ctx in CONTEXTS:
            us = time_us(lambda cfg=cfg, ctx=ctx: estimate_peak(
                cfg, memascend=True, ctx=ctx, batch=1), repeats=2)
            b = estimate_peak(cfg, memascend=False, ctx=ctx, batch=1).total
            m = estimate_peak(cfg, memascend=True, ctx=ctx, batch=1).total
            emit(f"ctx/{name}/{ctx}", us,
                 f"baseline={gib(b):.1f}GiB memascend={gib(m):.1f}GiB "
                 f"reduction={1 - m / b:.1%}")
        mb = max_context_under(cfg, LIMIT, memascend=False, batch=1)
        mm = max_context_under(cfg, LIMIT, memascend=True, batch=1)
        emit(f"ctx/{name}/max@128GiB", 0.0,
             f"baseline_max={mb} memascend_max={mm} "
             f"paper(qwen2.5-7b)=16384->131072")
