"""Paper Figs. 9/16 + (ours, PR 9) the measured long-context gate.

Two halves:

* **Measured** — REAL train steps of a small deep model in this
  container, walking a sequence-length ladder under each activation
  tier (``host`` / ``ssd`` / ``recompute``) and recording the tracked
  peak of the ``activation_checkpoints`` component.  A fixed host
  activation budget is taken from the host-resident run at
  ``BUDGET_SEQ``; the gate is the longest rung each tier can train
  within that budget.  Host-resident stops at ``BUDGET_SEQ`` by
  construction (every layer's checkpoint stays pinned), while the
  streamed tiers hold only the in-flight save/fetch window, so they
  climb further — the SSDTrain-style claim, measured.  The same runs
  assert bit-identical losses across tiers and report the overlap
  ablation (``act_fetch_wait_s`` / ``act_save_wait_s`` under ``sync``
  vs ``full``) showing the backward prefetch hiding under block
  compute.  Writes ``BENCH_context.json`` for
  ``benchmarks/check_regression.py`` (committed baseline in
  ``benchmarks/baselines/context.json``).

* **Analytic** — the paper-scale memory model (Qwen2.5-7B at 128 GiB:
  16,384 baseline -> 131,072 MemAscend), now including the ``ssd``
  activation tier, with real timings on the max-context search itself.
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax

from repro.configs import PAPER_MODELS
from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, OffloadSession
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

from .common import emit, gib, time_us
from .memory_model import GIB, estimate_peak, max_context_under

# deep-and-narrow on purpose: 8 checkpoints make the resident-host
# activation footprint the dominant seq-scaled term.
CFG = ModelConfig(name="bench-ctx", family="dense", n_layers=8, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
BATCH = 2
LADDER = (256, 384, 512, 640, 768, 896, 1024)
BUDGET_SEQ = 384          # host-resident tops out here by construction
IDENT_SEQ, IDENT_STEPS = 256, 3
OUT_PATH = "BENCH_context.json"

CONTEXTS = (4096, 16384, 32768, 65536, 131072)
LIMIT = 128 * GIB


def _run(root: str, tier: str, seq: int, steps: int,
         overlap: str = "full") -> dict:
    """Real train steps at one (tier, seq) point; returns losses, the
    activation-component peak, and the act-stream wait breakdown."""
    policy = (OffloadPolicy.preset("memascend").with_store(root)
              .with_adam(lr=1e-3).with_overlap(overlap)
              .with_activations(tier).build())
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    dl = DataLoader(SyntheticTextDataset(vocab=CFG.vocab, seed=0),
                    batch=BATCH, seq_len=seq)
    with OffloadSession(model, policy) as s:
        losses = []
        fetch_wait = save_wait = 0.0
        t0 = time.perf_counter()
        for _ in range(steps):
            b = dl.next_batch()
            m = s.train_step(b["tokens"], b["labels"])
            losses.append(m["loss"])
            fetch_wait += m["act_fetch_wait_s"]
            save_wait += m["act_save_wait_s"]
        s.synchronize()
        dt = time.perf_counter() - t0
        act_peak = s.tracker.component(
            "activation_checkpoints").peak_allocated
        total_peak = s.tracker.peak_allocated
    return {"losses": losses, "act_peak": act_peak,
            "total_peak": total_peak, "act_fetch_wait_s": fetch_wait,
            "act_save_wait_s": save_wait, "time_s": dt}


def _walk(root: str, tier: str, budget: int) -> tuple[int, dict]:
    """Climb the ladder until the measured activation peak exceeds the
    budget (peaks are monotone in seq within a tier, so the first
    over-budget rung ends the walk).  Returns (max in-budget seq,
    {seq: measured activation peak})."""
    peaks: dict[int, int] = {}
    best = 0
    for seq in LADDER:
        r = _run(f"{root}/{tier}{seq}", tier, seq, steps=1)
        peaks[seq] = r["act_peak"]
        if r["act_peak"] > budget:
            break
        best = seq
    return best, peaks


def _measured() -> None:
    root = tempfile.mkdtemp(prefix="bench_ctx_")
    try:
        budget = _run(f"{root}/budget", "host", BUDGET_SEQ, 1)["act_peak"]
        max_host, host_peaks = _walk(f"{root}/h", "host", budget)
        max_ssd, ssd_peaks = _walk(f"{root}/s", "ssd", budget)
        max_rec, rec_peaks = _walk(f"{root}/r", "recompute", budget)

        # loss identity + overlap ablation at one fixed point
        host_id = _run(f"{root}/ih", "host", IDENT_SEQ, IDENT_STEPS)
        ssd_id = _run(f"{root}/is", "ssd", IDENT_SEQ, IDENT_STEPS)
        rec_id = _run(f"{root}/ir", "recompute", IDENT_SEQ, IDENT_STEPS)
        ssd_sync = _run(f"{root}/iy", "ssd", IDENT_SEQ, IDENT_STEPS,
                        overlap="sync")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # hard acceptance gates, within this run: host-resident saturates the
    # budget at BUDGET_SEQ; the streamed tier must train strictly longer.
    if max_host != BUDGET_SEQ:
        raise AssertionError(
            f"host tier should top out at seq={BUDGET_SEQ} by "
            f"construction, measured {max_host} (peaks {host_peaks})")
    if max_ssd <= max_host:
        raise AssertionError(
            f"ssd tier must train longer sequences than host under the "
            f"same budget: ssd={max_ssd} host={max_host} "
            f"(budget={budget}B, ssd peaks {ssd_peaks})")

    # every tier moves the same floats through the same block order —
    # any divergence is an executor ordering/visibility bug, not noise.
    mismatches = sum(
        1 for lh, ls, lr, ly in zip(
            host_id["losses"], ssd_id["losses"], rec_id["losses"],
            ssd_sync["losses"], strict=True)
        if not (lh == ls == lr == ly))
    if mismatches:
        raise AssertionError(
            f"activation-tier losses diverged on {mismatches}/"
            f"{IDENT_STEPS} steps: host={host_id['losses']} "
            f"ssd={ssd_id['losses']} recompute={rec_id['losses']} "
            f"ssd_sync={ssd_sync['losses']}")

    per_step = 1.0 / IDENT_STEPS
    report = {
        "bench": "context",
        "config": {"model": CFG.name, "n_layers": CFG.n_layers,
                   "batch": BATCH, "ladder": list(LADDER),
                   "budget_seq": BUDGET_SEQ, "ident_seq": IDENT_SEQ,
                   "ident_steps": IDENT_STEPS},
        "metrics": {
            "budget_bytes": budget,
            "max_seq_host": max_host,
            "max_seq_ssd": max_ssd,
            "max_seq_recompute": max_rec,
            "seq_gain_ssd_vs_host": max_ssd / max_host,
            "act_peak_ssd_at_max_bytes": ssd_peaks[max_ssd],
            "act_peak_recompute_at_max_bytes": rec_peaks[max_rec],
            "loss_mismatch_modes": mismatches,
            "act_fetch_wait_ms_sync": (
                ssd_sync["act_fetch_wait_s"] * 1e3 * per_step),
            "act_fetch_wait_ms_full": (
                ssd_id["act_fetch_wait_s"] * 1e3 * per_step),
            "act_save_wait_ms_sync": (
                ssd_sync["act_save_wait_s"] * 1e3 * per_step),
            "act_save_wait_ms_full": (
                ssd_id["act_save_wait_s"] * 1e3 * per_step),
        },
        # ladder rungs and the byte budget are measured in-run, so the
        # gated max-seq values are stable across runner generations; the
        # wait-time ablation is reported but not gated (timing noise).
        "gates": {
            "max_seq_host": "higher_is_better",
            "max_seq_ssd": "higher_is_better",
            "seq_gain_ssd_vs_host": "higher_is_better",
            "loss_mismatch_modes": "lower_is_better",  # zero baseline
        },
        "threshold": 0.2,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    emit("ctx/measured/capacity", budget,
         f"budget={budget / 1e6:.2f}MB(host@{BUDGET_SEQ}) "
         f"max_seq: host={max_host} ssd={max_ssd} recompute={max_rec} "
         f"gain_ssd={max_ssd / max_host:.2f}x")
    emit("ctx/measured/act-peaks", float(ssd_peaks[max_ssd]),
         f"act peak at own max: host={budget / 1e6:.2f}MB "
         f"ssd={ssd_peaks[max_ssd] / 1e6:.2f}MB "
         f"recompute={rec_peaks[max_rec] / 1e6:.2f}MB")
    emit("ctx/measured/loss-identity", 0.0 if not mismatches else 1.0,
         f"host/ssd/recompute/ssd-sync bit-identical over "
         f"{IDENT_STEPS} steps: mismatches={mismatches}")
    emit("ctx/measured/prefetch-overlap",
         ssd_id["act_fetch_wait_s"] * 1e6 * per_step,
         f"per-step act_fetch_wait: "
         f"sync={ssd_sync['act_fetch_wait_s'] * 1e3 * per_step:.2f}ms "
         f"full={ssd_id['act_fetch_wait_s'] * 1e3 * per_step:.2f}ms; "
         f"act_save_wait: "
         f"sync={ssd_sync['act_save_wait_s'] * 1e3 * per_step:.2f}ms "
         f"full={ssd_id['act_save_wait_s'] * 1e3 * per_step:.2f}ms")


def _analytic() -> None:
    for name in ("llama3.1-8b", "qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b"):
        cfg = PAPER_MODELS[name]
        for ctx in CONTEXTS:
            us = time_us(lambda cfg=cfg, ctx=ctx: estimate_peak(
                cfg, memascend=True, ctx=ctx, batch=1), repeats=2)
            b = estimate_peak(cfg, memascend=False, ctx=ctx, batch=1).total
            m = estimate_peak(cfg, memascend=True, ctx=ctx, batch=1).total
            emit(f"ctx/{name}/{ctx}", us,
                 f"baseline={gib(b):.1f}GiB memascend={gib(m):.1f}GiB "
                 f"reduction={1 - m / b:.1%}")
        us = time_us(lambda cfg=cfg: max_context_under(
            cfg, LIMIT, memascend=True, batch=1), repeats=2)
        mb = max_context_under(cfg, LIMIT, memascend=False, batch=1)
        mm = max_context_under(cfg, LIMIT, memascend=True, batch=1)
        ms = max_context_under(cfg, LIMIT, memascend=True, batch=1,
                               act_policy="ssd")
        emit(f"ctx/{name}/max@128GiB", us,
             f"baseline_max={mb} memascend_host={mm} memascend_ssd={ms} "
             f"paper(qwen2.5-7b)=16384->131072")


def run() -> None:
    _measured()
    _analytic()
