"""CI bench-regression gate: fresh BENCH_*.json vs committed baselines.

Each benchmark that wants gating writes a JSON report containing

* ``metrics``   — flat {name: number},
* ``gates``     — {metric_name: "higher_is_better" | "lower_is_better"},
* ``threshold`` — relative tolerance (default 0.2 = 20%),

and commits a blessed copy under ``benchmarks/baselines/<name>.json``
(``BENCH_decode.json`` pairs with ``baselines/decode.json``).  The gate
fails when a gated metric regresses past the threshold — e.g. tokens/s
dropping >20% below baseline, or peak host bytes rising >20% above it.  A
zero baseline (the retrace gates) tolerates no increase at all.

Usage::

    python -m benchmarks.check_regression [BENCH_decode.json ...]
        [--baseline-dir benchmarks/baselines] [--threshold 0.2]

With no files given, every ``BENCH_*.json`` in the working directory is
checked.  Fresh reports without a committed baseline are skipped with a
warning so new benchmarks can land before their first blessing.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DEFAULT_BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
DEFAULT_THRESHOLD = 0.2


def baseline_path(fresh_path: str, baseline_dir: str) -> str:
    name = os.path.basename(fresh_path)
    if name.startswith("BENCH_"):
        name = name[len("BENCH_") :]
    return os.path.join(baseline_dir, name)


def compare(fresh: dict, baseline: dict, threshold: float | None) -> list[str]:
    """Failure messages for every gated metric that regressed."""
    gates = baseline.get("gates", {})
    tol = threshold if threshold is not None else baseline.get(
        "threshold", DEFAULT_THRESHOLD
    )
    failures = []
    for metric, direction in sorted(gates.items()):
        if direction not in ("higher_is_better", "lower_is_better"):
            failures.append(f"{metric}: unknown gate direction {direction!r}")
            continue
        base = baseline.get("metrics", {}).get(metric)
        new = fresh.get("metrics", {}).get(metric)
        if base is None or new is None:
            failures.append(
                f"{metric}: missing from "
                f"{'baseline' if base is None else 'fresh report'}"
            )
            continue
        if direction == "higher_is_better":
            floor = base * (1.0 - tol)
            if new < floor:
                failures.append(
                    f"{metric}: {new:.6g} < {floor:.6g} "
                    f"(baseline {base:.6g} - {tol:.0%})"
                )
        else:
            ceiling = base * (1.0 + tol)
            if base == 0:
                if new > 0:
                    failures.append(f"{metric}: {new:.6g} > 0 (baseline is zero)")
            elif new > ceiling:
                failures.append(
                    f"{metric}: {new:.6g} > {ceiling:.6g} "
                    f"(baseline {base:.6g} + {tol:.0%})"
                )
    return failures


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("reports", nargs="*", help="fresh BENCH_*.json files")
    parser.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    parser.add_argument(
        "--threshold",
        type=float,
        default=None,
        help="override the per-baseline relative tolerance",
    )
    args = parser.parse_args(argv)

    reports = args.reports or sorted(glob.glob("BENCH_*.json"))
    if not reports:
        print("check_regression: no BENCH_*.json reports found", file=sys.stderr)
        return 2

    any_failures = False
    for path in reports:
        with open(path) as f:
            fresh = json.load(f)
        bpath = baseline_path(path, args.baseline_dir)
        if not os.path.exists(bpath):
            print(f"check_regression: SKIP {path} (no baseline at {bpath})")
            continue
        with open(bpath) as f:
            baseline = json.load(f)
        failures = compare(fresh, baseline, args.threshold)
        if failures:
            any_failures = True
            print(f"check_regression: FAIL {path} vs {bpath}")
            for msg in failures:
                print(f"  - {msg}")
        else:
            gated = sorted(baseline.get("gates", {}))
            print(f"check_regression: OK {path} ({', '.join(gated)})")
    return 1 if any_failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
