"""Peak system-memory model at paper scale (Tables II, Figs. 8/9/10/15-18).

Runs the REAL policy objects (allocators, pools) in accounting mode — no
actual buffers — to produce peak-host-memory estimates for paper-scale
models.  Components, following the paper's Fig. 8 breakdown:

  parameter buffer pool   census-sized, fixed vs adaptive slots
  pinned-alloc overhead   pow2 rounding vs 4 KiB alignment on every
                          long-lived pinned buffer
  gradient flat buffer    fp32, whole model (constant across methods)
  overflow-check temps    2.25x flat-buffer peak vs ~one chunk
  optimizer stream        double-buffered Adam staging: 2 x (3 fp32
                          subgroup copies + truncation scratch)
  swap-out buffer         largest-tensor staging (constant)
  activation checkpoints  Eq. 1: N_g*B*C*L*H*2 bytes, offloaded-GC

Calibration notes (EXPERIMENTS.md §Paper-validation): prefetch depth
(`inflight_blocks`) is 1, matching the pool sizes reported in the paper's
Fig. 8/11 within a few percent.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        FixedBufferPool, MemoryTracker,
                        PowerOfTwoCachingAllocator)

GIB = 1 << 30


@dataclass
class PeakEstimate:
    pool: int
    pinned_overhead: int
    flat_buffer: int
    overflow_peak: int
    optimizer_stream: int
    swap_buffer: int
    checkpoints: int

    @property
    def total(self) -> int:
        # overflow temps and the optimizer stream don't overlap in time;
        # peak takes the max of the two transient phases (paper Fig. 3).
        transient = max(self.overflow_peak, self.optimizer_stream)
        return (self.pool + self.pinned_overhead + self.flat_buffer
                + transient + self.swap_buffer + self.checkpoints)

    def breakdown(self) -> dict:
        return {k: getattr(self, k) for k in (
            "pool", "pinned_overhead", "flat_buffer", "overflow_peak",
            "optimizer_stream", "swap_buffer", "checkpoints")}


def estimate_peak(cfg: ModelConfig, *, memascend: bool, n_gpus: int = 2,
                  batch: int = 8, ctx: int = 4096,
                  inflight_blocks: int = 1,
                  offload_checkpoints: bool = True,
                  act_policy: str = "host") -> PeakEstimate:
    census = cfg.pool_census(inflight_blocks=inflight_blocks, shards=n_gpus)
    tracker = MemoryTracker()
    alloc_cls = AlignmentFreeAllocator if memascend \
        else PowerOfTwoCachingAllocator
    pool_cls = AdaptiveBufferPool if memascend else FixedBufferPool

    # one pool per rank (each holds its parameter shard's staging slots).
    # ZeRO-Infinity pins each slot as its own allocation (each pow2-rounded
    # by the caching allocator); MemAscend reserves ONE monolithic arena
    # (paper §IV-B) at 4 KiB alignment.
    alloc = alloc_cls(tracker=tracker, component="pinned", caching=False)
    pool = pool_cls(census, alloc)
    pool_payload = pool.pool_bytes * n_gpus
    if memascend:
        pool_reserved = pool._arena_buf.capacity * n_gpus
    else:
        slab = census.max_tensor_bytes
        per_slot = alloc._rounded(slab)
        pool_reserved = per_slot * census.total_slots * n_gpus

    # gradient flat buffer: fp32 x whole model, split across ranks but summed
    n_params = cfg.param_count()
    flat_payload = n_params * 4
    flat_buf = alloc.alloc(flat_payload // n_gpus)
    flat_reserved = flat_buf.capacity * n_gpus

    # activation checkpoints: one (B, C, H) half-precision buffer per
    # layer per rank when every checkpoint stays host-resident (Eq. 1,
    # act_policy="host"); streamed tiers ("ssd" — and "recompute", which
    # checkpoints every other layer to SSD — PR 9 / SSDTrain) hold only
    # the in-flight window: one buffer being saved (D2H staging on the
    # writer) plus the prefetched-back window on the backward side, so
    # the host footprint stops scaling with depth.
    if act_policy not in ("host", "ssd", "recompute"):
        raise ValueError(f"act_policy must be host|ssd|recompute, got "
                         f"{act_policy!r}")
    ckpt_payload = 0
    ckpt_reserved = 0
    if offload_checkpoints:
        per_layer = batch * ctx * cfg.d_model * 2
        layers = cfg.n_layers + cfg.encoder_layers
        if act_policy == "host":
            resident = min(layers, 64)
        else:
            # save-side staging + double-buffered fetch-back window
            resident = min(1 + max(1, inflight_blocks), layers)
        for _ in range(resident):
            b = alloc.alloc(per_layer)
            ckpt_payload += per_layer * n_gpus
            ckpt_reserved += b.capacity * n_gpus
        if act_policy == "host" and layers > 64:
            scale = layers / 64   # avoid silly loops for deep models
            ckpt_payload = int(ckpt_payload * scale)
            ckpt_reserved = int(ckpt_reserved * scale)

    # optimizer subgroup stream: the pipelined Adam stage double-buffers
    # its host staging — 2 buffers of (master, m, v) fp32 working copies
    # of the largest subgroup plus a half-precision truncation scratch
    # (compute weights are cast through it), all tracker-charged up front
    # (constant across methods; see repro.core.optimizer._StagingArena).
    # Modeled for the default fp32-state mode: a bf16-STATE policy
    # (memascend-bf16) carries 3 state-scratch regions + the compute one
    # (8 B/elem instead of 2) — this model does not take a state dtype.
    max_elems = census.max_tensor_bytes // 2        # bf16 compute elems
    max_tensor = max_elems * 4                      # fp32 bytes of largest
    opt_stream = 2 * (3 * max_tensor + max_elems * 2) * n_gpus
    swap_buffer = max_tensor * n_gpus

    # overflow temporaries
    overflow_peak = (4 << 20) if memascend else int(1.25 * flat_payload)

    pinned_overhead = (pool_reserved - pool_payload) + \
        (flat_reserved - flat_payload) + (ckpt_reserved - ckpt_payload)

    return PeakEstimate(
        pool=pool_payload,
        pinned_overhead=pinned_overhead,
        flat_buffer=flat_payload,
        overflow_peak=overflow_peak,
        optimizer_stream=opt_stream,
        swap_buffer=swap_buffer,
        checkpoints=ckpt_payload,
    )


def max_context_under(cfg: ModelConfig, limit_bytes: int, *,
                      memascend: bool, n_gpus: int = 2, batch: int = 1,
                      act_policy: str = "host",
                      contexts=(4096, 8192, 16384, 32768, 65536, 131072,
                                262144)) -> int:
    """Largest context whose estimated peak fits the limit (Fig. 16)."""
    best = 0
    for ctx in contexts:
        est = estimate_peak(cfg, memascend=memascend, n_gpus=n_gpus,
                            batch=batch, ctx=ctx, act_policy=act_policy)
        if est.total <= limit_bytes:
            best = ctx
    return best


def max_batch_under(cfg: ModelConfig, limit_bytes: int, *, memascend: bool,
                    n_gpus: int = 2, ctx: int = 4096,
                    batches=(1, 2, 4, 8, 16, 32, 48, 64, 96)) -> int:
    best = 0
    for b in batches:
        est = estimate_peak(cfg, memascend=memascend, n_gpus=n_gpus,
                            batch=b, ctx=ctx)
        if est.total <= limit_bytes:
            best = b
    return best
