"""Paper Figs. 12/13: overflow-check latency + peak temp memory, chained
baseline vs fused, vs model size.  Paper: −97% latency, zero extra memory.

Container scale: flat-buffer slices up to 200M fp32 params (the paper's 8B
buffer is 29.9 GiB — we measure per-element cost and report it; the cost is
linear in N on both paths)."""

from __future__ import annotations

import numpy as np

from repro.core import (MemoryTracker, baseline_overflow_check,
                        fused_overflow_check)

from .common import emit, gib, time_us

SIZES_M = (10, 50, 200)   # millions of fp32 gradient elements


def run() -> None:
    rng = np.random.default_rng(0)
    for m in SIZES_M:
        n = m * 1_000_000
        g = rng.standard_normal(n).astype(np.float32)
        t = MemoryTracker()
        base_us = time_us(lambda: baseline_overflow_check(g, tracker=t),
                          repeats=3)
        base_peak = t.component("overflow_tmp").peak_allocated
        t2 = MemoryTracker()
        fused_us = time_us(lambda: fused_overflow_check(g, tracker=t2),
                           repeats=3)
        fused_peak = t2.component("overflow_tmp").peak_allocated
        emit(f"overflow/{m}M", fused_us,
             f"baseline_us={base_us:.0f} fused_us={fused_us:.0f} "
             f"latency_reduction={1 - fused_us / base_us:.1%} "
             f"baseline_peak={gib(g.nbytes + base_peak):.2f}GiB "
             f"fused_peak={gib(g.nbytes + fused_peak):.2f}GiB "
             f"paper_latency=-97%")
        del g
    # extrapolation to the paper's 8B flat buffer
    emit("overflow/8B-extrapolated", 0.0,
         "peak_baseline=2.25x_flat=67.3GiB peak_fused=1.0x_flat=29.9GiB "
         "(paper Fig. 3/13)")
