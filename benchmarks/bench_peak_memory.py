"""Paper Table II + Fig. 15: end-to-end peak system memory at paper scale,
from the byte-exact accounting model over the real policy objects."""

from __future__ import annotations

from repro.configs import ALL_MODELS

from .common import emit, gib, time_us
from .memory_model import estimate_peak

PAPER_FIG15 = {   # GiB (baseline, memascend)
    "llama3.1-8b": (91.06, 44.71),
    "qwen2.5-7b": (109.06, 43.67),
    "qwen2.5-14b": (174.5, 76.1),
    "qwen2.5-32b": (322.3, 143.6),
}


def run() -> None:
    reductions = []
    for name, cfg in ALL_MODELS.items():
        us = time_us(lambda cfg=cfg: estimate_peak(cfg, memascend=True),
                     repeats=3)
        base = estimate_peak(cfg, memascend=False).total
        mem = estimate_peak(cfg, memascend=True).total
        red = 1 - mem / base
        reductions.append(red)
        ref = PAPER_FIG15.get(name)
        ref_s = (f" paper=({ref[0]:.1f},{ref[1]:.1f})GiB"
                 if ref else "")
        emit(f"peakmem/{name}", us,
             f"baseline={gib(base):.1f}GiB memascend={gib(mem):.1f}GiB "
             f"reduction={red:.1%}{ref_s}")
    emit("peakmem/average", 0.0,
         f"avg_reduction={sum(reductions)/len(reductions):.1%} paper=55.7%")
