"""Shared benchmark plumbing: timing + the required CSV emission format."""

from __future__ import annotations

import time

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_us(fn, *, repeats: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def gib(n: float) -> float:
    return n / (1 << 30)
