"""Paper Fig. 8 (pinned-overhead component): pow2 vs alignment-free waste
over the long-lived offloading buffers.  Paper: 24.90 GiB -> 1.63 GiB
(-93.5%) for Qwen2.5-7B."""

from __future__ import annotations

from repro.configs import ALL_MODELS
from repro.core import (AlignmentFreeAllocator, MemoryTracker,
                        PowerOfTwoCachingAllocator)

from .common import emit, gib, time_us


def _long_lived_buffers(cfg, n_gpus=2):
    """Request sizes of every long-lived pinned buffer (per §IV-C)."""
    census = cfg.pool_census(inflight_blocks=1, shards=n_gpus)
    sizes = []
    for cls in census.classes:
        sizes += [cls.nbytes] * cls.slots(census.inflight_blocks)
    sizes.append(cfg.param_count() * 4 // n_gpus)       # gradient flat buffer
    sizes += [census.max_tensor_bytes * 2] * 3           # optimizer staging
    sizes += [8 * 4096 * cfg.d_model * 2] * min(cfg.n_layers, 64)  # offl. GC
    return sizes


def run() -> None:
    for name, cfg in ALL_MODELS.items():
        sizes = _long_lived_buffers(cfg)

        def alloc_all(cls):
            t = MemoryTracker()
            a = cls(tracker=t, component="x", caching=False)
            for s in sizes:
                a.alloc(s)
            return t

        us = time_us(lambda: alloc_all(AlignmentFreeAllocator), repeats=3)
        t_pow2 = alloc_all(PowerOfTwoCachingAllocator)
        t_free = alloc_all(AlignmentFreeAllocator)
        waste_pow2 = t_pow2.live_allocated - t_pow2.live_requested
        waste_free = t_free.live_allocated - t_free.live_requested
        emit(f"pinned/{name}", us,
             f"pow2_waste={gib(waste_pow2):.2f}GiB "
             f"alignfree_waste={gib(waste_free):.3f}GiB "
             f"reduction={1 - waste_free / max(waste_pow2, 1):.1%} "
             f"paper=93.5%")
