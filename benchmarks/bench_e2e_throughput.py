"""Paper Table IV: end-to-end offloaded-training throughput, ZeRO-Infinity
baseline vs MemAscend, measured on REAL steps of a small model in this
container (both policies run the identical compute; the deltas come from
the overflow check, allocator, and storage paths — exactly the paper's
claim).  Plus the StreamPlan lookahead ablation: fetch-wait time with
synchronous per-unit fetches (lookahead=1, the seed engine's behaviour)
vs lookahead pipelining (block i+1's SSD read under block i's compute)."""

from __future__ import annotations

import shutil
import tempfile
import time

import jax

from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, OffloadSession
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

from .common import emit

CFG = ModelConfig(name="bench-20m", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192)
BATCH, SEQ, STEPS = 4, 256, 4


def _run_policy(policy) -> tuple[float, float, float]:
    """(tokens/s, peak host bytes, fetch-wait seconds) over STEPS steps."""
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    dl = DataLoader(SyntheticTextDataset(vocab=CFG.vocab, seed=0),
                    batch=BATCH, seq_len=SEQ)
    with OffloadSession(model, policy) as s:
        b = dl.next_batch()
        s.train_step(b["tokens"], b["labels"])    # warmup/compile
        wait0 = s.swapper.stats.wait_seconds
        t0 = time.perf_counter()
        for _ in range(STEPS):
            b = dl.next_batch()
            s.train_step(b["tokens"], b["labels"])
        dt = time.perf_counter() - t0
        fetch_wait = s.swapper.stats.wait_seconds - wait0
        peak = s.tracker.peak_allocated
    return STEPS * BATCH * SEQ / dt, peak, fetch_wait


def _policy(name: str, root: str, **kw):
    builder = OffloadPolicy.preset(name).with_store(root).with_adam(lr=1e-3)
    if "lookahead" in kw:
        builder = builder.with_lookahead(kw["lookahead"])
    return builder.build()


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_e2e_")
    try:
        tput_base, peak_base, _ = _run_policy(
            _policy("zero-infinity", root + "/z"))
        tput_mem, peak_mem, wait_pipe = _run_policy(
            _policy("memascend", root + "/m"))
        tput_bf16, _, _ = _run_policy(
            _policy("memascend-bf16", root + "/b"))
        # lookahead ablation: same policy, prefetch window forced to 1
        tput_sync, _, wait_sync = _run_policy(
            _policy("memascend", root + "/s", lookahead=1))
        emit("e2e/throughput", 1e6 / tput_mem,
             f"baseline={tput_base:.0f}tok/s memascend={tput_mem:.0f}tok/s "
             f"improvement={tput_mem / tput_base - 1:+.1%} "
             f"paper=+2.7..18.9%")
        emit("e2e/bf16-optimizer", 1e6 / tput_bf16,
             f"memascend_bf16={tput_bf16:.0f}tok/s "
             f"vs_fp32={tput_bf16 / tput_mem - 1:+.1%} paper=+10..57%")
        emit("e2e/peak-host", 0.0,
             f"baseline={peak_base / 1e6:.1f}MB "
             f"memascend={peak_mem / 1e6:.1f}MB "
             f"reduction={1 - peak_mem / peak_base:.1%}")
        emit("e2e/fetch-wait", wait_pipe * 1e6 / STEPS,
             f"sync={wait_sync * 1e3:.1f}ms lookahead={wait_pipe * 1e3:.1f}ms "
             f"(per {STEPS} steps) reduction="
             f"{1 - wait_pipe / max(wait_sync, 1e-12):.1%} "
             f"sync_tput={tput_sync:.0f}tok/s pipe_tput={tput_mem:.0f}tok/s")
    finally:
        shutil.rmtree(root, ignore_errors=True)
