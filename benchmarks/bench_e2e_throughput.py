"""Paper Table IV: end-to-end offloaded-training throughput, ZeRO-Infinity
baseline vs MemAscend, measured on REAL steps of a small model in this
container (both policies run the identical compute; the deltas come from
the overflow check, allocator, and storage paths — exactly the paper's
claim)."""

from __future__ import annotations

import shutil
import tempfile
import time

import jax

from repro.configs.base import ModelConfig
from repro.core import (OffloadedTrainer, memascend_policy,
                        zero_infinity_policy)
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

from .common import emit

CFG = ModelConfig(name="bench-20m", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192)
BATCH, SEQ, STEPS = 4, 256, 4


def _throughput(policy) -> tuple[float, float]:
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    tr = OffloadedTrainer(model, policy)
    dl = DataLoader(SyntheticTextDataset(vocab=CFG.vocab, seed=0),
                    batch=BATCH, seq_len=SEQ)
    b = dl.next_batch()
    tr.train_step(b["tokens"], b["labels"])    # warmup/compile
    t0 = time.perf_counter()
    for _ in range(STEPS):
        b = dl.next_batch()
        tr.train_step(b["tokens"], b["labels"])
    dt = time.perf_counter() - t0
    peak = tr.tracker.peak_allocated
    tr.close()
    return STEPS * BATCH * SEQ / dt, peak


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_e2e_")
    try:
        tput_base, peak_base = _throughput(
            zero_infinity_policy(root + "/z", lr=1e-3))
        tput_mem, peak_mem = _throughput(
            memascend_policy(root + "/m", lr=1e-3))
        tput_bf16, _ = _throughput(
            memascend_policy(root + "/b", lr=1e-3, bf16_optimizer=True))
        emit("e2e/throughput", 1e6 / tput_mem,
             f"baseline={tput_base:.0f}tok/s memascend={tput_mem:.0f}tok/s "
             f"improvement={tput_mem / tput_base - 1:+.1%} "
             f"paper=+2.7..18.9%")
        emit("e2e/bf16-optimizer", 1e6 / tput_bf16,
             f"memascend_bf16={tput_bf16:.0f}tok/s "
             f"vs_fp32={tput_bf16 / tput_mem - 1:+.1%} paper=+10..57%")
        emit("e2e/peak-host", 0.0,
             f"baseline={peak_base / 1e6:.1f}MB "
             f"memascend={peak_mem / 1e6:.1f}MB "
             f"reduction={1 - peak_mem / peak_base:.1%}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
