"""Paper Table IV: end-to-end offloaded-training throughput, ZeRO-Infinity
baseline vs MemAscend, measured on REAL steps of a small model in this
container (both policies run the identical compute; the deltas come from
the overflow check, allocator, and storage paths — exactly the paper's
claim).  Plus the overlap ablation (paper Fig. 6): the same MemAscend
policy at the three pipeline levels —

* ``sync`` — SSD reads prefetch under compute (lookahead-N), but H2D
  blocks inside each FetchOp, gradient D2H runs on the compute thread,
  and the optimizer streams strictly after the backward pass,
* ``h2d``  — adds the H2D worker + double-buffered device slots,
* ``full`` — adds the gradient writer thread and the cross-step optimizer
  worker (step k's host Adam under step k+1's forward prefetch window).

The three runs execute identical float ops in identical order, so their
loss trajectories must match bit for bit — asserted here, gated in CI.
Writes ``BENCH_e2e.json`` for ``benchmarks/check_regression.py``
(committed baseline in ``benchmarks/baselines/e2e.json``).
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax

from repro.configs.base import ModelConfig
from repro.core import OffloadPolicy, OffloadSession
from repro.core.model_adapter import make_offloadable_lm
from repro.data import DataLoader, SyntheticTextDataset

from .common import emit

CFG = ModelConfig(name="bench-20m", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192)
BATCH, SEQ, STEPS = 4, 256, 4
OUT_PATH = "BENCH_e2e.json"


def _run_policy(policy) -> dict:
    """Timed steps (synchronize() inside the window, so full-overlap pays
    its optimizer tail instead of hiding it past the clock)."""
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    dl = DataLoader(SyntheticTextDataset(vocab=CFG.vocab, seed=0),
                    batch=BATCH, seq_len=SEQ)
    with OffloadSession(model, policy) as s:
        b = dl.next_batch()
        s.train_step(b["tokens"], b["labels"])    # warmup/compile
        s.synchronize()
        losses = []
        fetch_wait = ssd_wait = optim_gate = 0.0
        optim_prefetch_wait = overflow_screen = 0.0
        t0 = time.perf_counter()
        for _ in range(STEPS):
            b = dl.next_batch()
            m = s.train_step(b["tokens"], b["labels"])
            losses.append(m["loss"])
            fetch_wait += m["fetch_wait_s"]
            ssd_wait += m["ssd_wait_s"]
            optim_gate += m["optim_gate_s"]
            optim_prefetch_wait += m["optim_prefetch_wait_s"]
            overflow_screen += m["overflow_screen_s"]
        s.synchronize()
        dt = time.perf_counter() - t0
        peak = s.tracker.peak_allocated
    return {
        "tokens_per_s": STEPS * BATCH * SEQ / dt,
        "peak_host_bytes": peak,
        "losses": losses,
        "fetch_wait_s": fetch_wait,   # compute-thread stall for weights
        "ssd_wait_s": ssd_wait,       # raw read waits (off-thread in overlap)
        "optim_gate_s": optim_gate,
        # Adam-stage internals: optimizer worker blocked on staged state
        # (the pipelined analogue of fetch wait) and per-region Inf/NaN
        # screen time (paid off the barrier, on the writer thread)
        "optim_prefetch_wait_s": optim_prefetch_wait,
        "overflow_screen_s": overflow_screen,
    }


def _policy(name: str, root: str, **kw):
    builder = OffloadPolicy.preset(name).with_store(root).with_adam(lr=1e-3)
    if "lookahead" in kw:
        builder = builder.with_lookahead(kw["lookahead"])
    if "overlap" in kw:
        builder = builder.with_overlap(kw["overlap"])
    return builder.build()


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_e2e_")
    try:
        base = _run_policy(_policy("zero-infinity", root + "/z"))
        mem = _run_policy(_policy("memascend", root + "/m"))   # overlap=full
        bf16 = _run_policy(_policy("memascend-bf16", root + "/b"))
        # overlap ablation: same policy, pipeline legs peeled back
        sync = _run_policy(_policy("memascend", root + "/s", overlap="sync"))
        h2d = _run_policy(_policy("memascend", root + "/h", overlap="h2d"))
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # Equivalence acceptance gate: the ablation levels move work between
    # threads but run identical float ops in identical order — any loss
    # divergence is an executor ordering/visibility bug, not noise.
    mismatches = sum(
        1 for ls, lh, lf in zip(sync["losses"], h2d["losses"], mem["losses"],
                              strict=True)
        if not (ls == lh == lf))
    if mismatches:
        raise AssertionError(
            f"overlap ablation losses diverged on {mismatches}/{STEPS} "
            f"steps: sync={sync['losses']} h2d={h2d['losses']} "
            f"full={mem['losses']}")

    per_step = 1.0 / STEPS
    report = {
        "bench": "e2e",
        "config": {"model": CFG.name, "n_layers": CFG.n_layers,
                   "batch": BATCH, "seq": SEQ, "steps": STEPS},
        "metrics": {
            "tokens_per_s_baseline": base["tokens_per_s"],
            "tokens_per_s_memascend": mem["tokens_per_s"],
            "tokens_per_s_memascend_bf16": bf16["tokens_per_s"],
            "tokens_per_s_sync": sync["tokens_per_s"],
            "tokens_per_s_h2d": h2d["tokens_per_s"],
            "tokens_per_s_full": mem["tokens_per_s"],
            "speedup_memascend_vs_baseline": (
                mem["tokens_per_s"] / base["tokens_per_s"]),
            "speedup_full_vs_sync": (
                mem["tokens_per_s"] / sync["tokens_per_s"]),
            "peak_host_bytes_baseline": base["peak_host_bytes"],
            "peak_host_bytes_memascend": mem["peak_host_bytes"],
            "step_wait_ms_sync": sync["fetch_wait_s"] * 1e3 * per_step,
            "step_wait_ms_h2d": h2d["fetch_wait_s"] * 1e3 * per_step,
            "step_wait_ms_full": mem["fetch_wait_s"] * 1e3 * per_step,
            "ssd_wait_ms_full_offthread": mem["ssd_wait_s"] * 1e3 * per_step,
            "optim_gate_ms_full": mem["optim_gate_s"] * 1e3 * per_step,
            "optim_prefetch_wait_ms_full": (
                mem["optim_prefetch_wait_s"] * 1e3 * per_step),
            "overflow_screen_ms_full": (
                mem["overflow_screen_s"] * 1e3 * per_step),
            "loss_mismatch_steps": mismatches,
        },
        # tokens/s is machine-dependent; the speedup and mismatch metrics
        # are measured within one run, so they hold across runner
        # generations even when absolute throughput shifts.
        "gates": {
            "tokens_per_s_full": "higher_is_better",
            "speedup_full_vs_sync": "higher_is_better",
            "peak_host_bytes_memascend": "lower_is_better",
            "loss_mismatch_steps": "lower_is_better",  # zero baseline
        },
        "threshold": 0.2,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    emit("e2e/throughput", 1e6 / mem["tokens_per_s"],
         f"baseline={base['tokens_per_s']:.0f}tok/s "
         f"memascend={mem['tokens_per_s']:.0f}tok/s "
         f"improvement={mem['tokens_per_s'] / base['tokens_per_s'] - 1:+.1%} "
         f"paper=+2.7..18.9%")
    emit("e2e/bf16-optimizer", 1e6 / bf16["tokens_per_s"],
         f"memascend_bf16={bf16['tokens_per_s']:.0f}tok/s "
         f"vs_fp32={bf16['tokens_per_s'] / mem['tokens_per_s'] - 1:+.1%} "
         f"paper=+10..57%")
    emit("e2e/peak-host", 0.0,
         f"baseline={base['peak_host_bytes'] / 1e6:.1f}MB "
         f"memascend={mem['peak_host_bytes'] / 1e6:.1f}MB "
         f"reduction={1 - mem['peak_host_bytes'] / base['peak_host_bytes']:.1%}")
    emit("e2e/overlap-ablation", 1e6 / mem["tokens_per_s"],
         f"sync={sync['tokens_per_s']:.0f}tok/s "
         f"h2d={h2d['tokens_per_s']:.0f}tok/s "
         f"full={mem['tokens_per_s']:.0f}tok/s "
         f"full_vs_sync={mem['tokens_per_s'] / sync['tokens_per_s'] - 1:+.1%} "
         f"loss_mismatches={mismatches}")
    emit("e2e/fetch-wait", mem["fetch_wait_s"] * 1e6 / STEPS,
         f"per-step compute-visible wait: "
         f"sync={sync['fetch_wait_s'] * 1e3 * per_step:.1f}ms "
         f"h2d={h2d['fetch_wait_s'] * 1e3 * per_step:.1f}ms "
         f"full={mem['fetch_wait_s'] * 1e3 * per_step:.1f}ms "
         f"(full hides {mem['ssd_wait_s'] * 1e3 * per_step:.1f}ms of SSD "
         f"wait on the staging worker)")
    emit("e2e/adam-stage", mem["optim_gate_s"] * 1e6 / STEPS,
         f"per-step optim-gate={mem['optim_gate_s'] * 1e3 * per_step:.1f}ms "
         f"(pipelined state streaming; prefetch-wait inside the stage "
         f"{mem['optim_prefetch_wait_s'] * 1e3 * per_step:.1f}ms, "
         f"per-region overflow screen "
         f"{mem['overflow_screen_s'] * 1e3 * per_step:.2f}ms off the "
         f"barrier)")
