"""Speculative vs plain greedy decoding on the offloaded serve path.

The claim under test is the one that makes speculative decoding worth
anything on an SSD-offloaded host: the per-step cost is dominated by
streaming every block's weights through the pinned pool, and that cost is
flat in the number of query positions — so verifying a K-token draft
window in one pass prices K tokens at ~one token's weight traffic.  With
the free self-drafting source (suffix n-gram lookup over the request's
own context) the accepted tokens are pure savings.

One seeded repetition-friendly workload (tiled prompt pattern + a long
generation budget, where greedy decode settles into loops the n-gram
draft predicts well) decoded two ways through identically-configured
sessions.  The workload is a single request: single-stream latency is
where speculation pays (the joint ``generate`` path advances all lanes
in lockstep by the batch-minimum accepted run, so multi-lane acceptance
is the min across lanes; per-slot independent acceptance is the serving
engine's job and is covered by its tests).  Modes:

* ``plain`` — the cached prefill-then-step loop (one streamed pass per
  token), which is also the reference ledger for the identity gate;
* ``spec``  — draft / verify-K / per-slot rollback rounds
  (``generate(spec=SpecConfig(...))``).

Acceptance gates (hard failures here, regression-gated in CI):

* bit-identical output tokens — speculation must never change what is
  emitted, only how fast;
* tokens/s(spec) > tokens/s(plain) at equal output, judged on the median
  of ``N_TRIALS`` back-to-back paired runs;
* zero warm retraces: after one warmup pass per mode, the timed runs must
  reuse the warmed trace set exactly (the verify window is padded to
  power-of-two k-buckets precisely so this set stays bounded).

Writes ``BENCH_spec_decode.json`` for ``benchmarks/check_regression.py``
(committed baseline in ``benchmarks/baselines/spec_decode.json``).
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DecodeSpec, OffloadPolicy
from repro.core.model_adapter import make_offloadable_lm
from repro.serve import OffloadedDecoder, SpecConfig

from .common import emit

CFG = ModelConfig(
    name="bench-20m",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab=8192,
)
BATCH, MAX_SEQ, BUCKET = 1, 160, 32
PROMPT_PATTERN, PROMPT_REPEATS = 6, 4  # tiled prompt: 24 tokens
NEW_TOKENS = 96
SPEC_K = 6  # window: pending + up to 5 drafts
# Paired back-to-back trials, verdict on the median ratio: a scheduler
# burst on a small CI box must corrupt two of three pairs to flip it
# (same stance as bench_serving).
N_TRIALS = 3
OUT_PATH = "BENCH_spec_decode.json"


def make_prompts(seed: int = 0) -> np.ndarray:
    """Seeded repetition-friendly prompts: each lane tiles its own short
    random pattern, so the n-gram draft has structure to chew on from the
    first round and greedy decode tends to settle into predictable loops."""
    rng = np.random.default_rng(seed)
    rows = [
        np.tile(rng.integers(3, 64, PROMPT_PATTERN), PROMPT_REPEATS)
        for _ in range(BATCH)
    ]
    return np.stack(rows).astype(np.int32)


def timed_generate(dec, prompts, spec=None):
    t0 = time.perf_counter()
    out = dec.generate(prompts, NEW_TOKENS, spec=spec)
    wall = time.perf_counter() - t0
    return out, wall


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_spec_decode_")
    dspec = DecodeSpec(batch=BATCH, max_seq=MAX_SEQ, bucket=BUCKET)
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = OffloadPolicy.preset("memascend").with_store(root).build()
    prompts = make_prompts()
    sc = SpecConfig(k=SPEC_K)
    trials = []
    try:
        with OffloadedDecoder(model, policy, decode=dspec) as dec:
            # warmup: one pass per mode traces every bucket/extent/k-bucket
            # the timed runs can reach (the workload is deterministic, so
            # the timed rounds replay exactly the warmed shapes)
            ref, _ = timed_generate(dec, prompts)
            warm_spec, _ = timed_generate(dec, prompts, spec=sc)
            warm = dec.session.decode_compiles()
            for _ in range(N_TRIALS):
                plain_out, plain_wall = timed_generate(dec, prompts)
                spec_out, spec_wall = timed_generate(dec, prompts, spec=sc)
                trials.append(
                    (
                        plain_wall,
                        spec_wall,
                        int(np.array_equal(plain_out, ref)),
                        int(np.array_equal(spec_out, ref)),
                    )
                )
            retraces = dec.session.decode_compiles() - warm
            stats = dec.spec_stats
            rollback_pages = dec.kv_stats["rollback_pages"]
            rollbacks = dec.kv_stats["rollbacks"]
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # Hard acceptance gates: identity and retrace-boundedness are
    # correctness claims — they fail outright, never drift through the
    # 20% regression window.
    if not np.array_equal(warm_spec, ref):
        raise AssertionError(
            "speculative decoding changed greedy output in the warmup run"
        )
    mismatched = [
        i for i, (_, _, p_ok, s_ok) in enumerate(trials) if not (p_ok and s_ok)
    ]
    if mismatched:
        raise AssertionError(
            f"output drifted across repeated runs (trials {mismatched}) — "
            f"generation must be deterministic for the paired comparison"
        )
    if retraces:
        raise AssertionError(
            f"{retraces} warm retraces in the timed runs — the k-bucketed "
            f"verify windows must stay inside the warmed trace set"
        )

    tokens = BATCH * NEW_TOKENS
    ratios = sorted(p / s for p, s, _, _ in trials)
    speedup = ratios[len(ratios) // 2]
    plain_wall, spec_wall, _, _ = sorted(trials, key=lambda t: t[0] / t[1])[
        len(trials) // 2
    ]
    if speedup <= 1.0:
        raise AssertionError(
            f"speculative decoding did not beat plain greedy at equal "
            f"output: median paired speedup {speedup:.2f}x "
            f"(samples {[f'{x:.2f}' for x in ratios]})"
        )

    report = {
        "bench": "spec_decode",
        "config": {
            "model": CFG.name,
            "n_layers": CFG.n_layers,
            "batch": BATCH,
            "max_seq": MAX_SEQ,
            "bucket": BUCKET,
            "new_tokens": NEW_TOKENS,
            "spec_k": SPEC_K,
            "workload_seed": 0,
            "n_trials": N_TRIALS,
        },
        "metrics": {
            "tokens_per_s_plain": tokens / plain_wall,
            "tokens_per_s_spec": tokens / spec_wall,
            "spec_speedup": speedup,
            "accepted_per_step": stats.accepted_per_step,
            "spec_rounds": stats.rounds,
            "spec_overhead_s": stats.spec_overhead_s,
            "rollbacks": rollbacks,
            "rollback_pages": rollback_pages,
            "token_mismatches": len(mismatched),
            "retraces_warm_spec": retraces,
        },
        # absolute tokens/s is machine-dependent (>20% run-to-run swing
        # observed on a loaded box, with the paired ratio steady), so it
        # is reported but not gated; the speedup and the acceptance rate
        # are measured within one run, so they hold across runner
        # generations.  The zero-valued counters gate at exactly zero
        # (check_regression tolerates no increase from a zero baseline).
        "gates": {
            "spec_speedup": "higher_is_better",
            "accepted_per_step": "higher_is_better",
            "token_mismatches": "lower_is_better",
            "retraces_warm_spec": "lower_is_better",
        },
        "threshold": 0.2,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    emit(
        "spec_decode/throughput",
        1e6 / (tokens / spec_wall),
        f"spec={tokens / spec_wall:.1f}tok/s "
        f"plain={tokens / plain_wall:.1f}tok/s "
        f"speedup={speedup:.2f}x median of {N_TRIALS} paired trials "
        f"(bit-identical output)",
    )
    emit(
        "spec_decode/acceptance",
        0.0,
        f"accepted_per_step={stats.accepted_per_step:.2f} "
        f"rounds={stats.rounds} drafted={stats.drafted} "
        f"accepted={stats.accepted} "
        f"overhead={stats.spec_overhead_s * 1e3:.1f}ms",
    )
    emit(
        "spec_decode/rollback",
        0.0,
        f"rollbacks={rollbacks} rollback_pages={rollback_pages} "
        f"retraces_warm={retraces} (k-bucketed verify windows)",
    )
