"""MoE expert paging, measured: routed-only expert streaming vs paging
every expert (paper Fig. 18's sparse-model point, taken past the analytic
pool-waste estimate to real fetch traffic).

Two arms run the SAME model, data, and jitted program — the expert stacks
keep their full (E, ...) shapes in both, only the bytes memcpy'd out of
the expert page cache differ — so the bench can hard-assert bitwise loss
and greedy-token identity between them before gating:

* ``all``    — every expert's pages staged per step (timing-independent
               prefetch baseline; the residency analogue of keeping
               experts resident),
* ``routed`` — only the experts the router actually selected; the
               lookahead window prestages the previous step's routed set
               and the ExpertFetchOp restages on a covering miss.

Reports measured expert fetch bytes (train + decode), the prestage hit
rate, decode tokens/s, and the expert page cache's spill/refill ledger,
then writes ``BENCH_moe.json`` for CI's ``benchmarks/check_regression.py``
gate (committed baseline in ``benchmarks/baselines/moe.json``).

The analytic Fig. 18 pool-waste sweep the stub version of this file
computed survives as the final emit rows (it costs microseconds and
reproduces the paper's 71.9% figure).
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.configs import ALL_MODELS
from repro.configs.base import ModelConfig, MoEConfig
from repro.core import DecodeSpec, OffloadSession, memascend_policy

from .common import emit, gib, time_us
from .memory_model import estimate_peak

# Small enough for CI, sparse enough that the routed set stays well under
# E: 8 tokens x top_k 2 over 16 experts routes ~7 unique experts per
# layer per train step, and a decode step routes at most 2 per layer.
CFG = ModelConfig(
    name="bench-moe",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=64),
)
BATCH, SEQ, TRAIN_STEPS = 1, 8, 3
PROMPT_LEN, NEW_TOKENS, MAX_SEQ = 8, 24, 64
PAGE_SLOTS = 64          # < 96 total pages: eviction/refill is exercised
OUT_PATH = "BENCH_moe.json"


def _train_batch():
    rng = np.random.default_rng(0)
    return (rng.integers(3, CFG.vocab, (BATCH, SEQ)).astype(np.int32),
            rng.integers(3, CFG.vocab, (BATCH, SEQ)).astype(np.int32))


def _prompts():
    return np.random.default_rng(1).integers(
        3, CFG.vocab, (BATCH, PROMPT_LEN)).astype(np.int32)


def _generate(session, kv, prompts, n):
    logits = session.prefill(kv, prompts)
    toks = [np.argmax(logits, axis=-1).astype(np.int32)]
    for _ in range(n - 1):
        logits = session.decode_step(kv, toks[-1][:, None])
        toks.append(np.argmax(logits, axis=-1).astype(np.int32))
    return np.stack(toks, axis=1)


def _run_arm(mode: str) -> dict:
    """One expert-paging mode end to end: measured train steps, then a
    cold + a timed warm greedy generation through the paged serve path."""
    from repro.core.model_adapter import make_offloadable_lm

    root = tempfile.mkdtemp(prefix=f"bench-moe-{mode}-")
    try:
        model = make_offloadable_lm(CFG, jax.random.PRNGKey(0),
                                    expert_paging=mode)
        policy = memascend_policy(root, lr=1e-2).replace(
            expert_paging=mode, expert_page_slots=PAGE_SLOTS,
            overlap="full")
        tokens, labels = _train_batch()
        with OffloadSession(model, policy,
                            decode=DecodeSpec(batch=BATCH,
                                              max_seq=MAX_SEQ)) as s:
            o0 = s.overlap_snapshot()
            losses = [s.train_step(tokens, labels)["loss"]
                      for _ in range(TRAIN_STEPS)]
            s.synchronize()
            o1 = s.overlap_snapshot()
            train_bytes = (o1["expert_fetch_bytes"]
                           - o0["expert_fetch_bytes"])

            kv = s.open_kv_cache()
            try:
                _generate(s, kv, _prompts(), NEW_TOKENS)   # cold: compiles
            finally:
                kv.close()
            o2 = s.overlap_snapshot()
            kv = s.open_kv_cache()
            try:
                t0 = time.perf_counter()
                toks = _generate(s, kv, _prompts(), NEW_TOKENS)
                dt = time.perf_counter() - t0
            finally:
                kv.close()
            o3 = s.overlap_snapshot()
            gets = o3["expert_stage_gets"] - o0["expert_stage_gets"]
            hits = o3["expert_stage_hits"] - o0["expert_stage_hits"]
            return {
                "losses": losses,
                "tokens": toks.tolist(),
                "train_expert_fetch_bytes": train_bytes,
                "decode_expert_fetch_bytes": (o3["expert_fetch_bytes"]
                                              - o2["expert_fetch_bytes"]),
                "tokens_per_s": BATCH * NEW_TOKENS / dt,
                "prefetch_hit_rate": hits / gets if gets else 1.0,
                "expert_fetch_wait_s": (o3["expert_fetch_wait_seconds"]
                                        - o0["expert_fetch_wait_seconds"]),
                "cache": s.expert_cache_stats(),
            }
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _measured() -> dict:
    all_arm = _run_arm("all")
    routed = _run_arm("routed")

    # Hard equivalence gates before any report is written: routed-only
    # residency must be bit-identical — unrouted experts' stack rows are
    # zero and never read, so any drift is a paging bug, not noise.
    loss_mismatches = sum(a != b for a, b in
                          zip(all_arm["losses"], routed["losses"]))
    token_mismatches = int(np.sum(np.asarray(all_arm["tokens"])
                                  != np.asarray(routed["tokens"])))
    assert loss_mismatches == 0, (
        f"routed vs all-resident train losses diverged: "
        f"{all_arm['losses']} vs {routed['losses']}")
    assert token_mismatches == 0, "routed vs all-resident decode diverged"
    for phase in ("train", "decode"):
        r = routed[f"{phase}_expert_fetch_bytes"]
        a = all_arm[f"{phase}_expert_fetch_bytes"]
        assert 0 < r < a, (
            f"{phase}: routed expert fetch bytes {r} not strictly below "
            f"all-resident {a}")

    metrics = {
        "loss_mismatches": loss_mismatches,
        "token_mismatches": token_mismatches,
        "train_expert_fetch_bytes_routed":
            routed["train_expert_fetch_bytes"],
        "train_expert_fetch_bytes_all":
            all_arm["train_expert_fetch_bytes"],
        "decode_expert_fetch_bytes_routed":
            routed["decode_expert_fetch_bytes"],
        "decode_expert_fetch_bytes_all":
            all_arm["decode_expert_fetch_bytes"],
        # ratios are the paper point and are exactly deterministic (the
        # byte ledgers count routed memcpys, not timing)
        "expert_bytes_ratio_train": (routed["train_expert_fetch_bytes"]
                                     / all_arm["train_expert_fetch_bytes"]),
        "expert_bytes_ratio_decode": (
            routed["decode_expert_fetch_bytes"]
            / all_arm["decode_expert_fetch_bytes"]),
        "prefetch_hit_rate_routed": routed["prefetch_hit_rate"],
        "tokens_per_s_routed": routed["tokens_per_s"],
        "tokens_per_s_all": all_arm["tokens_per_s"],
        "expert_fetch_wait_s_routed": routed["expert_fetch_wait_s"],
        "expert_page_refills_routed": routed["cache"].get("refills", 0),
        "expert_page_spills_routed": routed["cache"].get("spills", 0),
    }
    report = {
        "bench": "moe",
        "config": {
            "model": CFG.name,
            "n_layers": CFG.n_layers,
            "n_experts": CFG.moe.n_experts,
            "top_k": CFG.moe.top_k,
            "batch": BATCH,
            "seq": SEQ,
            "train_steps": TRAIN_STEPS,
            "prompt_len": PROMPT_LEN,
            "new_tokens": NEW_TOKENS,
            "max_seq": MAX_SEQ,
            "expert_page_slots": PAGE_SLOTS,
        },
        "metrics": metrics,
        "gates": {
            "loss_mismatches": "lower_is_better",
            "token_mismatches": "lower_is_better",
            "expert_bytes_ratio_train": "lower_is_better",
            "expert_bytes_ratio_decode": "lower_is_better",
            "prefetch_hit_rate_routed": "higher_is_better",
            "tokens_per_s_routed": "higher_is_better",
        },
        "threshold": 0.2,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return metrics


def run() -> None:
    m = _measured()
    emit("moe/paging/train",
         0.0,
         f"routed={m['train_expert_fetch_bytes_routed']}B "
         f"all={m['train_expert_fetch_bytes_all']}B "
         f"ratio={m['expert_bytes_ratio_train']:.2f} "
         f"loss_mismatches={m['loss_mismatches']}")
    emit("moe/paging/decode",
         0.0,
         f"routed={m['decode_expert_fetch_bytes_routed']}B "
         f"all={m['decode_expert_fetch_bytes_all']}B "
         f"ratio={m['expert_bytes_ratio_decode']:.2f} "
         f"hit_rate={m['prefetch_hit_rate_routed']:.2f} "
         f"tok/s={m['tokens_per_s_routed']:.1f} "
         f"token_mismatches={m['token_mismatches']}")

    # -- analytic Fig. 18 sweep (the original stub's rows) -------------------
    for name in ("qwen3-30b-a3b", "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b",
                 "jamba-v0.1-52b"):
        cfg = ALL_MODELS[name]
        us = time_us(lambda: estimate_peak(cfg, memascend=True, batch=1),
                     repeats=2)
        for ctx in (4096, 131072):
            b = estimate_peak(cfg, memascend=False, batch=1, ctx=ctx).total
            mm = estimate_peak(cfg, memascend=True, batch=1, ctx=ctx).total
            emit(f"moe/{name}/ctx{ctx}", us,
                 f"baseline={gib(b):.1f}GiB memascend={gib(mm):.1f}GiB "
                 f"reduction={1 - mm / b:.1%} paper(qwen3-30b)=71.4-71.9%")
