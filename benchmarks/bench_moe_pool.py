"""Paper Fig. 18: MoE (sparse) models magnify fixed-pool waste — many small
expert tensors forced into embedding-sized slots.  Paper: 71.9% reduction
for Qwen3-30B-A3B-class models."""

from __future__ import annotations

from repro.configs import ALL_MODELS

from .common import emit, gib, time_us
from .memory_model import estimate_peak


def run() -> None:
    for name in ("qwen3-30b-a3b", "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b",
                 "jamba-v0.1-52b"):
        cfg = ALL_MODELS[name]
        us = time_us(lambda: estimate_peak(cfg, memascend=True, batch=1),
                     repeats=2)
        for ctx in (4096, 131072):
            b = estimate_peak(cfg, memascend=False, batch=1, ctx=ctx).total
            m = estimate_peak(cfg, memascend=True, batch=1, ctx=ctx).total
            emit(f"moe/{name}/ctx{ctx}", us,
                 f"baseline={gib(b):.1f}GiB memascend={gib(m):.1f}GiB "
                 f"reduction={1 - m / b:.1%} paper(qwen3-30b)=71.4-71.9%")
