"""Paper Fig. 20 / Table VI: optimizer-step I/O volume per iteration,
fp32 vs bf16 optimizer states.  Paper: −58% I/O, +24–57% throughput."""

from __future__ import annotations

from repro.configs import ALL_MODELS
from repro.core import AdamConfig, OffloadedAdam

from .common import emit, gib


def run() -> None:
    fp32 = AdamConfig(state_dtype="float32")
    bf16 = AdamConfig(state_dtype="bfloat16")
    per32 = OffloadedAdam.io_bytes_per_param(fp32)
    per16 = OffloadedAdam.io_bytes_per_param(bf16)
    emit("io/bytes-per-param", 0.0,
         f"fp32={per32}B bf16={per16}B reduction={1 - per16 / per32:.1%} "
         f"paper=58%")
    for name, cfg in ALL_MODELS.items():
        n = cfg.param_count()
        emit(f"io/{name}", 0.0,
             f"fp32={gib(n * per32):.1f}GiB/iter "
             f"bf16={gib(n * per16):.1f}GiB/iter "
             f"reduction={1 - per16 / per32:.1%}")
