"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  bench_buffer_pool      Fig. 11 (+Fig. 18 censuses)
  bench_pinned_alloc     Fig. 8 pinned-overhead component
  bench_overflow         Figs. 12/13
  bench_nvme             Fig. 14
  bench_peak_memory      Table II / Fig. 15
  bench_context_scaling  Figs. 9/16 + (ours) measured activation-tier
                         ladder: max trainable seq at a fixed host
                         budget under host/ssd/recompute, loss-identity
                         and prefetch-overlap ablation (writes
                         BENCH_context.json for the CI regression gate)
  bench_batch_scaling    Figs. 10/17 + (ours) measured slot-occupancy
                         ablation (merges into BENCH_serving.json)
  bench_moe_pool         Fig. 18
  bench_io_volume        Fig. 20 / Table VI
  bench_e2e_throughput   Table IV (real steps, container scale)
  bench_kernels          (ours) kernel oracle timings + correctness
  bench_decode           (ours) cached vs uncached offloaded decode
                         (also writes BENCH_decode.json for the CI
                         regression gate; see check_regression.py)
  bench_serving          (ours) continuous vs static batching over the
                         paged KV cache (writes BENCH_serving.json for
                         the CI regression gate)
  bench_spec_decode      (ours) speculative vs plain greedy decoding on
                         the offloaded serve path (writes
                         BENCH_spec_decode.json for the CI regression
                         gate)

Selection args name a bench exactly — either the module's short name
(``bench_decode``) or that name without the ``bench_`` prefix
(``decode``).  An arg that matches nothing is an error, not a no-op.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_batch_scaling, bench_buffer_pool,
                   bench_context_scaling, bench_decode,
                   bench_e2e_throughput, bench_io_volume, bench_kernels,
                   bench_moe_pool, bench_nvme, bench_overflow,
                   bench_peak_memory, bench_pinned_alloc, bench_serving,
                   bench_spec_decode)
    modules = [
        bench_buffer_pool, bench_pinned_alloc, bench_overflow, bench_nvme,
        bench_peak_memory, bench_context_scaling, bench_moe_pool,
        bench_io_volume, bench_e2e_throughput, bench_kernels,
        bench_decode, bench_serving, bench_batch_scaling,
        bench_spec_decode,
    ]

    def matches(arg: str, mod) -> bool:
        short = mod.__name__.rsplit(".", 1)[-1]
        return arg == short or short == f"bench_{arg}"

    only = sys.argv[1:] or None
    if only:
        unknown = [a for a in only
                   if not any(matches(a, m) for m in modules)]
        if unknown:
            known = ", ".join(m.__name__.rsplit(".", 1)[-1]
                              for m in modules)
            raise SystemExit(
                f"unknown benchmark(s): {unknown}; available: {known}"
            )
    print("name,us_per_call,derived")
    failed = []
    for mod in modules:
        if only and not any(matches(a, mod) for a in only):
            continue
        try:
            mod.run()
        except Exception as e:
            failed.append(mod.__name__)
            print(f"{mod.__name__},0,ERROR:{e!r}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
