"""Batch scaling, measured: the slot-occupancy ablation.

Two halves:

* **Measured** (the point of this bench): the same seeded ragged workload
  served through real offloaded sessions at several batch widths, once
  with static full-batch scheduling and once with the continuous per-slot
  lifecycle — identical KV page budget per width.  Static pays the
  drain tax (finished lanes idle until the whole batch retires);
  continuous backfills them, so its slot occupancy and aggregate tokens/s
  scale with batch width while static's occupancy *falls* as width grows.
  Merges ``occupancy_*`` / ``speedup_*`` per width into
  ``BENCH_serving.json`` (same CI regression gate as ``bench_serving``).
* **Paper model** (Figs. 10/17 context): the analytic peak-memory curve vs
  batch size that motivates serving many requests per session at all.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax

from repro.configs import PAPER_MODELS
from repro.core import DecodeSpec, OffloadPolicy
from repro.core.model_adapter import make_offloadable_lm

from .bench_serving import (
    BUCKET,
    CFG,
    MAX_SEQ,
    OUT_PATH,
    serve_metrics,
    solo_outputs,
    timed_run,
)
from .common import emit, gib
from .memory_model import GIB, estimate_peak, max_batch_under

BATCHES = (2, 4)  # measured widths: 3 requests per slot
LIMIT = 128 * GIB


def _measure_width(batch: int) -> dict:
    from repro.serve import OffloadedDecoder

    root = tempfile.mkdtemp(prefix=f"bench_occupancy_b{batch}_")
    spec = DecodeSpec(batch=batch, max_seq=MAX_SEQ, bucket=BUCKET)
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = OffloadPolicy.preset("memascend").with_store(root).build()
    n = 3 * batch
    try:
        with OffloadedDecoder(model, policy, decode=spec) as dec:
            solo = solo_outputs(dec, n=n)
            cont_report, cont_wall = timed_run(dec, "continuous", n=n)
            stat_report, stat_wall = timed_run(dec, "static", n=n)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    cont = serve_metrics(cont_report, cont_wall, solo)
    stat = serve_metrics(stat_report, stat_wall, solo)
    if cont["token_mismatches"] or stat["token_mismatches"]:
        raise AssertionError(f"batch={batch}: batched output diverged from solo decode")
    return {
        f"occupancy_continuous_b{batch}": cont["occupancy"],
        f"occupancy_static_b{batch}": stat["occupancy"],
        f"continuous_speedup_b{batch}": cont["tokens_per_s"] / stat["tokens_per_s"],
        f"tokens_per_s_continuous_b{batch}": cont["tokens_per_s"],
        f"tokens_per_s_static_b{batch}": stat["tokens_per_s"],
    }


def _merge_into_report(metrics: dict, gates: dict) -> None:
    """Fold the sweep into BENCH_serving.json (bench_serving writes it
    first under benchmarks/run.py's ordering; standalone runs start a
    fresh report)."""
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            report = json.load(f)
    else:
        report = {
            "bench": "serving",
            "config": {},
            "metrics": {},
            "gates": {},
            "threshold": 0.2,
        }
    report["config"]["occupancy_batches"] = list(BATCHES)
    report["metrics"].update(metrics)
    report["gates"].update(gates)
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")


def run() -> None:
    metrics: dict = {}
    gates: dict = {}
    for batch in BATCHES:
        m = _measure_width(batch)
        metrics.update(m)
        # Only occupancy gates: it is a deterministic lane-step ledger for
        # a fixed workload.  The per-width wall-clock speedups are reported
        # but not gated — at 3 requests per slot the pass-count gap is
        # within this container's timing noise (the headline bench gates
        # the speedup on a workload sized to dominate it).
        gates[f"occupancy_continuous_b{batch}"] = "higher_is_better"
        emit(
            f"batch/occupancy/b{batch}",
            0.0,
            f"continuous={m[f'occupancy_continuous_b{batch}']:.3f} "
            f"static={m[f'occupancy_static_b{batch}']:.3f} "
            f"speedup={m[f'continuous_speedup_b{batch}']:.2f}x "
            f"({3 * batch} requests, equal page budget)",
        )
    _merge_into_report(metrics, gates)

    # Paper Figs. 10/17: the analytic memory headroom that makes wide
    # serving batches feasible at all (batch 4 -> 32 on qwen2.5-7b under
    # 128 GiB in the paper).
    for name in ("llama3.1-8b", "qwen2.5-7b"):
        cfg = PAPER_MODELS[name]
        base = estimate_peak(cfg, memascend=False, batch=32).total
        mem = estimate_peak(cfg, memascend=True, batch=32).total
        bb = max_batch_under(cfg, LIMIT, memascend=False)
        bm = max_batch_under(cfg, LIMIT, memascend=True)
        emit(
            f"batch/{name}/max@128GiB",
            0.0,
            f"baseline_max={bb} memascend_max={bm} "
            f"(batch32: baseline={gib(base):.1f}GiB "
            f"memascend={gib(mem):.1f}GiB) paper(qwen2.5-7b)=4->32",
        )
