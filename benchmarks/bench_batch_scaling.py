"""Paper Figs. 10/17: peak memory vs batch size + max batch under 128 GiB.
Paper: batch 4 -> 32 on Qwen2.5-7B under 128 GiB (8x tokens/s)."""

from __future__ import annotations

from repro.configs import PAPER_MODELS

from .common import emit, gib, time_us
from .memory_model import GIB, estimate_peak, max_batch_under

BATCHES = (1, 4, 8, 16, 32, 64, 96)
LIMIT = 128 * GIB


def run() -> None:
    for name in ("llama3.1-8b", "qwen2.5-7b"):
        cfg = PAPER_MODELS[name]
        for b in BATCHES:
            us = time_us(lambda: estimate_peak(cfg, memascend=True, batch=b),
                         repeats=2)
            base = estimate_peak(cfg, memascend=False, batch=b).total
            mem = estimate_peak(cfg, memascend=True, batch=b).total
            emit(f"batch/{name}/{b}", us,
                 f"baseline={gib(base):.1f}GiB memascend={gib(mem):.1f}GiB")
        bb = max_batch_under(cfg, LIMIT, memascend=False)
        bm = max_batch_under(cfg, LIMIT, memascend=True)
        emit(f"batch/{name}/max@128GiB", 0.0,
             f"baseline_max={bb} memascend_max={bm} paper(qwen2.5-7b)=4->32")
