"""Paper Fig. 11: parameter-buffer-pool memory, fixed vs adaptive, per model.

Also covers Fig. 18's census view for the MoE models.  Paper reference:
72.71% average pool-memory reduction.
"""

from __future__ import annotations

from repro.configs import ALL_MODELS
from repro.core import (AdaptiveBufferPool, AlignmentFreeAllocator,
                        FixedBufferPool, MemoryTracker)

from .common import emit, gib, time_us


def run() -> None:
    reductions = []
    for name, cfg in ALL_MODELS.items():
        census = cfg.pool_census(inflight_blocks=1, shards=2)

        def make_pools():
            t = MemoryTracker()
            f = FixedBufferPool(census, AlignmentFreeAllocator(
                tracker=t, component="f"))
            a = AdaptiveBufferPool(census, AlignmentFreeAllocator(
                tracker=t, component="a"))
            return f, a

        us = time_us(make_pools, repeats=3)
        fixed, adaptive = make_pools()
        red = 1 - adaptive.pool_bytes / fixed.pool_bytes
        reductions.append(red)
        emit(f"pool/{name}", us,
             f"fixed={gib(fixed.pool_bytes):.2f}GiB "
             f"adaptive={gib(adaptive.pool_bytes):.2f}GiB "
             f"reduction={red:.1%}")
        fixed.close(); adaptive.close()
    emit("pool/average", 0.0,
         f"avg_reduction={sum(reductions)/len(reductions):.1%} "
         f"paper=72.71%")
