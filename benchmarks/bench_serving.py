"""Continuous batching vs static batching on the offloaded serving path.

One seeded ragged workload — Poisson arrivals, mixed prompt lengths, mixed
generation budgets — served three ways through the same session, model,
and KV page budget:

* ``solo``       — every request decoded entirely alone (a fresh engine
                   run per request).  This is the reference ledger for the
                   token-equality gate AND the jit warmup: a solo pass
                   visits every prompt bucket and every step extent the
                   batched runs can produce, so the timed runs must
                   retrace nothing.
* ``continuous`` — per-slot request lifecycle: joiners prefill-scatter
                   into free slots mid-flight, finished requests retire
                   (pages reclaimed, slot rejoins the free list) while the
                   rest keep decoding.
* ``static``     — the ablation: full batches formed in arrival order,
                   nothing admitted until the whole batch drains.

Acceptance gates (hard failures here, regression-gated in CI):

* every request's continuous-run tokens == its solo-run tokens (greedy,
  exact) — batching must never change output;
* zero warm retraces across both timed runs;
* continuous beats static on aggregate tokens/s AND p99 time-to-first-
  token under the identical page budget — judged on the median of
  ``N_TRIALS`` back-to-back paired runs, so a one-off scheduler burst
  on a small CI box cannot flip the verdict.

Writes ``BENCH_serving.json`` for ``benchmarks/check_regression.py``
(committed baseline in ``benchmarks/baselines/serving.json``);
``bench_batch_scaling.py`` merges its occupancy ablation into the same
report.
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DecodeSpec, OffloadPolicy
from repro.core.model_adapter import make_offloadable_lm
from repro.serve import OffloadedDecoder, Request, RequestState, ServingEngine

from .common import emit

CFG = ModelConfig(
    name="bench-20m",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab=8192,
)
BATCH, MAX_SEQ, BUCKET = 4, 160, 32
N_REQUESTS = 16
PROMPT_LEN_RANGE = (6, 32)  # rng.integers bounds (exclusive high)
# Serving economics at bench scale: every join costs one full prefill pass
# (a whole weight-streamed sweep), so continuous batching only wins when
# decode steps outnumber joins decisively — generations must run long, and
# their *spread* is the drain tax static batching pays (it drains at the
# batch max while continuous pays the mean).  Short or narrow generation
# budgets make both modes do nearly the same number of weight-streamed
# passes and the comparison sinks into 2-CPU wall-clock noise.
MAX_NEW_RANGE = (16, 96)
# r00: spans two prompt buckets (coverage for multi-bucket prefill at
# bench scale)
LONG_PROMPT_LEN = 45
ARRIVAL_MEAN_S = 0.005  # Poisson: arrivals much faster than service
# The structural continuous-vs-static margin at this scale (~1.15-1.2x) is
# real but thinner than 2-CPU wall-clock noise on a bad day: one scheduler
# burst landing inside a single timed window can flip an unpaired sample.
# So each trial times the two modes back-to-back (paired — drift hits
# both) and the gates take the *median of the per-trial ratios*: a noise
# event has to corrupt two of three pairs to change the verdict.
N_TRIALS = 3
OUT_PATH = "BENCH_serving.json"


def make_workload(seed: int = 0, n: int = N_REQUESTS) -> list[Request]:
    """The seeded ragged-arrival request set (fresh Request objects each
    call — requests are stateful)."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(*PROMPT_LEN_RANGE, size=n)
    lens[0] = LONG_PROMPT_LEN
    news = rng.integers(*MAX_NEW_RANGE, size=n)
    arrivals = np.cumsum(rng.exponential(scale=ARRIVAL_MEAN_S, size=n))
    return [
        Request(
            rid=f"r{i:02d}",
            prompt=rng.integers(3, CFG.vocab, size=int(lens[i]), dtype=np.int32),
            max_new_tokens=int(news[i]),
            arrival=float(arrivals[i]),
        )
        for i in range(n)
    ]


def solo_outputs(decoder, seed: int = 0, n: int = N_REQUESTS) -> dict:
    """Decode every request alone through the engine (reference + warmup:
    covers each prompt bucket and every step extent the batched runs use)."""
    outputs = {}
    for _i, req in enumerate(make_workload(seed, n)):
        req.arrival = 0.0
        report = ServingEngine(decoder).run([req])
        assert report.requests[0].state is RequestState.DONE
        outputs[req.rid] = list(report.requests[0].output)
    return outputs


def timed_run(decoder, mode: str, seed: int = 0, n: int = N_REQUESTS):
    t0 = time.perf_counter()
    report = ServingEngine(decoder).run(make_workload(seed, n), mode=mode)
    wall = time.perf_counter() - t0
    return report, wall


def _mismatches(report, solo: dict) -> int:
    return sum(1 for r in report.requests if list(r.output) != solo[r.rid])


def serve_metrics(report, wall: float, solo: dict) -> dict:
    assert not report.refused, "workload must be fully admissible"
    return {
        "tokens_per_s": report.total_tokens / wall,
        "ttft_p50_s": report.ttft_percentile(50),
        "ttft_p99_s": report.ttft_percentile(99),
        "occupancy": report.occupancy,
        "decode_steps": report.decode_steps,
        "prefills": report.prefills,
        "token_mismatches": _mismatches(report, solo),
        "kv_reclaims": report.kv_stats["reclaims"],
        "kv_spills": report.kv_stats["spills"],
    }


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_serving_")
    spec = DecodeSpec(batch=BATCH, max_seq=MAX_SEQ, bucket=BUCKET)
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = OffloadPolicy.preset("memascend").with_store(root).build()
    trials = []
    try:
        with OffloadedDecoder(model, policy, decode=spec) as dec:
            solo = solo_outputs(dec)
            warm = dec.session.decode_compiles()
            for _ in range(N_TRIALS):
                cont_report, cont_wall = timed_run(dec, "continuous")
                stat_report, stat_wall = timed_run(dec, "static")
                trials.append(
                    (
                        serve_metrics(cont_report, cont_wall, solo),
                        serve_metrics(stat_report, stat_wall, solo),
                        len(cont_report.refused) + len(stat_report.refused),
                    )
                )
            retraces = dec.session.decode_compiles() - warm
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # Everything but wall time is deterministic across trials (same seeded
    # workload, same drive loop); pick the median-throughput continuous
    # trial for the reported absolutes and gate on median paired ratios.
    speedups = sorted(c["tokens_per_s"] / s["tokens_per_s"] for c, s, _ in trials)
    ttft_ratios = sorted(s["ttft_p99_s"] / c["ttft_p99_s"] for c, s, _ in trials)
    cont, stat, _ = sorted(trials, key=lambda t: t[0]["tokens_per_s"])[len(trials) // 2]

    # Hard acceptance gates — these are correctness/ordering claims, not
    # perf points, so they fail the bench outright rather than drifting
    # through the 20% regression window.
    bad = [
        (i, c["token_mismatches"], s["token_mismatches"])
        for i, (c, s, _) in enumerate(trials)
        if c["token_mismatches"] or s["token_mismatches"]
    ]
    if bad:
        raise AssertionError(
            f"batched serving changed greedy output vs solo decode "
            f"(trial, continuous, static mismatch counts): {bad}"
        )
    if retraces:
        raise AssertionError(
            f"{retraces} warm retraces in the timed serving runs — the "
            f"solo pass must have warmed every bucket and extent"
        )
    speedup = speedups[len(speedups) // 2]
    ttft_ratio = ttft_ratios[len(ttft_ratios) // 2]
    if speedup <= 1.0:
        raise AssertionError(
            f"continuous batching did not beat static on aggregate "
            f"throughput: median paired speedup {speedup:.2f}x "
            f"(samples {[f'{x:.2f}' for x in speedups]})"
        )
    if ttft_ratio <= 1.0:
        raise AssertionError(
            f"continuous batching did not beat static on p99 TTFT: "
            f"median paired ratio {ttft_ratio:.2f}x "
            f"(samples {[f'{x:.2f}' for x in ttft_ratios]})"
        )

    report = {
        "bench": "serving",
        "config": {
            "model": CFG.name,
            "n_layers": CFG.n_layers,
            "batch": BATCH,
            "max_seq": MAX_SEQ,
            "bucket": BUCKET,
            "n_requests": N_REQUESTS,
            "prompt_len_range": list(PROMPT_LEN_RANGE),
            "max_new_range": list(MAX_NEW_RANGE),
            "arrival_mean_s": ARRIVAL_MEAN_S,
            "workload_seed": 0,
            "n_trials": N_TRIALS,
        },
        "metrics": {
            "tokens_per_s_continuous": cont["tokens_per_s"],
            "tokens_per_s_static": stat["tokens_per_s"],
            "continuous_speedup": speedup,
            "ttft_p50_s_continuous": cont["ttft_p50_s"],
            "ttft_p99_s_continuous": cont["ttft_p99_s"],
            "ttft_p50_s_static": stat["ttft_p50_s"],
            "ttft_p99_s_static": stat["ttft_p99_s"],
            "ttft_p99_ratio_static_over_continuous": ttft_ratio,
            "occupancy_continuous": cont["occupancy"],
            "occupancy_static": stat["occupancy"],
            "decode_steps_continuous": cont["decode_steps"],
            "decode_steps_static": stat["decode_steps"],
            "prefills_continuous": cont["prefills"],
            "kv_reclaims_continuous": cont["kv_reclaims"],
            "token_mismatches": sum(
                c["token_mismatches"] + s["token_mismatches"] for c, s, _ in trials
            ),
            "retraces_warm_serving": retraces,
            "requests_refused": sum(r for _, _, r in trials),
        },
        # absolute tokens/s is machine-dependent (same stance as
        # bench_decode); the speedup and TTFT ratios are measured within
        # one run, so they hold across runner generations.  The three
        # zero-valued counters gate at exactly zero (check_regression
        # tolerates no increase from a zero baseline).
        "gates": {
            "tokens_per_s_continuous": "higher_is_better",
            "continuous_speedup": "higher_is_better",
            "ttft_p99_ratio_static_over_continuous": "higher_is_better",
            "occupancy_continuous": "higher_is_better",
            "token_mismatches": "lower_is_better",
            "retraces_warm_serving": "lower_is_better",
            "requests_refused": "lower_is_better",
        },
        "threshold": 0.2,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    emit(
        "serving/throughput",
        1e6 / cont["tokens_per_s"],
        f"continuous={cont['tokens_per_s']:.1f}tok/s "
        f"static={stat['tokens_per_s']:.1f}tok/s "
        f"speedup={speedup:.2f}x median of {N_TRIALS} paired trials "
        f"(same KV page budget)",
    )
    emit(
        "serving/ttft",
        cont["ttft_p99_s"] * 1e6,
        f"p50={cont['ttft_p50_s'] * 1e3:.1f}ms "
        f"p99={cont['ttft_p99_s'] * 1e3:.1f}ms vs static "
        f"p99={stat['ttft_p99_s'] * 1e3:.1f}ms ({ttft_ratio:.2f}x)",
    )
    emit(
        "serving/occupancy",
        0.0,
        f"continuous={cont['occupancy']:.3f} static={stat['occupancy']:.3f} "
        f"steps={cont['decode_steps']}/{stat['decode_steps']} "
        f"prefills={cont['prefills']}",
    )
    emit(
        "serving/equivalence",
        0.0,
        f"token_mismatches=0/{2 * N_TRIALS * N_REQUESTS} "
        f"retraces_warm={retraces} "
        f"reclaims={cont['kv_reclaims']} (greedy output identical to "
        f"decoding each request alone)",
    )
