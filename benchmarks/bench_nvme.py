"""Paper Fig. 14: SSD read/write latency + bandwidth across tensor sizes,
per-tensor-file (ext4-like) baseline vs the direct-LBA engine, on this
container's real disk."""

from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.core import DirectNVMeEngine, FilesystemEngine

from .common import emit, time_us

SIZES = (2 << 20, 16 << 20, 128 << 20, 512 << 20)   # 2MiB .. 512MiB


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_nvme_")
    try:
        free = shutil.disk_usage(root).free
        sizes = [s for s in SIZES if s * 4 < free // 4]
        engines = {
            "fs": FilesystemEngine(root + "/fs", fsync=True),
            "direct": DirectNVMeEngine(root + "/raw", n_devices=2,
                                       device_capacity=max(sizes) * 2 + (64 << 20),
                                       n_workers=4),
        }
        rng = np.random.default_rng(0)
        for size in sizes:
            data = rng.integers(0, 255, size, dtype=np.uint8)
            out = np.empty_like(data)
            row = {}
            for name, eng in engines.items():
                key = f"t{size}"
                w_us = time_us(lambda eng=eng, key=key, data=data:
                               eng.write(key, data), repeats=3)
                r_us = time_us(lambda eng=eng, key=key, out=out:
                               eng.read(key, out), repeats=3)
                row[name] = (w_us, r_us)
                eng.delete(key) if name == "fs" else None
            (fw, fr), (dw, dr) = row["fs"], row["direct"]
            emit(f"nvme/write/{size >> 20}MiB", dw,
                 f"fs_us={fw:.0f} direct_us={dw:.0f} "
                 f"fs_bw={size / fw / 1e3:.0f}MB/s "
                 f"direct_bw={size / dw / 1e3:.0f}MB/s "
                 f"speedup={fw / dw:.2f}x paper_avg=+72%")
            emit(f"nvme/read/{size >> 20}MiB", dr,
                 f"fs_us={fr:.0f} direct_us={dr:.0f} "
                 f"fs_bw={size / fr / 1e3:.0f}MB/s "
                 f"direct_bw={size / dr / 1e3:.0f}MB/s "
                 f"speedup={fr / dr:.2f}x paper=comparable-mean")
        for eng in engines.values():
            eng.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
