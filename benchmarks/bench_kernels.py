"""Kernel microbenchmarks (ours, beyond-paper): interpret-mode Pallas vs
pure-jnp oracle wall time is NOT meaningful on CPU; what we report instead
is correctness deltas + the jnp-oracle throughput as the reference the TPU
kernels are validated against."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, time_us


def run() -> None:
    rng = np.random.default_rng(0)
    # fused overflow check, jnp formulation (the jitted on-device screen)
    g = jnp.asarray(rng.standard_normal(4 << 20), jnp.float32)
    from repro.core.overflow import (baseline_overflow_check_jnp,
                                     fused_overflow_check_jnp)
    f_fused = jax.jit(fused_overflow_check_jnp)
    f_base = jax.jit(baseline_overflow_check_jnp)
    us_f = time_us(lambda: jax.block_until_ready(f_fused(g)))
    us_b = time_us(lambda: jax.block_until_ready(f_base(g)))
    emit("kernel/overflow-jnp-4M", us_f,
         f"chained_us={us_b:.0f} fused_us={us_f:.0f} "
         f"speedup={us_b / us_f:.2f}x")

    # fused adam vs 4-op reference, jit'd oracle timing
    n = 1 << 20
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    gr = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.zeros(n); v = jnp.zeros(n)
    f_ref = jax.jit(lambda *a: ref.ref_fused_adam(*a))
    us_ref = time_us(lambda: jax.block_until_ready(f_ref(p, gr, m, v, 1)))
    out_k = ops.fused_adam(p, gr, m, v, 1)
    out_r = f_ref(p, gr, m, v, 1)
    err = float(jnp.abs(out_k[0] - out_r[0]).max())
    emit("kernel/fused-adam-1M", us_ref,
         f"oracle_us={us_ref:.0f} kernel_maxerr={err:.1e}")

    # swa attention kernel vs oracle
    b, h, s, d = 1, 4, 1024, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    f_oracle = jax.jit(lambda q, k, v: ref.ref_swa_attention(
        q, k, v, window=256))
    us_o = time_us(lambda: jax.block_until_ready(f_oracle(q, k, vv)))
    out = ops.swa_attention(q, k, vv, window=256)
    err = float(jnp.abs(out - f_oracle(q, k, vv)).max())
    emit("kernel/swa-1k", us_o,
         f"oracle_us={us_o:.0f} kernel_maxerr={err:.1e} window=256")
