"""Cached vs uncached offloaded decode: the O(T) serving ablation.

Three configurations of the same model, prompts, and greedy loop:

* ``uncached``     — PR-1 behaviour: every emitted token re-runs the full
                     prefix (O(T^2) compute) and retraces the jitted stages
                     as the (batch, time) shape grows.
* ``cached``       — paged spill-able KV cache, every page host-resident.
* ``cached_spill`` — KV residency budget of 2 layer-equivalents in pages:
                     cold pages round-trip through the SSD store,
                     prefetched + gathered on the staging worker under
                     compute.

A second, long-context ablation isolates what paging the time axis buys:
the same generation under the same host KV budget, once with bucket-sized
pages (only dirty tail pages pay spill writes; clean pages drop for free)
and once with ``page_tokens == max_seq`` — PR 2's whole-layer spill unit.
The paged configuration's KV spill bytes must come in strictly below the
whole-layer value, with identical output tokens.

Reports tokens/s, retrace counts (cold compile count and warm retraces —
the acceptance bar is zero warm retraces per bucket), peak host bytes,
fetch-wait seconds, and a teacher-forced equivalence audit (cached logits
within ~8 row-max bf16 ULPs of uncached at every step; greedy flips only
at provable near-ties; spill round-trips token-exact), then writes
``BENCH_decode.json`` for CI's ``benchmarks/check_regression.py`` gate
(committed baseline in ``benchmarks/baselines/decode.json``).
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DecodeSpec, OffloadPolicy
from repro.core.model_adapter import make_offloadable_lm
from repro.core.session import jit_cache_size
from repro.serve import OffloadedDecoder

from .common import emit

CFG = ModelConfig(
    name="bench-20m",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab=8192,
)
BATCH, PROMPT_LEN, NEW_TOKENS = 4, 32, 48
BUCKET, MAX_SEQ = 32, 96
# Long-context spill ablation: same host KV budget (2 layer-equivalents),
# paged (bucket-sized pages) vs whole-layer (page_tokens == max_seq, the
# PR-2 spill unit).
LC_MAX_SEQ, LC_NEW_TOKENS = 192, 48
OUT_PATH = "BENCH_decode.json"


def _decode_compiles(session) -> int:
    """Trace count across whichever stages this path jits (the guarded
    probe in repro.core.session owns the private-jax-API touch point)."""
    cached = session.decode_compiles()
    uncached = jit_cache_size(session._jit_block)
    return cached + uncached


def _prompts() -> np.ndarray:
    return np.random.default_rng(0).integers(
        3, CFG.vocab, size=(BATCH, PROMPT_LEN), dtype=np.int32
    )


def _run(root: str, spec: DecodeSpec | None) -> dict:
    """One configuration: warmup generate, then a timed warm generate."""
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = OffloadPolicy.preset("memascend").with_store(root).build()
    prompts = _prompts()
    with OffloadedDecoder(model, policy, decode=spec) as dec:
        session = dec.session
        dec.generate(prompts, NEW_TOKENS)  # cold: compiles every stage
        cold_compiles = _decode_compiles(session)
        wait0 = session.swapper.stats.wait_seconds
        t0 = time.perf_counter()
        tokens = dec.generate(prompts, NEW_TOKENS)
        dt = time.perf_counter() - t0
        early, late = _per_token_profile(dec, prompts, spec)
        result = {
            "tokens": tokens.tolist(),   # full sequences: equivalence gate
            "tokens_per_s": BATCH * NEW_TOKENS / dt,
            "compiles_cold": cold_compiles,
            "retraces_warm": _decode_compiles(session) - cold_compiles,
            "peak_host_bytes": session.tracker.peak_allocated,
            "fetch_wait_s": session.swapper.stats.wait_seconds - wait0,
            "step_s_early": early,
            "step_s_late": late,
            "kv": dec.kv_stats,
            "kv_overlap": dec.kv_overlap_stats,
        }
    return result


def _run_spill_ablation(root: str, spec: DecodeSpec, prompts) -> dict:
    """One long-context cached generate; returns tokens + the KV spill
    ledger (the paged-vs-whole-layer comparison needs bytes, not time).

    Runs under overlap="sync" so the byte ledgers are exactly
    deterministic and can gate with zero noise: with the staging worker
    on, its MRU touches/pins race the compute thread's eviction scan and
    the dirty-spill vs clean-drop mix can drift run to run."""
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = (
        OffloadPolicy.preset("memascend")
        .with_store(root)
        .with_overlap("sync")
        .build()
    )
    with OffloadedDecoder(model, policy, decode=spec) as dec:
        tokens = dec.generate(prompts, LC_NEW_TOKENS)
        return {"tokens": tokens.tolist(), "kv": dec.kv_stats}


def _uncached_reference(root: str, prompts) -> tuple[np.ndarray, list]:
    """Greedy tokens + per-step logits from the uncached full-prefix path."""
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = OffloadPolicy.preset("memascend").with_store(root).build()
    ctx = prompts
    logits_seq = []
    with OffloadedDecoder(model, policy) as dec:
        for _ in range(NEW_TOKENS):
            logits = dec.step_logits(ctx)
            logits_seq.append(np.asarray(logits, np.float32))
            nxt = np.argmax(logits, axis=-1).astype(np.int32)
            ctx = np.concatenate([ctx, nxt[:, None]], axis=1)
    return ctx[:, prompts.shape[1] :], logits_seq


# Per-step tolerance: ~8 bf16 ULPs of each row's max logit.  The cached and
# uncached paths run the same math through different matmul shapes, so XLA's
# reduction tiling wobbles the last significand bit and four layers of bf16
# compound it to a few ULPs (measured ~2e-2 on this model).  Real cache bugs
# (stale/misplaced K/V, wrong masking) shift logits at row-max scale, an
# order of magnitude past this bound.
ULP_TOL = 8.0 * 2.0**-8


def _cached_equivalence(root: str, spec: DecodeSpec, prompts, ref_logits) -> dict:
    """Teacher-forced per-step check: cached logits must match the uncached
    reference within ULP_TOL, and any greedy argmax flip must be a provable
    near-tie (top tokens within tolerance in the reference logits)."""
    model = make_offloadable_lm(CFG, jax.random.PRNGKey(0))
    policy = OffloadPolicy.preset("memascend").with_store(root).build()
    max_rel = 0.0
    agree = flips_beyond_tol = 0
    with OffloadedDecoder(model, policy, decode=spec) as dec:
        session = dec.session
        kv = session.open_kv_cache()
        try:
            logits = session.prefill(kv, prompts)
            for t, ref in enumerate(ref_logits):
                got = np.asarray(logits, np.float32)
                # row-scaled: ULPs of the max logit, the unit greedy
                # decode actually compares in
                scale = np.maximum(np.abs(ref).max(-1, keepdims=True), 1.0)
                rel = np.abs(got - ref) / scale
                max_rel = max(max_rel, float(rel.max()))
                if (rel > ULP_TOL).any():
                    raise AssertionError(
                        f"cached decode diverged from uncached at step {t}: "
                        f"max row-scaled logit diff {rel.max():.3e} > "
                        f"{ULP_TOL:.3e}"
                    )
                am_got, am_ref = got.argmax(-1), ref.argmax(-1)
                agree += int((am_got == am_ref).sum())
                for b in np.nonzero(am_got != am_ref)[0]:
                    gap = ref[b, am_ref[b]] - ref[b, am_got[b]]
                    if gap > ULP_TOL * scale[b, 0]:
                        flips_beyond_tol += 1
                if t + 1 < len(ref_logits):
                    # teacher-forced on the reference's greedy choice
                    step = np.argmax(ref, axis=-1).astype(np.int32)[:, None]
                    logits = session.decode_step(kv, step)
        finally:
            kv.close()
    if flips_beyond_tol:
        raise AssertionError(
            f"cached decode flipped {flips_beyond_tol} greedy argmaxes "
            f"beyond the near-tie tolerance"
        )
    total = len(ref_logits) * prompts.shape[0]
    return {
        "logit_max_rel_diff": max_rel,
        "argmax_agreement": agree / total,
        "argmax_flips_beyond_tol": flips_beyond_tol,
    }


def _per_token_profile(dec, prompts, spec) -> tuple[float, float]:
    """Mean per-token seconds for the first vs last quarter of a warm
    generation — the O(T) acceptance probe: cached decode's per-token cost
    must not depend on the emitted-token index, while the uncached path's
    grows with the prefix it re-runs."""
    times = []
    if spec is not None:
        session = dec.session
        kv = session.open_kv_cache()
        try:
            logits = session.prefill(kv, prompts)
            step = np.argmax(logits, axis=-1).astype(np.int32)[:, None]
            for _ in range(NEW_TOKENS - 1):
                t0 = time.perf_counter()
                session.decode_step(kv, step)
                times.append(time.perf_counter() - t0)
        finally:
            kv.close()
    else:
        ctx = prompts
        for _i in range(NEW_TOKENS - 1):
            t0 = time.perf_counter()
            logits = dec.step_logits(ctx)
            times.append(time.perf_counter() - t0)
            step = np.argmax(logits, axis=-1).astype(np.int32)
            ctx = np.concatenate([ctx, step[:, None]], axis=1)
    q = max(1, len(times) // 4)
    return sum(times[:q]) / q, sum(times[-q:]) / q


def run() -> None:
    root = tempfile.mkdtemp(prefix="bench_decode_")
    spec = DecodeSpec(batch=BATCH, max_seq=MAX_SEQ, bucket=BUCKET)
    spill = DecodeSpec(batch=BATCH, max_seq=MAX_SEQ, bucket=BUCKET, resident_blocks=2)
    lc_paged = DecodeSpec(
        batch=BATCH, max_seq=LC_MAX_SEQ, bucket=BUCKET, resident_blocks=2
    )
    lc_layer = DecodeSpec(
        batch=BATCH,
        max_seq=LC_MAX_SEQ,
        bucket=BUCKET,
        resident_blocks=2,
        page_tokens=LC_MAX_SEQ,
    )
    try:
        uncached = _run(root + "/u", None)
        cached = _run(root + "/c", spec)
        spilled = _run(root + "/s", spill)
        paged = _run_spill_ablation(root + "/lp", lc_paged, _prompts())
        layer = _run_spill_ablation(root + "/ll", lc_layer, _prompts())
        _ref_tokens, ref_logits = _uncached_reference(root + "/r", _prompts())
        equiv = _cached_equivalence(root + "/e", spec, _prompts(), ref_logits)
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # Page-size acceptance gates for the long-context ablation: paging only
    # changes the spill/refill unit, never the jitted math, so tokens must
    # match exactly — and the whole point of the block table is that the
    # same host budget moves strictly fewer spill bytes.
    if paged["tokens"] != layer["tokens"]:
        raise AssertionError(
            f"page size changed the decoded tokens: {paged['tokens']} vs "
            f"{layer['tokens']}"
        )
    if not paged["kv"]["spill_bytes"] < layer["kv"]["spill_bytes"]:
        raise AssertionError(
            f"paged spill I/O ({paged['kv']['spill_bytes']} B) is not below "
            f"the whole-layer spill unit ({layer['kv']['spill_bytes']} B)"
        )

    # Equivalence acceptance gates, every emitted step, every request:
    # (1) spilling is lossless — the two cached variants run identical
    #     jitted shapes, so their free-running tokens must match exactly;
    # (2) cached-vs-uncached logits agree to within ~2 bf16 ULPs per step
    #     (teacher-forced; raises inside _cached_equivalence), with greedy
    #     argmax flips allowed only at provable near-ties — free-running
    #     token equality alone is chaotic under 1-ULP matmul-shape wobble.
    if spilled["tokens"] != cached["tokens"]:
        raise AssertionError(
            f"KV spill round-trip changed the decoded tokens: "
            f"{spilled['tokens']} vs {cached['tokens']}"
        )

    speedup = cached["tokens_per_s"] / uncached["tokens_per_s"]
    report = {
        "bench": "decode",
        "config": {
            "model": CFG.name,
            "n_layers": CFG.n_layers,
            "batch": BATCH,
            "prompt_len": PROMPT_LEN,
            "new_tokens": NEW_TOKENS,
            "bucket": BUCKET,
            "max_seq": MAX_SEQ,
            "spill_resident_blocks": 2,
            "page_tokens": BUCKET,
            "lc_max_seq": LC_MAX_SEQ,
            "lc_new_tokens": LC_NEW_TOKENS,
        },
        "metrics": {
            "tokens_per_s_cached": cached["tokens_per_s"],
            "tokens_per_s_cached_spill": spilled["tokens_per_s"],
            "tokens_per_s_uncached": uncached["tokens_per_s"],
            "speedup_cached_vs_uncached": speedup,
            "retraces_warm_cached": cached["retraces_warm"],
            "retraces_warm_uncached": uncached["retraces_warm"],
            "compiles_cold_cached": cached["compiles_cold"],
            "compiles_cold_uncached": uncached["compiles_cold"],
            "peak_host_bytes_cached": cached["peak_host_bytes"],
            "peak_host_bytes_cached_spill": spilled["peak_host_bytes"],
            "peak_host_bytes_uncached": uncached["peak_host_bytes"],
            "fetch_wait_s_cached": cached["fetch_wait_s"],
            "fetch_wait_s_uncached": uncached["fetch_wait_s"],
            "step_time_late_vs_early_cached": (
                cached["step_s_late"] / cached["step_s_early"]
            ),
            "step_time_late_vs_early_uncached": (
                uncached["step_s_late"] / uncached["step_s_early"]
            ),
            "kv_spills": spilled["kv"]["spills"],
            "kv_clean_drops": spilled["kv"]["clean_drops"],
            "kv_refills": spilled["kv"]["refills"],
            "kv_prefetch_hits": spilled["kv"]["prefetch_hits"],
            "kv_spill_bytes": spilled["kv"]["spill_bytes"],
            "kv_wait_s": spilled["kv"]["wait_seconds"],
            "kv_stage_gets": spilled["kv_overlap"]["kv_stage_gets"],
            "kv_stage_hits": spilled["kv_overlap"]["kv_stage_hits"],
            "kv_stage_wait_s": spilled["kv_overlap"]["kv_stage_wait_s"],
            "lc_kv_spill_bytes_paged": paged["kv"]["spill_bytes"],
            "lc_kv_spill_bytes_whole_layer": layer["kv"]["spill_bytes"],
            "lc_kv_refill_bytes_paged": paged["kv"]["refill_bytes"],
            "lc_kv_refill_bytes_whole_layer": layer["kv"]["refill_bytes"],
            "lc_kv_clean_drops_paged": paged["kv"]["clean_drops"],
            "logit_max_rel_diff": equiv["logit_max_rel_diff"],
            "argmax_agreement": equiv["argmax_agreement"],
            "argmax_flips_beyond_tol": equiv["argmax_flips_beyond_tol"],
        },
        # tokens/s is the gate the issue asks for but is machine-dependent;
        # the speedup ratio is measured within one run, so it holds across
        # runner generations even when absolute throughput shifts.
        "gates": {
            "tokens_per_s_cached": "higher_is_better",
            "speedup_cached_vs_uncached": "higher_is_better",
            "peak_host_bytes_cached": "lower_is_better",
            "retraces_warm_cached": "lower_is_better",
            "argmax_flips_beyond_tol": "lower_is_better",
            "argmax_agreement": "higher_is_better",
            # the LC ablation runs under overlap="sync", so its byte
            # ledger is exactly deterministic — a paged-eviction
            # regression moves it, timing noise cannot.  (kv_spill_bytes
            # from the overlapped short config is reported but NOT gated:
            # the staging worker's MRU touches race the eviction scan, so
            # its dirty/clean mix can drift a little run to run.)
            "lc_kv_spill_bytes_paged": "lower_is_better",
        },
        "threshold": 0.2,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    emit(
        "decode/throughput",
        1e6 / cached["tokens_per_s"],
        f"cached={cached['tokens_per_s']:.1f}tok/s "
        f"uncached={uncached['tokens_per_s']:.1f}tok/s "
        f"speedup={speedup:.2f}x",
    )
    emit(
        "decode/retraces",
        0.0,
        f"warm_cached={cached['retraces_warm']} "
        f"warm_uncached={uncached['retraces_warm']} "
        f"cold_cached={cached['compiles_cold']} "
        f"cold_uncached={uncached['compiles_cold']}",
    )
    emit(
        "decode/kv-spill",
        1e6 / spilled["tokens_per_s"],
        f"spill_tput={spilled['tokens_per_s']:.1f}tok/s "
        f"spills={spilled['kv']['spills']} "
        f"clean_drops={spilled['kv']['clean_drops']} "
        f"refills={spilled['kv']['refills']} "
        f"prefetch_hits={spilled['kv']['prefetch_hits']}",
    )
    emit(
        "decode/kv-overlap",
        spilled["kv_overlap"]["kv_stage_wait_s"] * 1e6,
        f"staged_gets={spilled['kv_overlap']['kv_stage_gets']} "
        f"hits={spilled['kv_overlap']['kv_stage_hits']} "
        f"wait={spilled['kv_overlap']['kv_stage_wait_s'] * 1e3:.1f}ms "
        f"(KV H2D on the staging worker, off the compute thread)",
    )
    emit(
        "decode/paged-spill-bytes",
        float(paged["kv"]["spill_bytes"]),
        f"paged={paged['kv']['spill_bytes'] / 1e6:.2f}MB vs "
        f"whole-layer={layer['kv']['spill_bytes'] / 1e6:.2f}MB "
        f"({layer['kv']['spill_bytes'] / max(1, paged['kv']['spill_bytes']):.1f}x less, "
        f"same budget, tokens identical)",
    )
    emit(
        "decode/peak-host",
        0.0,
        f"cached={cached['peak_host_bytes'] / 1e6:.1f}MB "
        f"spill={spilled['peak_host_bytes'] / 1e6:.1f}MB "
        f"uncached={uncached['peak_host_bytes'] / 1e6:.1f}MB",
    )
    emit(
        "decode/equivalence",
        0.0,
        f"logit_max_rel_diff={equiv['logit_max_rel_diff']:.2e} "
        f"argmax_agreement={equiv['argmax_agreement']:.3f} "
        f"flips_beyond_tol={equiv['argmax_flips_beyond_tol']} "
        f"(tol 8 row-max bf16 ULPs, teacher-forced)",
    )
    emit(
        "decode/per-token-cost",
        cached["step_s_late"] * 1e6,
        f"cached late/early={cached['step_s_late'] / cached['step_s_early']:.2f} "
        f"uncached late/early="
        f"{uncached['step_s_late'] / uncached['step_s_early']:.2f} "
        f"(O(1) vs O(T) per-token prefix cost)",
    )
