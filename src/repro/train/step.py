"""Jitted train / prefill steps with explicit shardings.

ZeRO-Infinity execution split (paper Fig. 1): the ACCELERATOR runs forward +
backward and the on-device overflow screen; the HOST runs the optimizer
(:mod:`repro.core.optimizer` / :mod:`repro.kernels.fused_adam`).  The jitted
``train_step`` therefore computes (loss, grads, overflow_flag) — exactly
what a ZeRO-Infinity-class system lowers to the device — with

* bf16 compute / fp32 loss & grads accumulation,
* loss scaling (scale is a traced scalar so the host scaler can adapt
  without recompilation),
* the fused single-pass overflow check over every gradient leaf (the
  on-device adaptation of the paper's Algorithm 1),
* per-block remat (gradient checkpointing) inside the model's layer scan.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.overflow import (baseline_overflow_check_jnp,
                                 fused_overflow_check_jnp)
from repro.launch import sharding as shd
from repro.models.registry import ModelImpl


def grads_overflow_flag(grads, *, kind: str = "fused") -> jnp.ndarray:
    """OR of the per-leaf Inf/NaN screen across all gradient leaves.

    ``kind`` mirrors the offloaded path's ``OverflowCheckOp`` dispatch:
    ``"fused"`` is the single-pass bitwise check (Algorithm 1), and
    ``"baseline"`` keeps the chained abs→isinf/isnan formulation as the
    on-device semantic reference for ablations.
    """
    check = {"fused": fused_overflow_check_jnp,
             "baseline": baseline_overflow_check_jnp}[kind]
    flags = [check(g) for g in jax.tree.leaves(grads)]
    out = flags[0]
    for f in flags[1:]:
        out = out | f
    return out


def make_act_hint(mesh):
    """Activation-sharding re-assertion (batch over ("pod","data")) —
    §Perf default: without it the partitioner reshards full-batch
    activations in backward."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import batch_axes
    dp = batch_axes(mesh)
    import math as _math
    dp_size = _math.prod(mesh.shape[a] for a in dp)
    sh3 = NamedSharding(mesh, P(dp, None, None))

    def hint(x):
        if getattr(x, "ndim", 0) == 3 and x.shape[0] % dp_size == 0:
            return jax.lax.with_sharding_constraint(x, sh3)
        return x

    return hint


def build_train_step(impl: ModelImpl, mesh, *, batch_shape=None,
                     check_overflow: bool | str = True,
                     donate: bool = True):
    """Returns (step_fn, in_shardings, out_shardings) ready to jit/lower.

    step_fn(params, batch, loss_scale) -> (loss, grads, overflow)

    ``check_overflow``: ``False`` skips the screen; ``True``/``"fused"``
    uses the single-pass bitwise check; ``"baseline"`` keeps the chained
    formulation (the ablation axis the offloaded executor exposes through
    ``policy.fused_overflow``).
    """
    cfg = impl.cfg
    overflow_kind = "fused" if check_overflow is True else check_overflow

    def step(params, batch, loss_scale):
        def scaled_loss(p):
            return (impl.loss_fn(p, batch).astype(jnp.float32)
                    * loss_scale), ()

        (sloss, _), grads = jax.value_and_grad(scaled_loss, has_aux=True)(
            params)
        overflow = grads_overflow_flag(grads, kind=overflow_kind) \
            if overflow_kind else jnp.zeros((), jnp.bool_)
        return sloss / loss_scale, grads, overflow

    params_shape = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(cfg, params_shape, mesh)
    if batch_shape is None:
        raise ValueError("batch_shape (ShapeDtypeStructs) required")
    bshard = shd.batch_shardings(cfg, batch_shape, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P())
    in_shardings = (pshard, bshard, scalar)
    out_shardings = (scalar, pshard, scalar)
    return step, in_shardings, out_shardings


def build_prefill_step(impl: ModelImpl, mesh, *, batch_shape=None):
    """Forward-only logits (inference prefill).  Returns (fn, in, out)."""
    cfg = impl.cfg

    def prefill(params, batch):
        return impl.prefill_fn(params, batch)

    params_shape = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(cfg, params_shape, mesh)
    bshard = shd.batch_shardings(cfg, batch_shape, mesh)
    from jax.sharding import NamedSharding
    gb = jax.tree.leaves(batch_shape)[0].shape[0]
    out_shard = NamedSharding(mesh, shd.logits_spec(cfg, mesh, gb))
    return prefill, (pshard, bshard), out_shard
