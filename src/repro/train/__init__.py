from .step import build_train_step, build_prefill_step

__all__ = ["build_train_step", "build_prefill_step"]
