"""Distributed training launcher (pjit path + SSD-offloaded path).

On real hardware this drives the (data, model) mesh via the jitted
train_step, with the MemAscend host machinery (offloaded optimizer,
direct-NVMe state store, fused overflow screen) wrapped around it.  In this
container it runs reduced configs on the 1x1 host mesh — the same code
path, one device.

``--offload POLICY`` instead runs the arch through the SSD-offloaded
OffloadSession (StreamPlan schedules, lookahead prefetch, host Adam on
NVMe-resident state), with the policy selected by registry name.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 20 \
      [--reduced] [--batch 4] [--seq 128] [--offload memascend]
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.loss_scale import DynamicLossScaler
from repro.core.offload_engine import OffloadPolicy
from repro.core.session import OffloadSession
from repro.data import DataLoader, SyntheticTextDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build
from repro.train.step import build_train_step


def run_offloaded(cfg, args) -> None:
    """The SSD-offloaded path: registry policy + OffloadSession."""
    from repro.core.model_adapter import make_offloadable_lm
    model = make_offloadable_lm(cfg, jax.random.PRNGKey(0))
    b, s = args.batch, args.seq
    dl = DataLoader(SyntheticTextDataset(vocab=cfg.vocab, seed=0),
                    batch=b, seq_len=s)
    with tempfile.TemporaryDirectory(prefix="launch_offload_") as root:
        policy = (OffloadPolicy.preset(args.offload)
                  .with_store(root).with_adam(lr=args.lr)
                  .with_overlap(args.overlap).build())
        with OffloadSession(model, policy) as sess:
            print(f"offload policy {policy.name}: "
                  f"{sess.total_params / 1e6:.1f}M params, "
                  f"lookahead {sess.lookahead}, overlap {policy.overlap}")
            t0 = time.time()
            for i in range(1, args.steps + 1):
                hb = dl.next_batch()
                m = sess.train_step(hb["tokens"], hb["labels"])
                if i % 5 == 0 or i == 1:
                    tput = i * b * s / (time.time() - t0)
                    print(f"step {i:4d} loss {m['loss']:.4f} "
                          f"fetch-wait {m['fetch_wait_s'] * 1e3:.0f}ms "
                          f"optim-gate {m['optim_gate_s'] * 1e3:.0f}ms "
                          f"optim-prefetch-wait "
                          f"{m['optim_prefetch_wait_s'] * 1e3:.0f}ms "
                          f"overflow-screen "
                          f"{m['overflow_screen_s'] * 1e3:.1f}ms "
                          f"{tput:.0f} tok/s")
            sess.synchronize()   # close the timing window on the last Adam
    print("offloaded train loop done")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--offload", default=None,
                    choices=OffloadPolicy.names(),
                    help="run SSD-offloaded via this registry policy "
                         "instead of the pjit path")
    ap.add_argument("--overlap", default="full",
                    choices=["sync", "h2d", "full"],
                    help="offload pipeline overlap level (the Fig. 6 "
                         "ablation): sync H2D/gradwrite/optimizer, "
                         "async H2D only, or the full pipeline")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    if args.offload:
        run_offloaded(cfg, args)
        return
    impl = build(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    b, s = args.batch, args.seq
    batch_sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    extra = {}
    if cfg.family == "audio":
        batch_sds["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        extra["frames"] = jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.prefix_len:
        batch_sds["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
        extra["image_embeds"] = jnp.ones((b, cfg.prefix_len, cfg.d_model),
                                         jnp.bfloat16)

    with mesh:
        fn, in_sh, out_sh = build_train_step(impl, mesh,
                                             batch_shape=batch_sds)
        step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        params = impl.init_params(jax.random.PRNGKey(0))
        scaler = DynamicLossScaler(scale=1.0)   # bf16 compute
        # simple on-device SGD-on-grads demo loop (the offloaded-Adam path
        # lives in examples/finetune_offloaded.py)
        dl = DataLoader(SyntheticTextDataset(vocab=cfg.vocab, seed=0),
                        batch=b, seq_len=s)
        lr = args.lr
        t0 = time.time()
        for i in range(1, args.steps + 1):
            hb = dl.next_batch()
            batch = {"tokens": jnp.asarray(hb["tokens"]),
                     "labels": jnp.asarray(hb["labels"]), **extra}
            loss, grads, overflow = step(params, batch,
                                         jnp.float32(scaler.scale))
            if scaler.update(bool(overflow)):
                inv = 1.0 / scaler.scale
                params = jax.tree.map(
                    lambda p, g: (p - lr * inv * g.astype(p.dtype)).astype(
                        p.dtype), params, grads)
            if i % 5 == 0 or i == 1:
                tput = i * b * s / (time.time() - t0)
                print(f"step {i:4d} loss {float(loss):.4f} "
                      f"overflow={bool(overflow)} {tput:.0f} tok/s")
    print("train loop done")


if __name__ == "__main__":
    main()
