"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI link bandwidth  ~50 GB/s/link

Three terms per (arch x shape x mesh), in seconds per step:

    compute    = HLO_FLOPs_per_chip / 197e12
    memory     = HLO_bytes_per_chip / 819e9
    collective = link_bytes_per_chip / 50e9

Sources: the dry-run's calibrated ``cost_analysis`` (flops, bytes accessed;
while-loop depth corrected by the G1/G2 calibration — see dryrun.py) and
the HLO collective parse.  Collective *link* bytes per chip are derived
from result-shape bytes with the standard ring factors:

    all-gather          result x (n-1)/n      ~= result
    all-reduce          2 x result            (reduce-scatter + all-gather)
    reduce-scatter      result x (n-1)       ~= input
    all-to-all          result x (n-1)/n      ~= result
    collective-permute  result

``n`` (the participant count) is not in the HLO text dump, so the ~=
column is used (exact for large n; documented in EXPERIMENTS.md).  For
reduce-scatter we conservatively use result x 1 — XLA's RS results here are
full-shard outputs of grad reductions whose inputs were already counted by
the paired all-gather.

MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill), 2*N*B (decode), with
N = active params; the ratio MODEL_FLOPS / (HLO_FLOPs x chips) flags remat
and redundant-compute waste (ratio < 1 expected under remat: the extra
forward puts HLO at ~8/6 of model flops before attention terms).

Known under-count (documented): inner sequence scans (mamba chunk scan,
sLSTM per-step scan) remain rolled in the calibration models; the missed
terms are O(d_state/d_model) and O(1/slstm_every) relative — bounded in the
per-arch notes emitted below.
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import dataclass

from repro.configs import ARCHS, INPUT_SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

GiB = 1 << 30


def link_bytes(coll: dict) -> float:
    b = coll["bytes"]
    return (b.get("all-gather", 0)
            + 2 * b.get("all-reduce", 0)
            + b.get("reduce-scatter", 0)
            + b.get("all-to-all", 0)
            + b.get("collective-permute", 0))


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per request


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    fits: bool
    temp_gib_per_chip: float
    note: str

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.compute_s:.3e} | "
                f"{self.memory_s:.3e} | {self.collective_s:.3e} | "
                f"**{self.dominant}** | {self.useful_ratio:.2f} | "
                f"{self.temp_gib_per_chip:.1f} | {self.note} |")


def _recommendation(r: "Roofline") -> str:
    if r.dominant == "collective":
        return ("collective-bound: cut all-gather/all-reduce volume "
                "(reshard weights so the gather matches use, overlap with "
                "compute)")
    if r.dominant == "memory":
        return ("HBM-bound: shrink activation traffic (fusion, smaller "
                "remat working set, bf16 intermediates)")
    return ("compute-bound: already at the useful-work ceiling; gains come "
            "from cutting remat recompute or idle MXU (larger per-chip "
            "batch)")


def analyze(record: dict) -> Roofline | None:
    if record.get("status") != "ok":
        return None
    chips = record["n_chips"]
    flops = record["cost"].get("flops", 0.0)
    bytes_acc = record["cost"].get("bytes accessed", 0.0)
    lb = link_bytes(record["collectives"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = lb / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record["shape"])
    hlo_global = flops * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    temp = record["memory"].get("temp_size_in_bytes", 0) / GiB
    r = Roofline(record["arch"], record["shape"], record["mesh"],
                 compute_s, memory_s, collective_s, dominant, mf,
                 hlo_global, ratio, temp < 16.0, temp, "")
    r.note = _recommendation(r)
    return r


def load_records(out_dir: str, mesh: str) -> list[dict]:
    recs = []
    mdir = os.path.join(out_dir, mesh)
    for f in sorted(os.listdir(mdir)):
        if f.endswith(".json"):
            with open(os.path.join(mdir, f)) as fh:
                recs.append(json.load(fh))
    return recs


def report(out_dir: str, mesh: str) -> str:
    lines = [
        f"### Roofline — {mesh} mesh",
        "",
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | useful ratio | temp GiB/chip | what would move it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    skipped = []
    for rec in load_records(out_dir, mesh):
        r = analyze(rec)
        if r is None:
            skipped.append(f"{rec['arch']}/{rec['shape']}: "
                           f"{rec.get('reason', rec.get('error', '?'))[:90]}")
            continue
        lines.append(r.row())
    if skipped:
        lines += ["", "Skipped:"] + [f"- {s}" for s in skipped]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    print(report(os.path.abspath(args.out), args.mesh))


if __name__ == "__main__":
    main()
