import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) pair this lowers + compiles the
jitted step against the production mesh — 16x16 = 256 chips single-pod and
2x16x16 = 512 chips multi-pod — using ShapeDtypeStruct stand-ins (no
allocation).  ``compiled.memory_analysis()`` proves the layout fits;
``cost_analysis()`` + an HLO collective-bytes parse feed §Roofline.

The 512 placeholder host devices are forced by the XLA_FLAGS line ABOVE ANY
OTHER IMPORT — jax locks the device count on first init.  Never set that
flag globally: smoke tests and benchmarks must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
Results are cached as JSON under experiments/dryrun/<mesh>/ (resumable).
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.models import build, shape_supported, variant_for_shape
from repro.launch.mesh import make_production_mesh
from repro.serve.decode import build_serve_step
from repro.train.step import build_prefill_step, build_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_ARRAY_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _array_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO, by kind.

    These are GLOBAL logical bytes (the result array of the collective);
    per-chip link traffic is derived in roofline.py.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|\S+)\s+([a-z0-9-]+)", rhs)
        if not m:
            continue
        op = m.group(2)
        # normalize fusion/start/done variants: all-gather-start etc.
        base = None
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                base = kind
                break
        if base is None or op.endswith("-done"):
            continue
        out[base] += _array_bytes(m.group(1))
        counts[base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def make_act_hint(mesh):
    """Activation-sharding re-assertion: batch over ("pod","data")."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import batch_axes
    dp = batch_axes(mesh)
    sh3 = NamedSharding(mesh, P(dp, None, None))

    def hint(x):
        if getattr(x, "ndim", 0) == 3 and x.shape[0] % 16 == 0:
            return jax.lax.with_sharding_constraint(x, sh3)
        return x

    return hint


def _lower_one(cfg, shape, mesh, *, check_overflow=True, remat=True,
               unroll=False, serve_param_mode="zero3", act_hint=False,
               bf16_logits=False, device_params_bf16=False):
    """Lower + compile one config; returns (compiled, t_lower, t_compile)."""
    impl = build(cfg, remat=remat, unroll=unroll,
                 hint=make_act_hint(mesh) if act_hint else None,
                 bf16_logits=bf16_logits)
    params_sds = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    if device_params_bf16:
        # ZeRO-Infinity device weights are half precision (the fp32 master
        # lives on the host/SSD); lower the device program accordingly.
        params_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32 else a, params_sds)

    t0 = time.time()
    if shape.kind == "train":
        batch_sds = impl.input_specs(shape)
        fn, in_sh, out_sh = build_train_step(
            impl, mesh, batch_shape=batch_sds, check_overflow=check_overflow)
        scale_sds = jax.ShapeDtypeStruct((), jnp.float32)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(
            params_sds, batch_sds, scale_sds)
    elif shape.kind == "prefill":
        batch_sds = impl.input_specs(shape)
        fn, in_sh, out_sh = build_prefill_step(impl, mesh,
                                               batch_shape=batch_sds)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(
            params_sds, batch_sds)
    else:  # decode
        fn, in_sh, out_sh, (cache_sds, tok_sds, len_sds) = build_serve_step(
            impl, mesh, shape, param_mode=serve_param_mode)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(1,)).lower(
            params_sds, cache_sds, tok_sds, len_sds)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0


def _cost_record(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    return {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float))
            and k in ("flops", "bytes accessed", "transcendentals")}


def _depth_variant(cfg, groups: int):
    """Config with n_layers = groups * period (and scaled whisper encoder)."""
    from dataclasses import replace
    from repro.models.transformer import layer_period
    if cfg.family == "audio":
        return replace(cfg, n_layers=groups, encoder_layers=groups)
    p = layer_period(cfg)
    return replace(cfg, n_layers=groups * p)


def lower_pair(arch: str, shape_name: str, mesh, *, check_overflow=True,
               remat=True, calibrate=True, serve_param_mode="zero3",
               act_hint=False, bf16_logits=False, device_params_bf16=False):
    """Lower + compile one (arch, shape, mesh); returns the record dict.

    Two-part measurement (see EXPERIMENTS.md §Dry-run methodology):

    1. The FULL, DEPLOYABLE program — scan-over-layers + remat — is
       compiled; its ``memory_analysis`` is the fits-proof and its HLO the
       collective-schedule artifact.  XLA's cost analysis counts while-loop
       bodies ONCE, so its flops/bytes/collectives under-count depth.
    2. CALIBRATION: two shallow variants (1 and 2 layer-groups, layer scan
       unrolled) are compiled with identical shapes/sharding.  The cost
       delta is the exact per-group cost; total = C1 + (G-1)*(C2-C1).
       Inner sequence scans (mamba chunks, sLSTM steps) remain rolled in
       both — their unrolled-vs-rolled delta is an O(d_state/d_model)
       relative error, bounded analytically in §Roofline.
    """
    shape = INPUT_SHAPES[shape_name]
    base_cfg = get_config(arch)
    ok, reason = shape_supported(base_cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}
    cfg = variant_for_shape(base_cfg, shape)

    perf_kw = dict(serve_param_mode=serve_param_mode, act_hint=act_hint,
                   bf16_logits=bf16_logits,
                   device_params_bf16=device_params_bf16)
    compiled, t_lower, t_compile = _lower_one(
        cfg, shape, mesh, check_overflow=check_overflow, remat=remat,
        **perf_kw)
    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            mem_rec[field] = int(getattr(mem, field, 0) or 0)
    raw_cost = _cost_record(compiled)
    raw_coll = collective_bytes(compiled.as_text())

    from repro.models.transformer import layer_period
    n_groups = cfg.n_layers if cfg.family == "audio" \
        else cfg.n_layers // layer_period(cfg)

    extrap = None
    if calibrate and n_groups >= 2:
        c1, *_ = _lower_one(_depth_variant(cfg, 1), shape, mesh,
                            check_overflow=check_overflow, remat=remat,
                            unroll=True, **perf_kw)
        c2, *_ = _lower_one(_depth_variant(cfg, 2), shape, mesh,
                            check_overflow=check_overflow, remat=remat,
                            unroll=True, **perf_kw)
        cost1, cost2 = _cost_record(c1), _cost_record(c2)
        coll1 = collective_bytes(c1.as_text())
        coll2 = collective_bytes(c2.as_text())
        extrap = {"cost": {}, "collectives": {"bytes": {}, "counts": {}}}
        for k in set(cost1) | set(cost2):
            a, b = cost1.get(k, 0.0), cost2.get(k, 0.0)
            # clamped: per-group cost can't be negative, and the calibrated
            # total can't be below the (counted-once) rolled measurement
            est = a + (n_groups - 1) * max(b - a, 0.0)
            extrap["cost"][k] = max(est, raw_cost.get(k, 0.0))
        for k in _COLLECTIVES:
            a, b = coll1["bytes"][k], coll2["bytes"][k]
            est = a + (n_groups - 1) * max(b - a, 0)
            extrap["collectives"]["bytes"][k] = max(
                est, raw_coll["bytes"][k])
            ca, cb = coll1["counts"][k], coll2["counts"][k]
            extrap["collectives"]["counts"][k] = ca + \
                (n_groups - 1) * max(cb - ca, 0)
        extrap["collectives"]["total_bytes"] = sum(
            extrap["collectives"]["bytes"].values())
        extrap["n_groups"] = n_groups
        extrap["calib_g1_cost"] = cost1
        extrap["calib_g2_cost"] = cost2

    n_chips = mesh.devices.size
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": n_chips,
        "sliding_window": cfg.sliding_window,
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory": mem_rec,
        "cost_raw": raw_cost,
        "collectives_raw": raw_coll,
        "cost": (extrap or {}).get("cost", raw_cost),
        "collectives": (extrap or {}).get("collectives", raw_coll),
        "calibrated": extrap is not None,
    }


def run_all(meshes: list[str], archs, shapes, out_dir: str,
            *, force: bool = False):
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        mdir = os.path.join(out_dir, mesh_name)
        os.makedirs(mdir, exist_ok=True)
        with mesh:
            for arch in archs:
                for shape_name in shapes:
                    path = os.path.join(
                        mdir, f"{arch}__{shape_name}.json".replace("/", "_"))
                    if os.path.exists(path) and not force:
                        print(f"[cached] {mesh_name} {arch} {shape_name}")
                        continue
                    print(f"[dryrun] {mesh_name} {arch} {shape_name} ...",
                          flush=True)
                    try:
                        rec = lower_pair(arch, shape_name, mesh)
                    except Exception as e:  # a failure here is a real bug
                        rec = {"arch": arch, "shape": shape_name,
                               "status": "error", "error": repr(e),
                               "traceback": traceback.format_exc()}
                        print(f"  ERROR: {e}")
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                    if rec["status"] == "ok":
                        print(f"  ok: compile={rec['compile_seconds']}s "
                              f"flops={rec['cost'].get('flops', 0):.3e} "
                              f"coll={rec['collectives']['total_bytes']:.3e}B")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape is None else [args.shape]
    run_all(meshes, archs, shapes, args.out, force=args.force)


if __name__ == "__main__":
    main()
