"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

ZeRO-3-flavored layout (DESIGN §5): every weight is sharded over BOTH the
``data`` axis (stage-3 parameter partitioning — XLA inserts the per-layer
all-gather that ZeRO-Infinity performs explicitly, paper Fig. 1) and the
``model`` axis (tensor parallelism: column/row splits, vocab-sharded
embeddings, expert parallelism for MoE stacks).

All assignments are divisibility-gated: a dim is only sharded by an axis
(set) whose total size divides it — whisper's 6 heads or MQA's single KV
head simply stay replicated on that dim rather than failing to lower.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from .mesh import batch_axes


# ---------------------------------------------------------------------------
# generic machinery
# ---------------------------------------------------------------------------

def _axes_size(mesh, cand) -> int:
    names = cand if isinstance(cand, tuple) else (cand,)
    return math.prod(mesh.shape[n] for n in names)


def greedy_spec(mesh, shape, dim_prefs) -> P:
    """Assign each dim the first candidate axis(es) that divide it, without
    reusing any mesh axis across dims."""
    used: set[str] = set()
    parts = []
    for dim, prefs in zip(shape, dim_prefs, strict=False):
        chosen = None
        for cand in prefs or ():
            names = cand if isinstance(cand, tuple) else (cand,)
            if any(n not in mesh.axis_names or n in used for n in names):
                continue
            if dim % _axes_size(mesh, cand) == 0:
                chosen = cand
                used.update(names)
                break
        parts.append(chosen)
    return P(*parts)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

_COL_SUFFIXES = (  # (in, out) weights split column-wise: out -> model
    "attn.w_q", "attn.w_k", "attn.w_v", "attn.w_dq", "attn.w_uq",
    "attn.w_dkv", "attn.w_ukv", "xattn.w_q", "xattn.w_k", "xattn.w_v",
    "ffn.w_up", "ffn.w_gate", "ssm.w_in_x", "ssm.w_in_z", "ssm.w_dt_in",
    "ssm.w_b", "ssm.w_c", "ssm.w_dt",
    "mlstm.w_q", "mlstm.w_k", "mlstm.w_v", "mlstm.w_gates", "slstm.w_x",
    "moe.w_router", "moe.shared_up", "moe.shared_gate", "mtp_proj",
)
_ROW_SUFFIXES = (  # (in, out) weights split row-wise: in -> model
    "attn.w_o", "xattn.w_o", "ffn.w_down", "ssm.w_out", "mlstm.w_o",
    "slstm.w_o", "moe.shared_down",
)


def _param_dim_prefs(key: str, ndim: int, stacked: bool):
    """Dim preferences for one parameter leaf (before group-stack prefix).

    Each dim gets an ordered candidate list of axis names / axis tuples.
    """
    if key == "embed":
        prefs = [["model"], ["data"]]          # (vocab, d)
    elif key == "head":
        prefs = [["data"], ["model"]]          # (d, vocab)
    elif key in ("moe.w_up", "moe.w_gate"):
        prefs = [["model"], ["data"], []]      # (E, d, F): expert parallel
    elif key == "moe.w_down":
        prefs = [["model"], [], ["data"]]      # (E, F, d)
    elif key == "ssm.conv_w":
        prefs = [[], ["model"]]                # (K, di)
    elif key == "ssm.a_log":
        prefs = [["model"], []]                # (di, ds)
    elif key == "slstm.r":
        prefs = [["model"], [], []]            # (H, hd, 4hd)
    elif key in _COL_SUFFIXES:
        prefs = [["data"], ["model"]]
    elif key in _ROW_SUFFIXES:
        prefs = [["model"], ["data"]]
    elif ndim == 1:
        prefs = [[]]                           # norms, biases: replicated
    elif ndim == 2:
        prefs = [["data"], ["model"]]          # default column split
    else:
        prefs = [[] for _ in range(ndim)]
    if stacked:
        prefs = [[]] + prefs                   # leading group axis: replicated
    return prefs


def _leaf_key(path) -> str:
    """Last string key on a tree path ('attn.w_q', 'embed', ...)."""
    for entry in reversed(path):
        if hasattr(entry, "key") and isinstance(entry.key, str):
            return entry.key
    return ""


def _is_stacked(path) -> bool:
    for entry in path:
        if hasattr(entry, "key") and entry.key == "groups":
            return True
        if hasattr(entry, "key") and entry.key in ("enc_layers", "dec_layers"):
            return True
    return False


def param_specs(cfg: ModelConfig, params_shape, mesh, *,
                mode: str = "zero3"):
    """PartitionSpec tree for a params tree (or its eval_shape).

    mode="zero3" (training default): weights sharded over BOTH data (ZeRO-3
    stage-3 partitioning) and model (tensor parallel) — XLA all-gathers per
    layer, exactly ZeRO-Infinity's schedule.

    mode="tp" (serving, beyond-paper — EXPERIMENTS.md §Perf): weights
    sharded over the model axis only and REPLICATED across data.  Decode
    executes the same weight matmul every step; gathering a ZeRO-3 shard per
    token makes every decode step collective-bound.  TP-only costs
    (data_parallel-1)x more HBM for weights but removes the per-token
    parameter all-gather entirely — the standard inference-engine layout.
    """
    if mode not in ("zero3", "tp"):
        raise ValueError(f"unknown param mode {mode!r}")

    def spec_for(path, leaf):
        key = _leaf_key(path)
        stacked = _is_stacked(path)
        ndim = len(leaf.shape) - (1 if stacked else 0)
        prefs = _param_dim_prefs(key, ndim, stacked)
        if mode == "tp":
            prefs = [[c for c in dim_prefs
                      if "data" not in (c if isinstance(c, tuple) else (c,))
                      and "pod" not in (c if isinstance(c, tuple) else (c,))]
                     for dim_prefs in prefs]
        return greedy_spec(mesh, leaf.shape, prefs)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def param_shardings(cfg, params_shape, mesh, *, mode: str = "zero3"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params_shape, mesh, mode=mode))


# ---------------------------------------------------------------------------
# batches (train / prefill)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_shape, mesh):
    """Shard the global batch over ("pod","data"); seq stays unsharded for
    training (attention needs full-sequence locality per shard)."""
    dp = batch_axes(mesh)

    def spec_for(path, leaf):
        prefs = [[dp]] + [[] for _ in leaf.shape[1:]]
        return greedy_spec(mesh, leaf.shape, prefs)

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def batch_shardings(cfg, batch_shape, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_specs(cfg, batch_shape, mesh))


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, cache_shape, mesh):
    """Decode-state sharding.

    KV-ish caches (ndim>=3 with a seq dim): batch -> ("pod","data"), seq ->
    "model" (scores contract over seq; XLA emits the partial-sum
    all-reduce).  Recurrent states: batch -> dp, then the largest inner dim
    -> "model".  When batch=1 (long_500k) the batch dim is unshardable and
    inner dims pick up ("data","model") combos instead.
    """
    dp = batch_axes(mesh)

    def spec_for(path, leaf):
        shape = leaf.shape
        stacked = _is_stacked_cache(path, shape)
        dims = shape[1:] if stacked else shape
        key = _leaf_key(path)
        if key in ("k", "v", "xk", "xv", "ckv"):
            # (B, S, heads..., D): batch over dp, seq over model.  The
            # decode step consumes the PRE-UPDATE cache and merges the new
            # token analytically (attention.gqa_decode) so the seq-sharded
            # layout never forces a cache all-gather on the read path
            # (§Perf decode iterations 1-3).
            prefs = [[dp, ("data",)], [("model",), ("data", "model")]] + \
                [[] for _ in dims[2:]]
        elif key == "conv":
            prefs = [[dp, ("data",)], [], [("model",), ("data", "model")]]
        elif key == "ssm":
            prefs = [[dp, ("data",)], [("model",), ("data", "model")], []]
        elif key in ("c",):      # mlstm matrix state (B, H, dk, dv)
            prefs = [[dp, ("data",)], [("model",)],
                     [("data", "model"), ("model",)], []]
        elif key in ("n", "h"):
            prefs = [[dp, ("data",)], [("model",)],
                     [("data", "model"), ("model",)]]
        else:
            prefs = [[dp]] + [[] for _ in dims[1:]]
        prefs = prefs[:len(dims)] + [[] for _ in range(len(dims) - len(prefs))]
        if stacked:
            prefs = [[]] + prefs
        return greedy_spec(mesh, shape, prefs)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def _is_stacked_cache(path, shape) -> bool:
    """Transformer caches are tuples-of-group-stacked; whisper's are
    layer-stacked dicts.  Heuristic: tuple index present in path (the
    per-position tuple) => stacked leading group dim."""
    for entry in path:
        if type(entry).__name__ == "SequenceKey":
            return True
        if hasattr(entry, "key") and entry.key in ("k", "v", "xk", "xv") \
                and len(shape) == 5:
            return True
    return False


def cache_shardings(cfg, cache_shape, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cfg, cache_shape, mesh))


def logits_spec(cfg: ModelConfig, mesh, global_batch: int) -> P:
    dp = batch_axes(mesh)
    dp_size = math.prod(mesh.shape[a] for a in dp)
    batch_part = dp if global_batch % dp_size == 0 else None
    vocab_ok = cfg.vocab % mesh.shape["model"] == 0
    return P(batch_part, None, "model" if vocab_ok else None)
