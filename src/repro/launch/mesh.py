"""Production mesh definition (functions only — importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first jax init).

Target hardware: TPU v5e pods.
  single pod : 16 x 16 = 256 chips, axes ("data", "model")
  multi-pod  : 2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")

"pod" composes with "data" for gradient reduction (batch axes are
("pod", "data")); "model" carries tensor/expert parallelism.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch (data parallel + pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, *names: str) -> int:
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out
