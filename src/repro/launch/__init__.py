"""Launchers: mesh construction, multi-pod dry-run, roofline, training CLI.

NOTE: do not import ``dryrun`` from here — it sets XLA_FLAGS at import time
and must only be imported as the entry module of a dedicated process.
"""

from .mesh import make_production_mesh, make_host_mesh, batch_axes

__all__ = ["make_production_mesh", "make_host_mesh", "batch_axes"]
