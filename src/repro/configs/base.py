"""Config schema: architectures, input shapes, and the pool census.

Every assigned architecture is a :class:`ModelConfig`; the four assigned
input shapes are :data:`INPUT_SHAPES`.  The config also derives the two
quantities MemAscend's host-side machinery needs:

* :meth:`ModelConfig.pool_census` — the shape-class census (embedding, FFN,
  QO/KV projections, experts, SSM params, ...) that sizes both the fixed
  (baseline) and adaptive (MemAscend) parameter buffer pools, and
* :meth:`ModelConfig.param_count` — for flat-buffer / optimizer-state /
  I/O-volume accounting at paper scale.

``reduced()`` returns the CPU-smoke variant (≤2 layers, d_model ≤ 512,
≤4 experts) of the same family, exercised by per-arch smoke tests; the full
configs are touched only by the ShapeDtypeStruct dry-run.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims [arXiv:2412.19437]."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba / xLSTM block parameters."""

    kind: str = "mamba"          # "mamba" | "xlstm"
    d_state: int = 16
    expand: int = 2              # d_inner = expand * d_model
    conv_kernel: int = 4
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    # xLSTM only:
    slstm_every: int = 8         # one sLSTM block per this many (rest mLSTM)
    chunk: int = 128             # chunked-parallel scan chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def dt_rank_for(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention/MLP flavor
    qk_norm: bool = False
    gated_act: str = "swiglu"    # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    sliding_window: int = 0      # 0 = full attention; >0 enables SW variant
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale
    # MoE / MLA / SSM / hybrid
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    attn_period: int = 1         # hybrid: 1 attention layer per this many
                                 # (jamba: 8 -> layers i%8==0 are attention)
    moe_period: int = 1          # MoE FFN every this many layers (jamba: 2);
                                 # other layers get a dense FFN of d_ff
    mtp: bool = False            # DeepSeek multi-token prediction head
    # enc-dec (audio) / prefix (vlm) frontends — STUBBED per assignment
    encoder_layers: int = 0      # whisper: encoder depth
    encoder_seq: int = 0         # frames from the (stubbed) conv frontend
    prefix_len: int = 0          # vlm: image tokens from the (stubbed) ViT
    max_decode_len: int = 0      # architectural decode cap (whisper: 448)
    source: str = ""             # citation for the config

    # -- derived -----------------------------------------------------------------

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_attention_layer(self, i: int) -> bool:
        """Hybrid interleave: which layers are attention (vs SSM)."""
        if self.family != "hybrid":
            return True
        return i % self.attn_period == self.attn_period - 1

    @property
    def n_attn_layers(self) -> int:
        return sum(self.is_attention_layer(i) for i in range(self.n_layers))

    @property
    def n_ssm_layers(self) -> int:
        return self.n_layers - self.n_attn_layers if self.family == "hybrid" \
            else (self.n_layers if self.family == "ssm" else 0)

    # -- parameter census ---------------------------------------------------------

    def block_param_shapes(self, layer: int = 0) -> dict[str, tuple]:
        """Streamed-tensor shapes of one block, tagged by pool shape class.

        Returns {param_name: shape}; :meth:`class_of_param` maps names to
        shape classes.  Small per-channel vectors (norms, biases) stay
        resident in host memory (paper: tensors under ~2M elements are not
        offloaded) and are excluded.
        """
        d, shapes = self.d_model, {}
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            if s.kind == "xlstm":
                hd = d // self.n_heads
                if layer % s.slstm_every == s.slstm_every - 1:
                    shapes.update({
                        "slstm.w_x": (d, 4 * d),
                        "slstm.r": (self.n_heads, hd, 4 * hd),
                        "slstm.w_o": (d, d),
                    })
                else:
                    shapes.update({
                        "mlstm.w_q": (d, di),
                        "mlstm.w_k": (d, di),
                        "mlstm.w_v": (d, di),
                        "mlstm.w_gates": (d, 2 * self.n_heads),
                        "mlstm.w_o": (di, d),
                    })
                if self.d_ff:
                    shapes["ffn.w_gate"] = (d, self.d_ff)
                    shapes["ffn.w_up"] = (d, self.d_ff)
                    shapes["ffn.w_down"] = (self.d_ff, d)
                return shapes
            shapes.update(self._mamba_shapes())
            return shapes
        if self.family == "hybrid" and not self.is_attention_layer(layer):
            shapes.update(self._mamba_shapes())
        else:
            if self.mla is not None:
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                shapes.update({
                    "attn.w_dq": (d, m.q_lora_rank),
                    "attn.w_uq": (m.q_lora_rank, self.n_heads * qk_head),
                    "attn.w_dkv": (d, m.kv_lora_rank + m.qk_rope_head_dim),
                    "attn.w_ukv": (m.kv_lora_rank,
                                   self.n_heads * (m.qk_nope_head_dim
                                                   + m.v_head_dim)),
                    "attn.w_o": (self.n_heads * m.v_head_dim, d),
                })
            else:
                shapes.update({
                    "attn.w_q": (d, self.q_dim),
                    "attn.w_k": (d, self.kv_dim),
                    "attn.w_v": (d, self.kv_dim),
                    "attn.w_o": (self.q_dim, d),
                })
        if self.moe is not None and layer % self.moe_period == self.moe_period - 1:
            e = self.moe
            shapes["moe.w_router"] = (d, e.n_experts)
            for i in range(e.n_experts):
                shapes[f"moe.expert{i}.w_gate"] = (d, e.d_ff_expert)
                shapes[f"moe.expert{i}.w_up"] = (d, e.d_ff_expert)
                shapes[f"moe.expert{i}.w_down"] = (e.d_ff_expert, d)
            for i in range(e.n_shared):
                shapes[f"moe.shared{i}.w_gate"] = (d, e.d_ff_expert)
                shapes[f"moe.shared{i}.w_up"] = (d, e.d_ff_expert)
                shapes[f"moe.shared{i}.w_down"] = (e.d_ff_expert, d)
        elif self.d_ff:
            if self.gated_act in ("swiglu", "geglu"):
                shapes["ffn.w_gate"] = (d, self.d_ff)
            shapes["ffn.w_up"] = (d, self.d_ff)
            shapes["ffn.w_down"] = (self.d_ff, d)
        return shapes

    def _mamba_shapes(self) -> dict[str, tuple]:
        s = self.ssm or SSMConfig()
        d = self.d_model
        di = s.d_inner(d)
        dtr = s.dt_rank_for(d)
        return {
            "ssm.w_in_x": (d, di),
            "ssm.w_in_z": (d, di),
            "ssm.w_dt_in": (di, dtr),
            "ssm.w_dt": (dtr, di),
            "ssm.w_out": (di, d),
        }

    @staticmethod
    def class_of_param(name: str) -> str:
        """Pool shape class of a streamed tensor (paper §IV-B grouping)."""
        short = name.rsplit("/", 1)[-1]
        if short.startswith(("embed", "head", "lm_head")):
            return "embed"
        if ".expert" in short or ".shared" in short:
            return "expert"
        if short.startswith("ffn.") or short.startswith("moe.w_router"):
            return "ffn" if short.startswith("ffn.") else "router"
        if short.startswith("ssm.") or short.startswith("mlstm.") \
                or short.startswith("slstm."):
            return "ssm"
        if short.startswith("attn."):
            # paper: K/V identical under GQA get one subpool; Q/O another
            if short in ("attn.w_k", "attn.w_v"):
                return "kv_proj"
            return "qo_proj"
        return "other"

    def pool_census(self, *, inflight_blocks: int = 2, shards: int = 1):
        """Shape-class census across all layers (for the pool benchmarks)."""
        from repro.core.buffer_pool import PoolCensus, ShapeClass
        bytes_per = 2  # streamed in 16-bit compute precision
        nbytes: dict[str, int] = {}
        per_block: dict[str, int] = {}
        period = max(self.attn_period, self.moe_period)
        if self.ssm is not None and self.ssm.kind == "xlstm":
            period = max(period, self.ssm.slstm_every)
        for layer in set(range(min(self.n_layers, period))):
            counts: dict[str, int] = {}
            for pname, shape in self.block_param_shapes(layer).items():
                cls = self.class_of_param(pname)
                counts[cls] = counts.get(cls, 0) + 1
                nbytes[cls] = max(nbytes.get(cls, 0),
                                  math.prod(shape) * bytes_per)
            for cls, c in counts.items():
                per_block[cls] = max(per_block.get(cls, 0), c)
        embed_bytes = self.vocab * self.d_model * bytes_per
        nbytes["embed"] = max(nbytes.get("embed", 0), embed_bytes)
        standalone = {"embed": 1 if self.tie_embeddings else 2}  # embed + head
        classes = [ShapeClass(c, -(-nbytes[c] // shards),
                              per_block.get(c, 0), standalone.get(c, 0))
                   for c in sorted(nbytes)]
        return PoolCensus(tuple(classes), inflight_blocks)

    def param_count(self, *, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embeddings included."""
        total = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        for layer in range(self.n_layers):
            for pname, shape in self.block_param_shapes(layer).items():
                if active_only and ".expert" in pname and self.moe:
                    continue
                total += math.prod(shape)
            total += 2 * self.d_model  # norms
        if active_only and self.moe:
            e = self.moe
            per_expert = (self.d_model * 2 * e.d_ff_expert
                          + e.d_ff_expert * self.d_model)
            moe_layers = self.n_layers // self.moe_period
            total += moe_layers * e.top_k * per_expert
        if self.encoder_layers:
            enc_block = (4 * self.d_model * self.q_dim
                         + 2 * self.d_model * self.d_ff)
            total += self.encoder_layers * enc_block
        return total

    # -- reduced smoke variant ------------------------------------------------------

    def reduced(self) -> "ModelConfig":
        """≤2-layer, d_model ≤ 256 variant of the same family for CPU smoke."""
        d = 128
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, max(1, heads // 2)) if self.n_kv_heads > 1 else 1
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2 if self.family != "hybrid" else self.attn_period,
            d_model=d, n_heads=heads, n_kv_heads=kv,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=d // heads if self.mla is None else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window
            else 0,
        )
        if self.family == "hybrid":
            kw["n_layers"] = self.attn_period  # one full interleave group
        if self.moe:
            kw["moe"] = replace(self.moe, n_experts=4, top_k=2,
                                d_ff_expert=128)
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
            kw["head_dim"] = 0
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=8, chunk=32)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_seq"] = 64
            kw["max_decode_len"] = self.max_decode_len
        if self.prefix_len:
            kw["prefix_len"] = 16
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
