"""whisper-tiny [audio] — enc-dec; conv/mel frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                  # decoder depth
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51_865,
    head_dim=64,
    gated_act="gelu",
    encoder_layers=4,
    encoder_seq=1500,            # 30 s of audio after the (stubbed) conv stack
    max_decode_len=448,          # architectural decode cap
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
