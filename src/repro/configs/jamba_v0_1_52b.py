"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887]."""

from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65_536,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    moe_period=2,               # MoE every other layer (16 MoE layers)
    ssm=SSMConfig(kind="mamba", d_state=16, expand=2, conv_kernel=4),
    attn_period=8,              # 1 attention layer per 8 (1:7 attn:mamba)
    source="arXiv:2403.19887",
)
