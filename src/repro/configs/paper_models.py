"""Shape tables for the models the PAPER evaluates, so our fragmentation /
peak-memory benchmarks can be compared against the paper's own numbers
(Figs. 8, 11, 15–18; Tables II, IV, VI).

These are census-only configs: they drive the pool/allocator/I-O accounting
benchmarks, not the JAX model zoo.
"""

from .base import ModelConfig, MoEConfig

PAPER_MODELS: dict[str, ModelConfig] = {
    "llama3.1-8b": ModelConfig(
        name="llama3.1-8b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128_256, head_dim=128,
        source="arXiv:2407.21783"),
    "qwen2.5-7b": ModelConfig(
        name="qwen2.5-7b", family="dense", n_layers=28, d_model=3584,
        n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152_064, head_dim=128,
        source="arXiv:2412.15115"),
    "qwen2.5-14b": ModelConfig(
        name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152_064, head_dim=128,
        source="arXiv:2412.15115"),
    "qwen2.5-32b": ModelConfig(
        name="qwen2.5-32b", family="dense", n_layers=64, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=27648, vocab=152_064, head_dim=128,
        source="arXiv:2412.15115"),
    "qwen3-30b-a3b": ModelConfig(
        name="qwen3-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=768, vocab=151_936, head_dim=128,
        qk_norm=True,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
        source="hf:Qwen/Qwen3-30B-A3B"),
    "qwen2.5-0.5b": ModelConfig(
        name="qwen2.5-0.5b", family="dense", n_layers=24, d_model=896,
        n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151_936, head_dim=64,
        tie_embeddings=True, source="arXiv:2412.15115"),
}
