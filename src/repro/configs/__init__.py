"""Architecture registry: the 10 assigned configs + the paper's own models.

``get_config("gemma-7b")`` accepts dashed ids (the ``--arch`` flag form).
"""

from .base import (INPUT_SHAPES, InputShape, MLAConfig, ModelConfig,
                   MoEConfig, SSMConfig)

from .gemma_7b import CONFIG as _gemma_7b
from .starcoder2_15b import CONFIG as _starcoder2_15b
from .jamba_v0_1_52b import CONFIG as _jamba
from .phi3_5_moe_42b import CONFIG as _phi35_moe
from .whisper_tiny import CONFIG as _whisper_tiny
from .qwen3_32b import CONFIG as _qwen3_32b
from .paligemma_3b import CONFIG as _paligemma_3b
from .xlstm_1_3b import CONFIG as _xlstm_13b
from .qwen3_4b import CONFIG as _qwen3_4b
from .deepseek_v3_671b import CONFIG as _deepseek_v3
from .paper_models import PAPER_MODELS

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _gemma_7b, _starcoder2_15b, _jamba, _phi35_moe, _whisper_tiny,
        _qwen3_32b, _paligemma_3b, _xlstm_13b, _qwen3_4b, _deepseek_v3,
    ]
}

ALL_MODELS: dict[str, ModelConfig] = {**ARCHS, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    key = name.strip()
    if key in ALL_MODELS:
        return ALL_MODELS[key]
    # tolerate underscore/dash variants
    norm = key.replace("_", "-").lower()
    for k, v in ALL_MODELS.items():
        if k.lower() == norm:
            return v
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL_MODELS)}")


__all__ = ["ARCHS", "ALL_MODELS", "PAPER_MODELS", "INPUT_SHAPES",
           "InputShape", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "get_config"]
