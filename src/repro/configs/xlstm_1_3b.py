"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, 1 sLSTM per 8 [arXiv:2405.04517]."""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                      # xLSTM blocks carry their own up/down proj
    vocab=50_304,
    head_dim=512,
    ssm=SSMConfig(kind="xlstm", expand=2, slstm_every=8, chunk=128),
    source="arXiv:2405.04517",
)
