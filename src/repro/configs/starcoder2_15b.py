"""starcoder2-15b [dense] — GQA kv=4, RoPE, native 4k sliding window
[arXiv:2402.19173]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49_152,
    head_dim=128,
    gated_act="gelu",           # starcoder2 uses a plain (ungated) MLP
    sliding_window=4096,        # native SWA -> long_500k runs natively
    source="arXiv:2402.19173",
)
