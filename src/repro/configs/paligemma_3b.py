"""paligemma-3b [vlm] — SigLIP vision tower is a STUB (input_specs provides
patch embeddings); gemma-2b-class decoder with MQA kv=1 [arXiv:2407.07726]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,                # MQA
    d_ff=16384,
    vocab=257_216,
    head_dim=256,
    gated_act="geglu",
    tie_embeddings=True,
    embed_scale=True,
    prefix_len=256,              # 224px/14 -> 16x16 patches from stubbed SigLIP
    source="arXiv:2407.07726",
)
