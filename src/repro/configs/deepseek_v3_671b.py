"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8, MTP
[arXiv:2412.19437].

Simplifications vs the release (noted in DESIGN.md): all 61 layers are MoE
(the release keeps the first 3 dense), and sigmoid-gating/bias-free routing
is approximated by softmax top-k with an aux load-balance loss.
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,                   # per-expert FFN width
    vocab=129_280,
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    mtp=True,
    source="arXiv:2412.19437",
)
