from .decode import build_serve_step

__all__ = ["build_serve_step"]
