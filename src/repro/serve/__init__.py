from repro.core.kv_cache import DecodeSpec

from .decode import build_serve_step, build_verify_step
from .offloaded import OffloadedDecoder
from .request import Request, RequestMetrics, RequestState
from .scheduler import FifoScheduler, ServingEngine, ServingReport
from .spec import DraftSource, NGramDraft, SpecConfig, SpecStats

__all__ = [
    "build_serve_step",
    "build_verify_step",
    "DecodeSpec",
    "OffloadedDecoder",
    "Request",
    "RequestMetrics",
    "RequestState",
    "FifoScheduler",
    "ServingEngine",
    "ServingReport",
    "DraftSource",
    "NGramDraft",
    "SpecConfig",
    "SpecStats",
]
