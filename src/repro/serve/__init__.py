from .decode import build_serve_step
from .offloaded import OffloadedDecoder

__all__ = ["build_serve_step", "OffloadedDecoder"]
