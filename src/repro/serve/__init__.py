from repro.core.kv_cache import DecodeSpec

from .decode import build_serve_step
from .offloaded import OffloadedDecoder

__all__ = ["build_serve_step", "DecodeSpec", "OffloadedDecoder"]
