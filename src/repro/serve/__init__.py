from repro.core.kv_cache import DecodeSpec

from .decode import build_serve_step
from .offloaded import OffloadedDecoder
from .request import Request, RequestMetrics, RequestState
from .scheduler import FifoScheduler, ServingEngine, ServingReport

__all__ = ["build_serve_step", "DecodeSpec", "OffloadedDecoder",
           "Request", "RequestMetrics", "RequestState",
           "FifoScheduler", "ServingEngine", "ServingReport"]
