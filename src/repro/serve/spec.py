"""Speculative decoding over the weight-streamed serve path.

The economics are different from GPU speculative decoding.  On an
SSD-offloaded host the per-step cost is dominated by streaming every
block's weights through the pinned pool — a cost that is *flat* in the
number of query positions.  Verifying a K-token draft window in one
streamed pass therefore prices K tokens at roughly one token's weight
traffic; any accepted draft token is a whole block-stream round saved.
Even modest acceptance rates pay, and a *free* draft source is enough.

Three pieces:

* :class:`DraftSource` — the draft protocol, ``propose(context, n)``.
  Pluggable: anything that guesses continuation tokens works (a small
  resident model, a lookup table, ...).  Rejected guesses cost only the
  marginal query positions, never correctness.
* :class:`NGramDraft` — the built-in self-drafting source: suffix n-gram
  lookup over the request's own prompt + emitted tokens.  Free (no second
  model to stream), and effective exactly where generation is locally
  repetitive (code, structured text, extraction-style prompts).
* :class:`SpecStats` — accept/commit bookkeeping for one generation or
  serving run (see docs/METRICS.md: ``accepted_per_step``,
  ``spec_overhead_s``).

Greedy output equals plain decoding: the verify pass
(:meth:`~repro.core.session.OffloadSession.verify_step`) reproduces the
sequential step's logits bitwise at every window position, and the host
commits exactly the prefix the sequential argmax chain would have
produced.  Drafting quality affects *speed only*.  (One floating-point
caveat on very long generations — committed K/V come from window-shaped
projections, which XLA may round an ulp apart from step-shaped ones —
see the identity note in docs/SERVING.md.)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class DraftSource(Protocol):
    """Anything that proposes draft continuation tokens.

    ``propose`` receives the request's full visible context — prompt plus
    every token emitted so far, *including* the pending token whose K/V
    has not landed yet — and returns up to ``n`` guessed continuation ids
    as a 1-D integer array (possibly empty, never longer than ``n``).
    Guesses are free to be wrong; the verify pass rejects them at the
    cost of a wasted query position, never at the cost of output drift.
    """

    def propose(self, context: np.ndarray, n: int) -> np.ndarray: ...


class NGramDraft:
    """Self-drafting via suffix n-gram lookup over the request's context.

    Takes the last ``gram`` tokens as a key, scans the context backwards
    for that key's most recent earlier occurrence, and proposes the
    tokens that followed it.  The most recent match wins — local
    repetition (the common case in code and structured output) beats a
    stale early match.  No match, no draft: the round degenerates to a
    plain single-token step.
    """

    def __init__(self, gram: int = 2):
        if gram < 1:
            raise ValueError(f"gram must be >= 1, got {gram}")
        self.gram = gram

    def propose(self, context: np.ndarray, n: int) -> np.ndarray:
        ctx = np.asarray(context).ravel()
        g = self.gram
        if n < 1 or ctx.size <= g:
            return np.zeros((0,), np.int32)
        key = ctx[-g:]
        # candidate starts: every earlier position whose g-token window
        # matches the suffix key, newest first
        windows = np.lib.stride_tricks.sliding_window_view(ctx[:-1], g)
        hits = np.flatnonzero((windows == key).all(axis=1))
        for start in hits[::-1]:
            follow = ctx[start + g : start + g + n]
            if follow.size:
                return follow.astype(np.int32)
        return np.zeros((0,), np.int32)


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode knobs for one generation / serving run.

    ``k`` is the maximum verify-window width in tokens *including* the
    pending token, so up to ``k - 1`` draft guesses ride along per round;
    the executed window is padded to the covering power of two
    (:func:`~repro.core.session.verify_bucket`), which bounds the warm
    trace set.  ``draft`` defaults to a fresh :class:`NGramDraft`.
    """

    k: int = 4
    draft: DraftSource = field(default_factory=NGramDraft)

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec window k must be >= 1, got {self.k}")


@dataclass
class SpecStats:
    """Accept/commit counters for one spec-decode run.

    ``lane_rounds`` counts (verify pass × participating lane) pairs, so
    :attr:`accepted_per_step` is the mean tokens a lane commits per
    streamed pass — the headline number (1.0 means spec decode degenerated
    to plain stepping; the weight-traffic saving is roughly this factor).
    ``spec_overhead_s`` is the host-side time spent drafting, comparing
    and rolling back — everything spec decode adds *outside* the streamed
    verify pass itself.
    """

    rounds: int = 0
    lane_rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    committed_tokens: int = 0
    spec_overhead_s: float = 0.0

    @property
    def accepted_per_step(self) -> float:
        if self.lane_rounds == 0:
            return 0.0
        return self.committed_tokens / self.lane_rounds

    def snapshot(self) -> dict:
        return {
            "rounds": self.rounds,
            "lane_rounds": self.lane_rounds,
            "drafted": self.drafted,
            "accepted": self.accepted,
            "committed_tokens": self.committed_tokens,
            "accepted_per_step": self.accepted_per_step,
            "spec_overhead_s": self.spec_overhead_s,
        }
