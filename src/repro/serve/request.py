"""Request lifecycle dataclasses for the continuous-batching front-end.

A :class:`Request` is one prompt → greedy-completion job moving through
``QUEUED → ACTIVE → DONE`` (or ``QUEUED → REFUSED`` when the KV-page
admission check says its prompt could never stream its own attended
window).  The scheduler stamps :class:`RequestMetrics` with engine-clock
times as the request crosses each boundary; derived latencies (queue wait,
time-to-first-token, decode tokens/s) are properties so reports never
carry stale copies.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum

import numpy as np


class RequestState(Enum):
    QUEUED = "queued"  # arrived, waiting for a slot / admission
    ACTIVE = "active"  # holds a batch slot, prefilled or decoding
    DONE = "done"  # retired: EOS, length cap, or max_new reached
    REFUSED = "refused"  # terminal: prompt pages cannot be streamed


@dataclass
class RequestMetrics:
    """Engine-clock stamps (seconds since the engine's run() started).

    ``arrival`` is when the request became visible to the scheduler;
    ``admitted_at`` when it won a batch slot; ``first_token_at`` when its
    prefill emitted the first greedy token; ``finished_at`` when it
    retired."""

    arrival: float = 0.0
    admitted_at: float | None = None
    first_token_at: float | None = None
    finished_at: float | None = None
    tokens_out: int = 0

    @property
    def queue_wait_s(self) -> float | None:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.arrival

    @property
    def ttft_s(self) -> float | None:
        """Arrival → first token (the serving-latency headline)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival

    @property
    def decode_tokens_per_s(self) -> float | None:
        """Emitted tokens over the request's slot-holding time."""
        if self.finished_at is None or self.admitted_at is None:
            return None
        dt = self.finished_at - self.admitted_at
        return self.tokens_out / dt if dt > 0 else None


@dataclass
class Request:
    """One serving job: prompt ids + a greedy-decode budget.

    ``arrival`` is the request's offered arrival time on the engine clock
    (0.0 = available immediately); the scheduler will not see it earlier.
    ``eos_token`` stops decode early when emitted (the emitted EOS is kept
    in the output).  ``max_new_tokens`` caps emission; the engine also
    retires a request whose cache would exceed the spec's ``max_seq``.
    """

    rid: str
    prompt: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0
    eos_token: int | None = None
    state: RequestState = RequestState.QUEUED
    slot: int | None = None
    output: list[int] = field(default_factory=list)
    metrics: RequestMetrics = field(default_factory=RequestMetrics)

    def __post_init__(self) -> None:
        arr = np.asarray(self.prompt)
        if arr.ndim != 1 or arr.size < 1:
            raise ValueError(
                f"request {self.rid}: prompt must be a "
                f"non-empty 1-D token array, got {arr.shape}"
            )
        if not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(
                f"request {self.rid}: prompt must hold integer "
                f"token ids, got {arr.dtype}"
            )
        if int(arr.min()) < 0:
            raise ValueError(f"request {self.rid}: negative token ids")
        self.prompt = np.ascontiguousarray(arr, dtype=np.int32)
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.rid}: max_new_tokens must be "
                f">= 1, got {self.max_new_tokens}"
            )
        self.metrics.arrival = float(self.arrival)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])
