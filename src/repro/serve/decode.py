"""Jitted decode (serving) step with explicit cache shardings.

``serve_step(params, cache, tokens, cache_len) -> (logits, new_cache)``:
one new token against a KV cache / recurrent state of ``seq_len`` context
(the assigned ``decode_32k`` / ``long_500k`` shapes).  The cache is donated
— decoding updates it in place, which is what keeps HBM flat at scale.

``build_verify_step`` is the speculative-decoding counterpart: a K-wide
token window folded through the same per-token decode step *inside one
XLA program* (``lax.scan``), returning every position's logits so the
host can accept the longest agreeing draft prefix.  On this
device-resident path the win is dispatch/launch amortization — K steps,
one program — unlike the SSD-offloaded verify
(:meth:`repro.core.session.OffloadSession.verify_step`), where one pass
prices K tokens at a single streamed weight read.  Scanning the exact
single-step function keeps the logits chain identical to stepping.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as shd
from repro.models.registry import ModelImpl
from repro.configs.base import InputShape


def build_serve_step(
    impl: ModelImpl,
    mesh,
    shape: InputShape,
    *,
    cache_dtype=jnp.bfloat16,
    param_mode: str = "zero3",
):
    """Returns (serve_fn, in_shardings, out_shardings, arg_specs).

    ``param_mode="tp"`` serves with model-axis-only weight sharding (no
    per-token ZeRO-3 all-gather) — see sharding.param_specs.
    """
    cfg = impl.cfg
    cache_specs, tokens_spec, len_spec = impl.decode_args_specs(shape, cache_dtype)

    def serve(params, cache, tokens, cache_len):
        return impl.decode_fn(params, cache, tokens, cache_len)

    params_shape = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(cfg, params_shape, mesh, mode=param_mode)
    cshard = shd.cache_shardings(cfg, cache_specs, mesh)
    dp = shd.batch_axes(mesh)
    b = shape.global_batch
    tok_spec = (
        P(dp, None)
        if b % math.prod(mesh.shape[a] for a in dp) == 0
        else P(None, None)
    )
    tshard = NamedSharding(mesh, tok_spec)
    scalar = NamedSharding(mesh, P())
    logits_shard = NamedSharding(mesh, shd.logits_spec(cfg, mesh, shape.global_batch))
    in_shardings = (pshard, cshard, tshard, scalar)
    out_shardings = (logits_shard, cshard)
    arg_specs = (cache_specs, tokens_spec, len_spec)
    return serve, in_shardings, out_shardings, arg_specs


def build_verify_step(
    impl: ModelImpl,
    mesh,
    shape: InputShape,
    *,
    window: int,
    cache_dtype=jnp.bfloat16,
    param_mode: str = "zero3",
):
    """Returns (verify_fn, in_shardings, out_shardings, arg_specs).

    ``verify_fn(params, cache, tokens, cache_len) -> (logits, new_cache)``
    with ``tokens`` of shape ``(batch, window)`` and ``logits``
    ``(batch, window, vocab)``: position ``j``'s row is exactly what the
    single-token :func:`build_serve_step` chain would produce after
    appending the window's first ``j`` tokens.  The host owns
    accept/reject; on rejection it re-issues from the last accepted
    position (``cache_len`` gates what later steps may attend to, so
    stale window K/V past the commit point is overwritten, never read).
    """
    if window < 1:
        raise ValueError(f"verify window must be >= 1, got {window}")
    cfg = impl.cfg
    cache_specs, tokens_spec, len_spec = impl.decode_args_specs(shape, cache_dtype)

    def verify(params, cache, tokens, cache_len):
        def body(carry, tok):
            cache, pos = carry
            logits, cache = impl.decode_fn(params, cache, tok[:, None], pos)
            return (cache, pos + 1), logits[:, 0]

        (cache, _), logits = jax.lax.scan(body, (cache, cache_len), tokens.T)
        return jnp.moveaxis(logits, 0, 1), cache

    params_shape = jax.eval_shape(impl.init_params, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(cfg, params_shape, mesh, mode=param_mode)
    cshard = shd.cache_shardings(cfg, cache_specs, mesh)
    dp = shd.batch_axes(mesh)
    b = shape.global_batch
    tok_spec = (
        P(dp, None)
        if b % math.prod(mesh.shape[a] for a in dp) == 0
        else P(None, None)
    )
    tshard = NamedSharding(mesh, tok_spec)
    scalar = NamedSharding(mesh, P())
    logits_shard = NamedSharding(mesh, shd.logits_spec(cfg, mesh, shape.global_batch))
    in_shardings = (pshard, cshard, tshard, scalar)
    out_shardings = (logits_shard, cshard)
    window_sds = jax.ShapeDtypeStruct((b, window), tokens_spec.dtype)
    arg_specs = (cache_specs, window_sds, len_spec)
    return verify, in_shardings, out_shardings, arg_specs
