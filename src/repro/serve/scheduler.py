"""Continuous-batching scheduler over the paged spill-able KV cache.

Splits serving into two layers with a deliberate boundary:

* :class:`FifoScheduler` — pure admission policy.  Holds the not-yet-
  arrived and arrived-but-waiting queues, reveals requests to the engine
  only once their offered ``arrival`` time has passed, and admits strictly
  in FIFO order: the queue head either joins a free batch slot, is refused
  terminally (its prompt's page window can never be streamed under the
  cache's residency budget — admitting it would thrash every other lane),
  or blocks the queue until a slot frees.  No skip-ahead: later requests
  never overtake an admissible head, so queue-wait is bounded by slot
  turnover, not by luck.
* :class:`ServingEngine` — execution.  Drives the session's compile-once
  serve path: joiners are prefilled in prompt-*bucket* groups through the
  KVWriteOp prefill-scatter mode (each group runs the exact trace a solo
  prefill of those requests would, which keeps continuously-batched greedy
  output bit-identical to decoding every request alone), active slots
  advance together through :meth:`OffloadSession.decode_step_slots`, and
  finished slots retire immediately — pages reclaimed without a spill
  write, the slot returned to the free list for the next joiner.

The engine takes injectable ``clock``/``sleep`` callables so tests can
drive arrivals deterministically with a fake clock; the defaults are wall
time.  ``run(mode="static")`` is the ablation baseline: classic static
batching that forms full batches in arrival order and admits nothing until
the whole batch drains.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.session import verify_bucket
from repro.serve.request import Request, RequestState
from repro.serve.spec import SpecConfig, SpecStats


class FifoScheduler:
    """Arrival-ordered admission over the cache's slots and page budget."""

    def __init__(self, requests: list[Request]):
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("duplicate request ids")
        for r in requests:
            if r.state is not RequestState.QUEUED:
                raise ValueError(f"request {r.rid} already {r.state.value}")
        # stable sort: ties on arrival keep submission order (FIFO)
        self._pending = deque(sorted(requests, key=lambda r: r.arrival))
        self._queue: deque[Request] = deque()

    def poll(self, now: float) -> None:
        """Reveal every request whose arrival time has passed."""
        while self._pending and self._pending[0].arrival <= now:
            self._queue.append(self._pending.popleft())

    def admit(self, kv, now: float) -> list[Request]:
        """Admit from the queue head: join a slot per request until the
        free list runs dry.  Inadmissible prompts are refused terminally
        and do not block the queue; admissible ones do (no skip-ahead)."""
        joiners: list[Request] = []
        while self._queue:
            r = self._queue[0]
            if not kv.admissible(r.prompt_len):
                self._queue.popleft()
                r.state = RequestState.REFUSED
                r.metrics.finished_at = now
                continue
            if kv.free_slots == 0:
                break
            slot = kv.join()
            assert slot is not None
            self._queue.popleft()
            r.slot = slot
            r.state = RequestState.ACTIVE
            r.metrics.admitted_at = now
            joiners.append(r)
        return joiners

    def next_arrival(self) -> float | None:
        return self._pending[0].arrival if self._pending else None

    @property
    def waiting(self) -> int:
        """Arrived requests not yet admitted."""
        return len(self._queue)

    @property
    def drained(self) -> bool:
        return not self._pending and not self._queue


@dataclass
class ServingReport:
    """Outcome of one :meth:`ServingEngine.run`: the requests (with their
    stamped metrics) plus engine-level throughput counters."""

    requests: list[Request]
    mode: str
    duration_s: float
    decode_steps: int = 0
    active_lane_steps: int = 0
    prefills: int = 0
    batch: int = 0
    kv_stats: dict = field(default_factory=dict)
    # speculative decoding (all zero unless the engine ran with spec=...)
    spec_rounds: int = 0
    spec_committed: int = 0
    spec_lane_rounds: int = 0
    spec_overhead_s: float = 0.0

    @property
    def completed(self) -> list[Request]:
        return [r for r in self.requests if r.state is RequestState.DONE]

    @property
    def refused(self) -> list[Request]:
        return [r for r in self.requests if r.state is RequestState.REFUSED]

    @property
    def total_tokens(self) -> int:
        return sum(r.metrics.tokens_out for r in self.completed)

    @property
    def tokens_per_s(self) -> float:
        """Aggregate emitted tokens over the whole run's wall time."""
        return self.total_tokens / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of batch lanes doing useful work per decode step
        — the number continuous batching exists to raise."""
        if self.decode_steps == 0 or self.batch == 0:
            return 0.0
        return self.active_lane_steps / (self.decode_steps * self.batch)

    @property
    def accepted_per_step(self) -> float:
        """Mean tokens a lane commits per speculative verify pass — the
        weight-traffic saving factor (0.0 when spec decode was off)."""
        if self.spec_lane_rounds == 0:
            return 0.0
        return self.spec_committed / self.spec_lane_rounds

    def ttft_percentile(self, q: float) -> float:
        """q-th percentile (0-100) of arrival → first-token latency."""
        ttfts = [
            r.metrics.ttft_s for r in self.completed if r.metrics.ttft_s is not None
        ]
        if not ttfts:
            raise ValueError("no completed requests with a first token")
        return float(np.percentile(np.asarray(ttfts), q))


class ServingEngine:
    """Drives an :class:`~repro.serve.offloaded.OffloadedDecoder`'s
    session as a continuous-batching server.

    ``clock`` and ``sleep`` default to wall time; tests inject a fake pair
    to make arrivals and queue-wait metrics deterministic.  One ``run()``
    at a time: it opens the session's single KV cache and closes it (page
    slots returned, in-flight request pages reclaimed) on every exit path.
    """

    def __init__(
        self,
        decoder,
        *,
        spec: SpecConfig | None = None,
        clock=time.monotonic,
        sleep=time.sleep,
    ):
        if decoder.decode_spec is None:
            raise ValueError(
                "ServingEngine needs a decoder built with "
                "decode=DecodeSpec(...) — the paged KV cache "
                "is the serving substrate"
            )
        self.decoder = decoder
        self.spec = spec
        self._spec_stats: SpecStats | None = None
        self._clock = clock
        self._sleep = sleep
        self._t0 = 0.0

    def _now(self) -> float:
        return self._clock() - self._t0

    # -- request lifecycle helpers -------------------------------------------

    @staticmethod
    def _token_cap(r: Request, max_seq: int) -> int:
        """Emission cap: the request's own budget, or the cache running
        out of positions to append into (prefill's first token is free —
        it appends nothing)."""
        return min(r.max_new_tokens, max_seq - r.prompt_len + 1)

    def _emit(
        self, r: Request, token: int, now: float, next_tok: np.ndarray, max_seq: int
    ) -> bool:
        """Record one greedy token; returns True when the request is done
        (EOS or cap) and should retire."""
        if r.metrics.first_token_at is None:
            r.metrics.first_token_at = now
        r.output.append(token)
        r.metrics.tokens_out += 1
        next_tok[r.slot] = token
        if token == r.eos_token:
            return True
        return r.metrics.tokens_out >= self._token_cap(r, max_seq)

    @staticmethod
    def _retire(kv, r: Request, now: float) -> None:
        kv.retire(r.slot)
        r.state = RequestState.DONE
        r.metrics.finished_at = now

    def _prefill_group(
        self,
        kv,
        group: list[Request],
        next_tok: np.ndarray,
        by_slot: dict[int, Request],
    ) -> None:
        """One prefill-scatter pass for a same-bucket group of joiners."""
        session = self.decoder.session
        spec = self.decoder.decode_spec
        t_pad = max(r.prompt_len for r in group)
        toks = np.zeros((spec.batch, t_pad), np.int32)
        for r in group:
            toks[r.slot, : r.prompt_len] = r.prompt
        logits = session.prefill(
            kv,
            toks,
            slots=[r.slot for r in group],
            lengths=[r.prompt_len for r in group],
        )
        now = self._now()
        for r in group:
            done = self._emit(
                r, int(np.argmax(logits[r.slot])), now, next_tok, spec.max_seq
            )
            if done:
                self._retire(kv, r, now)
            else:
                by_slot[r.slot] = r

    def _step_active(
        self, kv, next_tok: np.ndarray, by_slot: dict[int, Request]
    ) -> int:
        """One batched decode step; retires finishing slots.  Returns the
        number of lanes that did useful work."""
        session = self.decoder.session
        spec = self.decoder.decode_spec
        toks = np.zeros((spec.batch, 1), np.int32)
        for slot in by_slot:
            toks[slot, 0] = next_tok[slot]
        logits = session.decode_step_slots(kv, toks)
        now = self._now()
        lanes = len(by_slot)
        for slot, r in sorted(by_slot.items()):
            if self._emit(r, int(np.argmax(logits[slot])), now, next_tok, spec.max_seq):
                del by_slot[slot]
                self._retire(kv, r, now)
        return lanes

    def _step_active_spec(
        self, kv, next_tok: np.ndarray, by_slot: dict[int, Request]
    ) -> int:
        """One speculative round over the active slots: a shared-width
        draft window (each slot's pending token + its own n-gram drafts)
        verified in one streamed pass, then **per-slot** accept/commit —
        one lane's rejection rolls only that lane's pages back; the
        others keep every token their own drafts earned.  Finishing
        slots (EOS or cap mid-window) stop committing early and retire.
        Returns the number of lanes that did useful work."""
        session = self.decoder.session
        dspec = self.decoder.decode_spec
        sc = self.spec
        stats = self._spec_stats
        th0 = time.perf_counter()
        # shared window width: the tightest lane's capacity bounds the
        # padded window for everyone (per-query results are extent- and
        # padding-invariant, so a wide lane loses nothing but the pad)
        n_cap = sc.k
        while n_cap > 1 and any(
            kv.slot_length(s) + verify_bucket(n_cap) > dspec.max_seq for s in by_slot
        ):
            n_cap -= 1
        drafts = {}
        for slot, r in by_slot.items():
            room = self._token_cap(r, dspec.max_seq) - r.metrics.tokens_out
            want = min(n_cap, max(room, 1)) - 1
            context = np.concatenate([r.prompt, np.asarray(r.output, np.int32)])
            drafts[slot] = sc.draft.propose(context, want)[: max(want, 0)]
        n = 1 + max((d.shape[0] for d in drafts.values()), default=0)
        toks = np.zeros((dspec.batch, n), np.int32)
        for slot in by_slot:
            toks[slot, 0] = next_tok[slot]
            d = drafts[slot]
            toks[slot, 1 : 1 + d.shape[0]] = d
            stats.drafted += int(d.shape[0])
        stats.spec_overhead_s += time.perf_counter() - th0
        logits = session.verify_step_slots(kv, toks)
        now = self._now()
        th1 = time.perf_counter()
        greedy = np.argmax(logits, axis=-1).astype(np.int32)
        lanes = len(by_slot)
        for slot, r in sorted(by_slot.items()):
            base = kv.slot_length(slot)
            accept = 0
            while accept + 1 < n and toks[slot, accept + 1] == greedy[slot, accept]:
                accept += 1
            committed = 0
            done = False
            for j in range(accept + 1):
                done = self._emit(r, int(greedy[slot, j]), now, next_tok, dspec.max_seq)
                committed += 1
                if done:
                    break
            stats.lane_rounds += 1
            stats.committed_tokens += committed
            stats.accepted += committed - 1
            if done:
                del by_slot[slot]
                self._retire(kv, r, now)  # drops ALL the slot's pages
            else:
                kv.rollback(slot, base + committed)
        stats.rounds += 1
        stats.spec_overhead_s += time.perf_counter() - th1
        return lanes

    def _step(self, kv, next_tok: np.ndarray, by_slot: dict[int, Request]) -> int:
        """One batched advance of the active slots — speculative when the
        engine was built with ``spec=``, plain greedy otherwise."""
        if self.spec is not None:
            return self._step_active_spec(kv, next_tok, by_slot)
        return self._step_active(kv, next_tok, by_slot)

    @staticmethod
    def _bucket_groups(spec, joiners: list[Request]) -> list[list[Request]]:
        """Group joiners by prompt time-bucket so each group's prefill
        runs the exact trace a solo prefill would (bit-identical output);
        ordered by bucket for determinism."""
        groups: dict[int, list[Request]] = {}
        for r in joiners:
            groups.setdefault(spec.bucket_len(r.prompt_len), []).append(r)
        return [groups[b] for b in sorted(groups)]

    # -- drive loops ---------------------------------------------------------

    def run(self, requests: list[Request], mode: str = "continuous") -> ServingReport:
        """Serve ``requests`` to completion; returns the stamped report.

        ``mode="continuous"``: per-slot join/decode/retire — a finishing
        request's slot and pages go to the next joiner immediately.
        ``mode="static"``: the ablation — full batches in arrival order,
        nothing admitted until the previous batch fully drains.
        """
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown serving mode {mode!r}")
        if not requests:
            raise ValueError("no requests to serve")
        session = self.decoder.session
        spec = self.decoder.decode_spec
        report = ServingReport(
            requests=list(requests), mode=mode, duration_s=0.0, batch=spec.batch
        )
        sched = FifoScheduler(report.requests)
        self._spec_stats = SpecStats()
        kv = session.open_kv_cache()
        self._t0 = self._clock()
        try:
            # a fresh cache opens with every slot active (the joint-prefill
            # contract); serving starts from an all-free slot pool
            for s in sorted(kv.active):
                kv.retire(s)
            if mode == "continuous":
                self._drive_continuous(kv, sched, report)
            else:
                self._drive_static(kv, sched, report)
            report.duration_s = self._now()
            return report
        finally:
            # closes on error paths too: in-flight requests' pages are
            # reclaimed with the cache, never orphaned in the pool
            self.decoder.kv_stats = report.kv_stats = kv.stats.snapshot()
            st = self._spec_stats
            report.spec_rounds = st.rounds
            report.spec_committed = st.committed_tokens
            report.spec_lane_rounds = st.lane_rounds
            report.spec_overhead_s = st.spec_overhead_s
            if self.spec is not None:
                self.decoder.spec_stats = st
            kv.close()

    def _drive_continuous(
        self, kv, sched: FifoScheduler, report: ServingReport
    ) -> None:
        spec = self.decoder.decode_spec
        next_tok = np.zeros(spec.batch, np.int32)
        by_slot: dict[int, Request] = {}
        while not (sched.drained and not by_slot):
            sched.poll(self._now())
            joiners = sched.admit(kv, self._now())
            if joiners:
                for group in self._bucket_groups(spec, joiners):
                    self._prefill_group(kv, group, next_tok, by_slot)
                    report.prefills += 1
                continue  # re-poll: prefill took time, more may have come
            if by_slot:
                report.active_lane_steps += self._step(kv, next_tok, by_slot)
                report.decode_steps += 1
                continue
            # idle: every arrived request served, more still to come.  An
            # admissible queued request never strands here — with no active
            # slots the whole free list was available to admit() above.
            nxt = sched.next_arrival()
            if nxt is None:
                break
            delay = nxt - self._now()
            if delay > 0:
                self._sleep(delay)

    def _drive_static(self, kv, sched: FifoScheduler, report: ServingReport) -> None:
        """Classic static batching: take the next ``batch`` requests in
        arrival order, wait for all of them, prefill them as one group,
        and drain the whole batch before admitting anyone else."""
        spec = self.decoder.decode_spec
        next_tok = np.zeros(spec.batch, np.int32)
        while not sched.drained:
            # block until a full batch (or the final remainder) is here
            while True:
                sched.poll(self._now())
                nxt = sched.next_arrival()
                if nxt is None or sched.waiting >= spec.batch:
                    break
                delay = nxt - self._now()
                if delay > 0:
                    self._sleep(delay)
            by_slot: dict[int, Request] = {}
            joiners = sched.admit(kv, self._now())
            if joiners:
                # prefill in prompt-bucket groups, same as continuous: a
                # short prompt prefilled in a longer prompt's bucket runs
                # a different trace than its solo prefill would, which
                # voids the output-equals-solo-decode contract.  The
                # static tax is the decode drain, not the prefill.
                for group in self._bucket_groups(spec, joiners):
                    self._prefill_group(kv, group, next_tok, by_slot)
                    report.prefills += 1
            while by_slot:
                report.active_lane_steps += self._step(kv, next_tok, by_slot)
                report.decode_steps += 1
