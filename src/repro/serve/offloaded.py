"""Weight-streamed offloaded decode: serving through the offload session.

Opens the paper's pipeline to a new workload: generation on a host whose
DRAM cannot hold the model.  Weights stay on SSD; every decode step streams
them block-by-block through the same pool-slot → async-read → H2D → compute
→ release lifecycle as training, executed from a ``decode`` StreamPlan with
lookahead pipelining (block *i+1*'s SSD read overlaps block *i*'s compute).

This is throughput-oriented batch decoding: each emitted token re-runs the
full prefix through the streamed stack (no KV cache — per-layer caches
would pin host memory the offload budget doesn't have; a spill-able KV
cache is a ROADMAP follow-on).  The jitted serve path with device-resident
weights and donated caches lives in :mod:`repro.serve.decode`; this module
is its SSD-offloaded counterpart.
"""

from __future__ import annotations

import numpy as np

from repro.core.session import OffloadSession


class OffloadedDecoder:
    """Greedy batch decoding over an SSD-resident model.

    Wraps a serve-mode :class:`OffloadSession` (no optimizer state on the
    store, no gradient flat buffer) unless an open session is handed in.
    Context manager; closing releases the pool arena and store.
    """

    def __init__(self, model, policy, *, session: OffloadSession | None = None):
        self.session = session or OffloadSession(model, policy, mode="serve")
        self._owns_session = session is None

    def __enter__(self) -> "OffloadedDecoder":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        if self._owns_session:
            self.session.close()

    def step_logits(self, tokens: np.ndarray) -> np.ndarray:
        """Next-token logits for a (batch, time) prompt — one streamed pass."""
        logits = self.session.decode_logits(tokens)
        return logits[:, -1, :]

    def generate(self, prompts: np.ndarray, new_tokens: int) -> np.ndarray:
        """Greedy-decode ``new_tokens`` per request; returns (batch, new)."""
        tokens = np.asarray(prompts, dtype=np.int32)
        if tokens.ndim != 2:
            raise ValueError(f"prompts must be (batch, time), got "
                             f"{tokens.shape}")
        out = []
        for _ in range(new_tokens):
            nxt = np.argmax(self.step_logits(tokens), axis=-1).astype(np.int32)
            out.append(nxt)
            tokens = np.concatenate([tokens, nxt[:, None]], axis=1)
        return np.stack(out, axis=1)

    @property
    def fetch_stats(self) -> dict:
        """Swapper counters — how well decode hides SSD latency."""
        return self.session.swapper.stats.snapshot()
