"""Weight-streamed offloaded decode: serving through the offload session.

Opens the paper's pipeline to a new workload: generation on a host whose
DRAM cannot hold the model.  Weights stay on SSD; every decode step streams
them block-by-block through the same pool-slot → async-read → H2D → compute
→ release lifecycle as training, executed from StreamPlans with lookahead
pipelining (block *i+1*'s SSD read overlaps block *i*'s compute).

Two serving modes:

* **cached** (default when the session carries a
  :class:`~repro.core.kv_cache.DecodeSpec`): prefill-then-step over a
  **paged** spill-able KV cache.  K/V lives in fixed-size time-axis pages
  (``spec.page_size`` tokens each) in pool slots inside the same pinned
  arena as the weight staging buffers; only *dirty* pages pay a spill
  write past the residency budget and only the attended window's pages
  refill, so per-token cost is O(bucket) — independent of how many tokens
  were emitted — and each time bucket jit-compiles once.  Under
  ``policy.overlap`` ≠ ``"sync"`` each block's KV window is gathered and
  H2D'd on the staging worker beneath the previous block's compute
  (:meth:`OffloadedDecoder.kv_overlap_stats` shows the hit rate).
* **uncached**: the PR-1 behaviour — every emitted token re-runs the full
  prefix (O(T²) compute, a retrace per step).  Kept as the ablation
  baseline (``benchmarks/bench_decode.py``) and for model families without
  cached-decode applies (mamba/xLSTM mixers).

The jitted serve path with device-resident weights and donated caches lives
in :mod:`repro.serve.decode`; this module is its SSD-offloaded counterpart.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.kv_cache import DecodeSpec
from repro.core.session import OffloadSession, verify_bucket
from repro.serve.spec import SpecConfig, SpecStats


class OffloadedDecoder:
    """Greedy batch decoding over an SSD-resident model.

    Wraps a serve-mode :class:`OffloadSession` (no optimizer state on the
    store, no gradient flat buffer) unless an open session is handed in.
    Pass ``decode=DecodeSpec(...)`` to size the session's pool for the
    spill-able KV cache and enable O(T) cached generation.
    Context manager; closing releases the pool arena and store.

    Token contract (validated once, here): prompts/tokens are
    ``(batch, time)`` arrays of non-negative integer ids, any integer
    dtype, converted to int32.  Floats, scalars, and flat arrays are
    rejected rather than silently cast.
    """

    def __init__(
        self,
        model,
        policy,
        *,
        session: OffloadSession | None = None,
        decode: DecodeSpec | None = None,
    ):
        if session is not None and decode is not None:
            raise ValueError(
                "pass decode= when the decoder owns the "
                "session; an existing session already fixed "
                "its pool census"
            )
        self.session = session or OffloadSession(
            model, policy, mode="serve", decode=decode
        )
        self._owns_session = session is None
        self.kv_stats: dict | None = None  # last cached run's KV stats
        self.spec_stats: SpecStats | None = None  # last spec-decode run's
        self._closed = False
        self._last_fetch: dict | None = None
        self._last_overlap: dict | None = None

    def __enter__(self) -> "OffloadedDecoder":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Idempotent teardown.  Counter snapshots are taken first so
        :attr:`fetch_stats` / :attr:`kv_overlap_stats` keep answering
        after the session (and its worker threads) are gone — post-mortem
        reads see the final numbers instead of raising."""
        if self._closed:
            return
        self._last_fetch = self.session.swapper.stats.snapshot()
        self._last_overlap = self._overlap_live()
        self._closed = True
        if self._owns_session:
            self.session.close()

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def decode_spec(self) -> DecodeSpec | None:
        return self.session.decode_spec

    @staticmethod
    def _validate_tokens(tokens, name: str = "tokens") -> np.ndarray:
        """Enforce the token contract; returns a contiguous int32 copy."""
        arr = np.asarray(tokens)
        if arr.ndim != 2:
            raise ValueError(f"{name} must be (batch, time), got shape {arr.shape}")
        if not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(
                f"{name} must hold integer token ids, got dtype {arr.dtype}"
            )
        if arr.size and int(arr.min()) < 0:
            raise ValueError(f"{name} holds negative token ids")
        return np.ascontiguousarray(arr, dtype=np.int32)

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                "decoder is closed (stats properties still "
                "answer; compute paths do not)"
            )

    def step_logits(self, tokens: np.ndarray) -> np.ndarray:
        """Next-token logits for a (batch, time) prompt — one full streamed
        pass (uncached; see :meth:`generate` for the cached loop)."""
        self._check_open()
        tokens = self._validate_tokens(tokens)
        logits = self.session.decode_logits(tokens)
        return logits[:, -1, :]

    def generate(
        self,
        prompts: np.ndarray,
        new_tokens: int,
        *,
        use_cache: bool | None = None,
        spec: SpecConfig | None = None,
    ) -> np.ndarray:
        """Greedy-decode ``new_tokens`` per request; returns (batch, new).

        ``use_cache=None`` picks cached decode whenever the session has a
        DecodeSpec; ``use_cache=False`` forces the O(T²) full-prefix path
        (the bench ablation).  ``spec=SpecConfig(...)`` runs speculative
        decoding over the cached path — draft windows verified K tokens
        at a time with per-slot KV rollback; output matches the plain
        greedy loop (see :mod:`repro.serve.spec`), stats land in
        :attr:`spec_stats`.
        """
        self._check_open()
        tokens = self._validate_tokens(prompts, name="prompts")
        if tokens.shape[1] < 1:
            raise ValueError("prompts must hold at least one token")
        if new_tokens < 1:
            raise ValueError(f"new_tokens must be >= 1, got {new_tokens}")
        dspec = self.session.decode_spec
        cached = (dspec is not None) if use_cache is None else use_cache
        if spec is not None and not cached:
            raise ValueError(
                "speculative decoding needs the cached path; "
                "it cannot run with use_cache=False"
            )
        if not cached:
            return self._generate_uncached(tokens, new_tokens)
        if dspec is None:
            raise RuntimeError(
                "use_cache=True needs a session built with "
                "decode=DecodeSpec(...) so the pool census has KV slots"
            )
        batch, t0 = tokens.shape
        if batch != dspec.batch:
            raise ValueError(
                f"prompts batch {batch} != DecodeSpec batch "
                f"{dspec.batch} (jit shapes are fixed)"
            )
        if t0 + new_tokens > dspec.max_seq:
            raise ValueError(
                f"prompt ({t0}) + new_tokens ({new_tokens}) exceeds "
                f"DecodeSpec max_seq {dspec.max_seq}"
            )
        kv = self.session.open_kv_cache()
        try:
            if spec is not None:
                return self._generate_spec(kv, tokens, new_tokens, spec)
            logits = self.session.prefill(kv, tokens)
            out = []
            for i in range(new_tokens):
                nxt = np.argmax(logits, axis=-1).astype(np.int32)
                out.append(nxt)
                if i + 1 < new_tokens:
                    logits = self.session.decode_step(kv, nxt[:, None])
            return np.stack(out, axis=1)
        finally:
            self.kv_stats = kv.stats.snapshot()
            kv.close()

    def _generate_spec(
        self, kv, tokens: np.ndarray, new_tokens: int, spec: SpecConfig
    ) -> np.ndarray:
        """Speculative greedy loop over the cached path (joint batch).

        Round invariant: the cache holds every emitted token but the
        last, which rides as the pending head of the next verify window
        ``[pending, draft...]``.  The verify pass prices the whole window
        at ~one streamed weight pass; the host commits the longest prefix
        the sequential argmax chain agrees with (all lanes advance in
        lockstep by the batch minimum — recomputed tokens are
        deterministic, so per-lane output is unchanged) and rolls every
        slot back over the rejected tail.
        """
        session = self.session
        dspec = session.decode_spec
        stats = SpecStats()
        try:
            logits = session.prefill(kv, tokens)
            batch = tokens.shape[0]
            t_next = np.argmax(logits, axis=-1).astype(np.int32)
            out = [t_next.copy()]
            emitted = 1
            contexts = [
                list(map(int, tokens[b])) + [int(t_next[b])] for b in range(batch)
            ]
            while emitted < new_tokens:
                th0 = time.perf_counter()
                remaining = new_tokens - emitted
                n_cap = min(spec.k, remaining)
                drafts = [
                    spec.draft.propose(np.asarray(contexts[b], np.int32), n_cap - 1)
                    for b in range(batch)
                ]
                n = 1 + max(d.shape[0] for d in drafts)
                # padded window must still fit the cache capacity
                while n > 1 and kv.length + verify_bucket(n) > dspec.max_seq:
                    n -= 1
                window = np.zeros((batch, n), np.int32)
                window[:, 0] = t_next
                for b, d in enumerate(drafts):
                    m = min(d.shape[0], n - 1)
                    window[b, 1 : 1 + m] = d[:m]
                    stats.drafted += m
                stats.spec_overhead_s += time.perf_counter() - th0
                vlogits = session.verify_step(kv, window)
                th1 = time.perf_counter()
                greedy = np.argmax(vlogits, axis=-1).astype(np.int32)
                accept = np.zeros(batch, np.int64)
                for b in range(batch):
                    j = 0
                    while j + 1 < n and window[b, j + 1] == greedy[b, j]:
                        j += 1
                    accept[b] = j
                commit = int(min(int(accept.min()) + 1, remaining))
                for j in range(commit):
                    out.append(greedy[:, j].copy())
                base = kv.length
                for s in sorted(kv.active):
                    kv.rollback(s, base + commit)
                t_next = greedy[:, commit - 1].copy()
                for b in range(batch):
                    contexts[b].extend(int(x) for x in greedy[b, :commit])
                emitted += commit
                stats.rounds += 1
                stats.lane_rounds += batch
                stats.committed_tokens += commit * batch
                stats.accepted += (commit - 1) * batch
                stats.spec_overhead_s += time.perf_counter() - th1
            return np.stack(out, axis=1)
        finally:
            self.spec_stats = stats

    def _generate_uncached(self, tokens: np.ndarray, new_tokens: int) -> np.ndarray:
        """Full-prefix re-run per token (the PR-1 path; O(T²) ablation)."""
        out = []
        for _ in range(new_tokens):
            nxt = np.argmax(self.step_logits(tokens), axis=-1)
            nxt = nxt.astype(np.int32)
            out.append(nxt)
            tokens = np.concatenate([tokens, nxt[:, None]], axis=1)
        return np.stack(out, axis=1)

    def _overlap_live(self) -> dict:
        snap = self.session.overlap_snapshot()
        return {
            "kv_stage_gets": snap["kv_stage_gets"],
            "kv_stage_hits": snap["kv_stage_hits"],
            "kv_stage_wait_s": snap["kv_stage_wait_seconds"],
        }

    @property
    def fetch_stats(self) -> dict:
        """Swapper counters — how well decode hides SSD latency.  After
        :meth:`close`, the final pre-teardown snapshot."""
        if self._closed:
            assert self._last_fetch is not None
            return dict(self._last_fetch)
        return self.session.swapper.stats.snapshot()

    @property
    def kv_overlap_stats(self) -> dict:
        """Staged-KV transfer counters (session lifetime): how often a
        decode step found its KV window already on device
        (``kv_stage_hits``/``kv_stage_gets``, staged under the previous
        block's compute) and how long it blocked when it had not
        (``kv_stage_wait_s``).  All zero under ``overlap="sync"``, where
        the gather + H2D run inline on the compute thread.  After
        :meth:`close`, the final pre-teardown snapshot."""
        if self._closed:
            assert self._last_overlap is not None
            return dict(self._last_overlap)
        return self._overlap_live()
