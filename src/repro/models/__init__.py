"""Model zoo: dense/GQA/MLA, MoE, Mamba, xLSTM, whisper enc-dec, VLM prefix.

See :mod:`repro.models.registry` for the uniform build interface.
"""

from .registry import (LONG_CONTEXT_WINDOW, ModelImpl, build,
                       shape_supported, variant_for_shape)
from . import transformer, whisper, layers, attention, moe, mamba, xlstm

__all__ = ["build", "ModelImpl", "variant_for_shape", "shape_supported",
           "LONG_CONTEXT_WINDOW", "transformer", "whisper", "layers",
           "attention", "moe", "mamba", "xlstm"]
