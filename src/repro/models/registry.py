"""Model registry: one uniform interface over every assigned architecture.

``build(cfg)`` returns a :class:`ModelImpl` bundling init / train-loss /
prefill / decode functions plus ``input_specs`` (ShapeDtypeStruct stand-ins,
no allocation) for each assigned input shape — the dry-run, smoke tests,
and launchers all go through this.

Decode semantics per family (DESIGN §4):
* attention families — KV cache (rolling window when sliding_window>0),
* MLA — compressed-latent cache,
* mamba/mlstm/slstm — constant-size recurrent state,
* whisper — decoder self-KV + precomputed cross-KV,
* ``long_500k`` on dense/MoE archs uses the sliding-window variant
  (window :data:`LONG_CONTEXT_WINDOW`), applied by :func:`variant_for_shape`.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, InputShape
from . import transformer as tfm
from . import whisper as whs

LONG_CONTEXT_WINDOW = 8192


def variant_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Config variant actually lowered for a given input shape."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm") \
            and not cfg.sliding_window:
        # sub-quadratic requirement: sliding-window variant of the dense arch
        return replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    if shape.name == "long_500k" and cfg.family == "hybrid" \
            and not cfg.sliding_window:
        # hybrid: mamba layers are native; window the sparse attention layers
        return replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason-if-not).  The documented skips from DESIGN §4."""
    if cfg.family == "audio" and shape.name == "long_500k":
        return False, ("whisper is an enc-dec audio model with an "
                       "architectural decoder cap (~448 tokens); no "
                       "sub-quadratic 500k-context variant exists")
    return True, ""


@dataclass
class ModelImpl:
    cfg: ModelConfig
    init_params: Callable          # (key) -> params
    loss_fn: Callable              # (params, batch) -> scalar
    prefill_fn: Callable           # (params, batch) -> logits
    init_cache: Callable           # (batch, cache_seq, dtype) -> cache
    decode_fn: Callable            # (params, cache, tokens, cache_len)
    input_specs: Callable          # (shape) -> batch dict of SDS

    def decode_args_specs(self, shape: InputShape, dtype=jnp.bfloat16):
        """(cache_specs, tokens_spec, cache_len_spec) for serve lowering."""
        cache = jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len, dtype))
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        cache_len = jax.ShapeDtypeStruct((), jnp.int32)
        return cache, tokens, cache_len


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _lm_input_specs(cfg: ModelConfig, shape: InputShape,
                    compute_dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), compute_dtype)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    if cfg.prefix_len:
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), compute_dtype)
        s = s - cfg.prefix_len      # image tokens count toward the context
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


def build(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
          remat: bool = True, unroll: bool = False, hint=None,
          bf16_logits: bool = False) -> ModelImpl:
    if cfg.family == "audio":
        def loss_fn(params, batch):
            return whs.whisper_loss(cfg, params, batch,
                                    compute_dtype=compute_dtype,
                                    unroll=unroll)

        def prefill_fn(params, batch):
            memory = whs.encode(cfg, params,
                                batch["frames"].astype(compute_dtype),
                                unroll=unroll)
            h = whs.decoder_forward(cfg, params, batch["tokens"], memory,
                                    compute_dtype, unroll=unroll)
            from .layers import lm_logits
            return lm_logits(h, params["embed"], transpose=True)

        return ModelImpl(
            cfg=cfg,
            init_params=lambda key: whs.init_whisper_params(key, cfg),
            loss_fn=loss_fn,
            prefill_fn=prefill_fn,
            init_cache=lambda b, s, dtype=jnp.bfloat16:
                whs.init_whisper_cache(cfg, b, s, dtype),
            decode_fn=lambda params, cache, tokens, cache_len:
                whs.whisper_decode_step(cfg, params, cache, tokens, cache_len,
                                        compute_dtype=compute_dtype,
                                        unroll=unroll),
            input_specs=lambda shape: _lm_input_specs(cfg, shape,
                                                      compute_dtype),
        )

    def loss_fn(params, batch):
        return tfm.lm_loss(cfg, params, batch, compute_dtype=compute_dtype,
                           remat=remat, unroll=unroll, hint=hint,
                           bf16_logits=bf16_logits)

    def prefill_fn(params, batch):
        h = tfm.embed_tokens(cfg, params, batch["tokens"], compute_dtype)
        prefix = 0
        if cfg.prefix_len:
            h = jnp.concatenate(
                [batch["image_embeds"].astype(compute_dtype), h], axis=1)
            prefix = cfg.prefix_len
        if hint is not None:
            h = hint(h)
        h, _ = tfm.forward(cfg, params, h, prefix_len=prefix, remat=remat,
                           unroll=unroll, hint=hint)
        logits = tfm.logits_fn(cfg, params, h)
        return logits.astype(jnp.bfloat16) if bf16_logits else logits

    return ModelImpl(
        cfg=cfg,
        init_params=lambda key: tfm.init_params(key, cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        init_cache=lambda b, s, dtype=jnp.bfloat16:
            tfm.init_cache(cfg, b, s, dtype),
        decode_fn=lambda params, cache, tokens, cache_len:
            tfm.decode_step(cfg, params, cache, tokens, cache_len,
                            compute_dtype=compute_dtype, unroll=unroll),
        input_specs=lambda shape: _lm_input_specs(cfg, shape, compute_dtype),
    )
