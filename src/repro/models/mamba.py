"""Mamba (S6 selective SSM) mixer, chunked-parallel for TPU.

The recurrence h_t = Ā_t h_{t-1} + B̄_t x_t is evaluated chunk-parallel:
``lax.scan`` over chunks carries the (B, d_inner, d_state) state, while an
associative scan runs inside each chunk — the TPU-idiomatic replacement for
the CUDA selective-scan kernel (DESIGN §2: rethought for VMEM/MXU rather
than ported).  Chunk boundaries are the only sequential dependency, so
activation residuals stay O(L/chunk · state) instead of O(L · state).

Decode carries (conv_state (B, K-1, d_inner), ssm_state (B, d_inner, d_state))
explicitly — the constant-memory property that makes long_500k native for
SSM/hybrid architectures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense


def _ssm_params(params, x, cfg):
    """Input-dependent Δ, B, C from x: (B, L, d_inner).

    Separate projections (not one packed w_x_proj) — packed-split sharding
    note in layers.gated_mlp applies."""
    dt = jax.nn.softplus(
        dense(dense(x, params["ssm.w_dt_in"]), params["ssm.w_dt"])
        + params["ssm.dt_bias"].astype(x.dtype))
    b_in = dense(x, params["ssm.w_b"])
    c_in = dense(x, params["ssm.w_c"])
    return dt, b_in, c_in                                # (B,L,di), (B,L,ds) x2


def _discretize(dt, b_in, x, a_log):
    """Ā = exp(Δ·A) (ZOH), B̄x = Δ·B·x."""
    a = -jnp.exp(a_log.astype(jnp.float32))              # (di, ds), negative
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)        # (...,di,ds)
    inp = (dt * x).astype(jnp.float32)[..., None] * \
        b_in.astype(jnp.float32)[..., None, :, :].swapaxes(-2, -2)
    return decay, inp


def causal_conv1d(x, w, *, state=None):
    """Depthwise causal conv, kernel K.  x: (B, L, C), w: (K, C).

    With ``state`` (B, K-1, C) it is a streaming update; returns
    (y, new_state).
    """
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)               # (B, L+K-1, C)
    wc = w.astype(x.dtype)
    y = sum(xp[:, i:i + x.shape[1], :] * wc[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad[:, :0]
    return y, new_state


def selective_scan(x, dt, b_in, c_in, a_log, d_skip, *, chunk: int,
                   h0=None):
    """Chunk-parallel selective scan.

    x, dt: (B, L, di); b_in, c_in: (B, L, ds); a_log: (di, ds); d_skip: (di,).
    Returns (y (B, L, di), h_final (B, di, ds)).
    """
    bsz, L, di = x.shape
    ds = b_in.shape[-1]
    chunk = min(chunk, L)
    if L % chunk:
        raise ValueError(f"seq len {L} not divisible by chunk {chunk}")
    nc = L // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))                        # (di, ds)

    # PERF (EXPERIMENTS.md §Perf, jamba iteration 1): the (B, L, di, ds)
    # discretized decay/input tensors are NEVER materialized over the full
    # sequence — Ā and B̄x are computed per chunk inside the (rematerialized)
    # scan body, so the live working set is (B, chunk, di, ds).  The
    # full-sequence formulation cost ~1.7 TiB/chip of XLA temps on
    # jamba-52b train_4k; this form is the TPU-VMEM-sized equivalent of the
    # CUDA selective-scan kernel's tiling.
    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc = to_chunks(x)
    dtc = to_chunks(dt)
    bc = to_chunks(b_in)
    cc = to_chunks(c_in)

    if h0 is None:
        h0 = jnp.zeros((bsz, di, ds), jnp.float32)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    def chunk_body(h, xs):
        x_i, dt_i, b_i, c_i = xs               # (B,chunk,di), ..., (B,chunk,ds)
        dt32 = dt_i.astype(jnp.float32)
        dch = jnp.exp(dt32[..., None] * a)     # (B,chunk,di,ds)
        ich = (dt32 * x_i.astype(jnp.float32))[..., None] * \
            b_i.astype(jnp.float32)[:, :, None, :]
        cum_a, cum_b = jax.lax.associative_scan(assoc, (dch, ich), axis=1)
        h_t = cum_a * h[:, None] + cum_b       # (B,chunk,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", h_t, c_i.astype(jnp.float32))
        return h_t[:, -1], y

    chunk_body = jax.checkpoint(chunk_body)
    h_final, ys = jax.lax.scan(chunk_body, h0, (xc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(bsz, L, di)
    y = y + d_skip.astype(jnp.float32) * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final


def mamba_mixer(params, x, cfg):
    """Full Mamba block mixer (train/prefill).  x: (B, L, D) -> (B, L, D)."""
    s = cfg.ssm
    xi = dense(x, params["ssm.w_in_x"])                  # (B,L,di)
    z = dense(x, params["ssm.w_in_z"])
    xi, _ = causal_conv1d(xi, params["ssm.conv_w"])
    xi = jax.nn.silu(xi)
    dt, b_in, c_in = _ssm_params(params, xi, cfg)
    y, _ = selective_scan(xi, dt, b_in, c_in, params["ssm.a_log"],
                          params["ssm.d_skip"], chunk=s.chunk)
    y = y * jax.nn.silu(z)
    return dense(y, params["ssm.w_out"])


def mamba_decode(params, x, cfg, cache):
    """One-token streaming update.  x: (B, 1, D).

    cache: {"conv": (B, K-1, di), "ssm": (B, di, ds)} -> (out, new_cache).
    """
    xi = dense(x, params["ssm.w_in_x"])
    z = dense(x, params["ssm.w_in_z"])
    xi, conv_state = causal_conv1d(xi, params["ssm.conv_w"],
                                   state=cache["conv"])
    xi = jax.nn.silu(xi)
    dt, b_in, c_in = _ssm_params(params, xi, cfg)
    a = -jnp.exp(params["ssm.a_log"].astype(jnp.float32))
    dt32 = dt[:, 0].astype(jnp.float32)                              # (B,di)
    decay = jnp.exp(dt32[..., None] * a)                             # (B,di,ds)
    inp = (dt32 * xi[:, 0].astype(jnp.float32))[..., None] * \
        b_in[:, 0].astype(jnp.float32)[:, None, :]
    h = cache["ssm"] * decay + inp
    y = jnp.einsum("bds,bs->bd", h, c_in[:, 0].astype(jnp.float32))
    y = y + params["ssm.d_skip"].astype(jnp.float32) * \
        xi[:, 0].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
    out = dense(y, params["ssm.w_out"])
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}


def init_mamba_params(key, cfg, dtype=jnp.float32):
    from .layers import fan_in_init
    s = cfg.ssm
    d, di, ds = cfg.d_model, s.d_inner(cfg.d_model), s.d_state
    dtr = s.dt_rank_for(d)
    keys = jax.random.split(key, 8)
    return {
        "ssm.w_in_x": fan_in_init(keys[0], (d, di), dtype),
        "ssm.w_in_z": fan_in_init(keys[5], (d, di), dtype),
        "ssm.conv_w": fan_in_init(keys[1], (s.conv_kernel, di), dtype),
        "ssm.w_dt_in": fan_in_init(keys[2], (di, dtr), dtype),
        "ssm.w_b": fan_in_init(keys[6], (di, ds), dtype),
        "ssm.w_c": fan_in_init(keys[7], (di, ds), dtype),
        "ssm.w_dt": fan_in_init(keys[3], (dtr, di), dtype),
        "ssm.dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "ssm.a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "ssm.d_skip": jnp.ones((di,), dtype),
        "ssm.w_out": fan_in_init(keys[4], (di, d), dtype),
    }
