"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Top-k softmax routing with an auxiliary load-balance loss; dispatch uses a
sort + scatter formulation (no (T, E, C) one-hot tensors), so it scales to
DeepSeek-V3's 256 experts at 64k tokens/device without materializing
terabyte masks.  Expert weights are stacked (E, ...) so the expert axis can
be sharded (expert parallelism over the ``model`` mesh axis -> XLA emits the
all-to-all the paper's MoE discussion anticipates).

Shared (always-on) experts, DeepSeek-style, run densely beside the routed
path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense


def router_topk(logits, top_k: int):
    """Softmax-then-top-k routing.

    Returns (weights (T, k) normalized over the chosen k, indices (T, k),
    aux load-balance loss).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    w, idx = jax.lax.top_k(probs, top_k)                          # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * mean_e(frac_tokens_e * mean_prob_e)
    n_experts = logits.shape[-1]
    assign = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], idx].add(1.0) / top_k
    aux = n_experts * jnp.mean(assign.mean(0) * probs.mean(0)) * top_k
    return w.astype(jnp.float32), idx, aux


def _positions_in_expert(flat_experts, n_tokens_k: int):
    """Rank of each (token, choice) within its expert, via sort."""
    order = jnp.argsort(flat_experts)                    # stable
    sorted_e = flat_experts[order]
    # position within run of equal expert ids
    run_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(n_tokens_k) - run_start
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    return pos


def moe_ffn(params, x, cfg, idx=None):
    """Routed expert FFN (+ shared experts).  x: (B, S, D) -> (B, S, D).

    params: moe.w_router (D, E), moe.w_gate/w_up (E, D, F) each,
    moe.w_down (E, F, D); optionally moe.shared_gate/up/down.
    Returns (out, aux_loss).

    ``idx`` (optional, (T, k) or (B, S, k) int32) pins the expert
    assignment instead of recomputing top-k — the expert-paging path
    passes the routing stage's choice so the host-side fetch decision and
    the expert compute agree *by construction* (weights are re-gathered
    from the softmax probabilities at those indices, which equals the
    top-k values bitwise when ``idx`` came from the same logits).
    """
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = dense(xf, params["moe.w_router"])
    if idx is None:
        w, idx, aux = router_topk(logits, e.top_k)        # (T,k) fp32, (T,k)
    else:
        idx = idx.reshape(t, e.top_k)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w = jnp.take_along_axis(probs, idx, axis=-1)      # == top_k values
        w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(
            jnp.float32)
        assign = jnp.zeros_like(probs).at[
            jnp.arange(t)[:, None], idx].add(1.0) / e.top_k
        aux = e.n_experts * jnp.mean(assign.mean(0) * probs.mean(0)) \
            * e.top_k

    capacity = int(max(e.top_k * t // e.n_experts * e.capacity_factor, 4))
    flat_e = idx.reshape(-1)                              # (T*k,)
    pos = _positions_in_expert(flat_e, t * e.top_k)       # (T*k,)
    keep = pos < capacity
    slot = jnp.where(keep, pos, 0)

    token_of = jnp.repeat(jnp.arange(t), e.top_k)
    # dispatch: (E, C, D) scatter of kept token activations
    dispatched = jnp.zeros((e.n_experts, capacity, d), x.dtype)
    contrib = jnp.where(keep[:, None], xf[token_of], 0).astype(x.dtype)
    dispatched = dispatched.at[flat_e, slot].add(contrib)

    # expert compute: gated MLP per expert, batched over E (gate and up
    # are separate tensors — see layers.gated_mlp on packed-split reshards)
    up = jnp.einsum("ecd,edf->ecf", dispatched,
                    params["moe.w_up"]).astype(x.dtype)
    gate = jnp.einsum("ecd,edf->ecf", dispatched,
                      params["moe.w_gate"]).astype(x.dtype)
    hid = jax.nn.silu(gate) * up
    out_e = jnp.einsum("ecf,efd->ecd", hid,
                       params["moe.w_down"]).astype(x.dtype)

    # combine: gather each choice's expert output, weight, sum over k
    gathered = out_e[flat_e, slot]                        # (T*k, D)
    wk = (w.reshape(-1) * keep).astype(x.dtype)
    yf = jnp.zeros((t, d), x.dtype).at[token_of].add(gathered * wk[:, None])

    if "moe.shared_up" in params:
        u = jnp.einsum("td,df->tf", xf,
                       params["moe.shared_up"]).astype(x.dtype)
        g = jnp.einsum("td,df->tf", xf,
                       params["moe.shared_gate"]).astype(x.dtype)
        yf = yf + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u,
                             params["moe.shared_down"]).astype(x.dtype)

    return yf.reshape(b, s, d), aux * e.router_aux_weight
