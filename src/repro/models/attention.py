"""Attention variants: GQA/MQA (optionally qk-norm), sliding-window, MLA.

All functions take activations shaped (batch, seq, ...) and weights packed in
plain dicts.  Decode paths consume/produce explicit KV caches so `serve_step`
can be jitted with the cache as a donated argument.

Sliding-window attention is the sub-quadratic variant used for the
``long_500k`` shape on dense/MoE architectures (see DESIGN §4): during
prefill the score matrix is banded (O(S·W)); during decode the cache is a
rolling window of W entries.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, rms_norm

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B, S, KH, D) -> (B, S, KH*n_rep, D) for GQA."""
    if n_rep == 1:
        return k
    b, s, kh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, d)).reshape(
        b, s, kh * n_rep, d)


def attention_scores(q, k, v, *, causal: bool, window: int = 0,
                     q_offset=0, prefix_len: int = 0):
    """Plain softmax attention over full (or banded) scores.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D).  ``q_offset`` is the absolute
    position of q[0] (decode: cache length).  ``prefix_len`` marks a
    bidirectional prefix (PaliGemma): positions < prefix_len attend freely.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d)
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = k_pos[None, :] <= q_pos[:, None]
        if prefix_len:
            # bidirectional prefix: queries in the prefix see the whole prefix
            in_prefix = (q_pos[:, None] < prefix_len) & (k_pos[None, :] < prefix_len)
            mask = mask | in_prefix
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.astype(q.dtype) if out.dtype != q.dtype else out


# ---------------------------------------------------------------------------
# GQA projection + attention (train/prefill and decode)
# ---------------------------------------------------------------------------

def gqa_project_qkv(params, x, cfg, positions):
    """Project and rope q/k/v.  Returns (q, k, v) with heads unfolded."""
    b, s, _ = x.shape
    q = dense(x, params["attn.w_q"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = dense(x, params["attn.w_k"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = dense(x, params["attn.w_v"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["attn.q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["attn.k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(params, x, cfg, *, causal=True, window=None,
                  prefix_len: int = 0):
    """Full-sequence GQA attention (train / prefill)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = gqa_project_qkv(params, x, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    w = cfg.sliding_window if window is None else window
    out = attention_scores(q, k, v, causal=causal, window=w,
                           prefix_len=prefix_len)
    return dense(out.reshape(b, s, -1), params["attn.w_o"])


def gqa_decode(params, x, cfg, cache, cache_len):
    """One-token decode against a KV cache.

    cache: dict(k=(B, S_max, KH, D), v=...); ``cache_len`` — tokens already
    cached (the new token is written at index cache_len % S_max for
    sliding-window caches, plain cache_len otherwise).
    Returns (out, new_cache).
    """
    b, one, _ = x.shape
    assert one == 1
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = gqa_project_qkv(params, x, cfg, positions)
    s_max = cache["k"].shape[1]
    slot = (cache_len % s_max) if cfg.sliding_window else cache_len

    # Attention reads the PRE-UPDATE cache and merges the new token's
    # contribution analytically (two-term softmax).  The updated cache is
    # produced only as an OUTPUT: keeping the dynamic-update-slice result
    # out of the attention dataflow lets SPMD keep the seq-sharded cache
    # local instead of all-gathering it per layer per token (§Perf decode
    # iteration 3 — the gather was ~77 GB/chip/token on qwen3-4b).
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kk = _repeat_kv(cache["k"], n_rep)
    vv = _repeat_kv(cache["v"], n_rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) / math.sqrt(cfg.head_dim)
    # valid OLD entries: first min(cache_len, s_max) slots (new token is
    # handled separately below; for rolling caches the slot being
    # overwritten is also stale)
    n_valid = jnp.minimum(cache_len, s_max)
    idx = jnp.arange(s_max)[None, None, None, :]
    valid = idx < n_valid
    if cfg.sliding_window:
        valid = valid & (idx != slot)
    scores = jnp.where(valid, scores, NEG_INF)

    # two-term online-softmax merge with the new token's self-attention
    s_new = (jnp.einsum("bqhd,bqhd->bhq", q, _repeat_kv(k_new, n_rep))
             / math.sqrt(cfg.head_dim)).astype(jnp.float32)[..., None]
    m_old = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m_old, s_new)
    p_old = jnp.exp(scores - m)
    p_new = jnp.exp(s_new - m)                           # (B,H,1,1)
    denom = p_old.sum(-1, keepdims=True) + p_new
    out_old = jnp.einsum("bhqk,bkhd->bqhd", (p_old / denom).astype(q.dtype),
                         vv)
    w_new = (p_new / denom)[:, :, 0].astype(q.dtype)     # (B,H,1)
    out_new = w_new.transpose(0, 2, 1)[..., None] * _repeat_kv(v_new, n_rep)
    out = (out_old + out_new).astype(x.dtype)
    out = dense(out.reshape(b, 1, -1), params["attn.w_o"])

    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# GQA with an explicit fixed-shape cache (SSD-offloaded cached decode)
# ---------------------------------------------------------------------------
#
# The offloaded serve path keeps per-layer KV in *host* pool slots and
# streams a fixed time-bucket to the device per step, so these functions
# take the cache as plain (B, S_bucket, KH, D) arrays plus a traced
# ``cache_len`` scalar — no in-graph cache update, no donation.  Entries at
# positions >= cache_len are garbage (pool slots are recycled memory) and
# are masked out exactly, so results match the uncached full-prefix pass.

def gqa_prefill(params, x, cfg, *, window=None):
    """Full-sequence attention that also returns the pre-repeat K/V to
    cache.  x may be right-padded past the true prompt length: causal
    masking keeps padded keys out of every valid query's softmax."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = gqa_project_qkv(params, x, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    w = cfg.sliding_window if window is None else window
    out = attention_scores(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep),
                           causal=True, window=w)
    return dense(out.reshape(b, s, -1), params["attn.w_o"]), k, v


def gqa_step(params, x, cfg, k_cache, v_cache, cache_len, *, window=None,
             chunk=None):
    """One-token attention against a host-fed cache slice.

    x: (B, 1, D); k_cache/v_cache: (B, S_bucket, KH, D) with positions
    < cache_len valid; cache_len: traced int scalar, or a traced (B,)
    vector of per-row lengths (continuous batching: each batch slot sits
    at its own position; a row with length 0 attends only to itself).
    Returns (out, k_new, v_new) — the caller appends the (B, 1, KH, D)
    slices to the host cache at each row's cache_len position.

    ``chunk`` (static) makes the softmax/PV reductions **extent-
    invariant**: the cache axis is processed in fixed-size chunks on an
    absolute position grid and the partials combined in a fixed order, so
    a row's output is bitwise identical no matter how far S_bucket
    extends past its own length.  Without chunking, XLA regroups the
    reductions when S_bucket changes and the same row rounds differently
    at different extents — enough to flip a near-tie greedy argmax, which
    breaks continuous batching's output-equals-solo-decode contract
    whenever a co-lane pushes the shared extent across a bucket boundary.
    Masked positions score ``NEG_INF`` and contribute exactly 0.0 to
    every partial, so a fully-masked chunk is a bitwise no-op; callers
    must keep ``chunk`` constant and a divisor of every extent step (the
    serving session passes its time-bucket size).  ``None`` keeps the
    whole axis as one chunk.
    """
    b, one, _ = x.shape
    cl = jnp.asarray(cache_len, dtype=jnp.int32)
    cl_col = cl.reshape((-1, 1))     # scalar -> (1,1); per-row -> (B,1)
    positions = jnp.broadcast_to(cl_col, (b, 1))
    q, k_new, v_new = gqa_project_qkv(params, x, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    s_bucket = k_cache.shape[1]
    c = s_bucket if chunk is None else int(chunk)
    w = cfg.sliding_window if window is None else window
    scale = math.sqrt(cfg.head_dim)

    # per-chunk masked scores, fixed (B, H, 1, <=c) shapes on the absolute
    # position grid [0, c), [c, 2c), ... — identical at every extent
    score_chunks, v_chunks = [], []
    for lo in range(0, s_bucket, c):
        hi = min(lo + c, s_bucket)
        kk_c = _repeat_kv(k_cache[:, lo:hi], n_rep)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk_c,
                        preferred_element_type=jnp.float32) / scale
        idx = jnp.arange(lo, hi)[None, :]
        valid = idx < cl_col                          # (1 or B, hi-lo)
        if w:
            valid = valid & (idx > cl_col - w)
        score_chunks.append(
            jnp.where(valid[:, None, None, :], sc, NEG_INF))
        v_chunks.append(_repeat_kv(v_cache[:, lo:hi], n_rep))
    # the new token attends to itself at position cache_len (always in
    # window): its score anchors the max, so every row's m is finite
    s_new = (jnp.einsum("bqhd,bkhd->bhqk", q, _repeat_kv(k_new, n_rep),
                        preferred_element_type=jnp.float32) / scale)

    # two-pass softmax with fixed combine order: max, then denominator —
    # a fully-masked chunk adds exp(NEG_INF - m) == 0.0 exactly
    m = s_new
    for sc in score_chunks:
        m = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
    denom = jnp.exp(s_new - m)
    for sc in score_chunks:
        denom = denom + jnp.sum(jnp.exp(sc - m), axis=-1, keepdims=True)

    out = (jnp.exp(s_new - m) / denom).astype(x.dtype) * \
        _repeat_kv(v_new, n_rep).transpose(0, 2, 1, 3)    # (B,H,1,D)
    for sc, vv_c in zip(score_chunks, v_chunks, strict=True):
        p_c = (jnp.exp(sc - m) / denom).astype(x.dtype)
        out = out + jnp.einsum("bhqk,bkhd->bhqd", p_c, vv_c)
    out = out.transpose(0, 2, 1, 3).astype(x.dtype)       # (B,1,H,D)
    out = dense(out.reshape(b, 1, -1), params["attn.w_o"])
    return out, k_new, v_new


def gqa_verify(params, x, cfg, k_cache, v_cache, cache_len, *, window=None,
               chunk=None):
    """k-query attention for speculative-decode verification.

    x: (B, K, D) — a window of K draft tokens per row, query j sitting at
    absolute position ``cache_len + j``; k_cache/v_cache: (B, S_bucket,
    KH, D) with positions < cache_len valid (same host-fed slice
    ``gqa_step`` reads — the window's K/V have NOT been appended yet);
    cache_len: traced int scalar or (B,) vector.  Returns (out, k_new,
    v_new) with k_new/v_new shaped (B, K, KH, D) for the caller to append.

    The contract is stronger than "mathematically causal": position j's
    output must be **bitwise identical** to what K sequential ``gqa_step``
    calls would produce (append token 0, step token 1, ...), because
    greedy spec-decode only equals plain greedy decode if the verify
    logits reproduce the step logits exactly — a one-ulp difference flips
    near-tie argmaxes at bf16 (the same failure mode chunking already
    guards against, see ``gqa_step``).  So the kernel replays the exact
    reduction structure of the sequential step:

    * the window's k_new/v_new are **merged into the chunk grid at their
      absolute positions** [cache_len, cache_len+K) — exactly where the
      sequential appends would have put them — instead of being treated
      as a separate score block;
    * query j masks the merged chunks with ``idx < cache_len + j`` (its
      own prefix; later window positions and cache garbage score
      NEG_INF and contribute exactly 0.0);
    * query j's self-attention term anchors the running max first, then
      chunks combine in the same fixed order as ``gqa_step``.

    With chunk equal to the decode bucket this is extent-invariant like
    ``gqa_step``, and K=1 degenerates to the sequential step bitwise.
    """
    b, kq, _ = x.shape
    cl = jnp.asarray(cache_len, dtype=jnp.int32)
    cl_col = cl.reshape((-1, 1))     # scalar -> (1,1); per-row -> (B,1)
    positions = jnp.broadcast_to(cl_col + jnp.arange(kq)[None, :], (b, kq))
    q, k_new, v_new = gqa_project_qkv(params, x, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    s_bucket = k_cache.shape[1]
    c = s_bucket if chunk is None else int(chunk)
    w = cfg.sliding_window if window is None else window
    scale = math.sqrt(cfg.head_dim)

    # scatter the window K/V onto the absolute position grid: position
    # cache_len + r takes window row r, everything else keeps the cache
    idx_all = jnp.arange(s_bucket)
    rel = idx_all[None, :] - cl_col                       # (1 or B, S)
    in_win = ((rel >= 0) & (rel < kq))
    gidx = jnp.broadcast_to(jnp.clip(rel, 0, kq - 1),
                            (b, s_bucket))[:, :, None, None]
    in_win = jnp.broadcast_to(in_win, (b, s_bucket))[:, :, None, None]
    merged_k = jnp.where(in_win, jnp.take_along_axis(k_new, gidx, axis=1),
                         k_cache)
    merged_v = jnp.where(in_win, jnp.take_along_axis(v_new, gidx, axis=1),
                         v_cache)

    # per-query valid prefix: query j sees positions < cache_len + j
    limit = cl_col[:, :, None] + jnp.arange(kq)[None, :, None]  # (1orB,K,1)

    score_chunks, v_chunks = [], []
    for lo in range(0, s_bucket, c):
        hi = min(lo + c, s_bucket)
        kk_c = _repeat_kv(merged_k[:, lo:hi], n_rep)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk_c,
                        preferred_element_type=jnp.float32) / scale
        idx = jnp.arange(lo, hi)[None, None, :]
        valid = idx < limit                          # (1 or B, K, hi-lo)
        if w:
            valid = valid & (idx > limit - w)
        score_chunks.append(jnp.where(valid[:, None, :, :], sc, NEG_INF))
        v_chunks.append(_repeat_kv(merged_v[:, lo:hi], n_rep))

    # each query attends to itself at position cache_len + j (always in
    # window): its score anchors the max, so every row's m is finite
    s_self = (jnp.einsum("bqhd,bqhd->bhq", q, _repeat_kv(k_new, n_rep),
                         preferred_element_type=jnp.float32)
              / scale)[..., None]                    # (B, H, K, 1)

    m = s_self
    for sc in score_chunks:
        m = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
    denom = jnp.exp(s_self - m)
    for sc in score_chunks:
        denom = denom + jnp.sum(jnp.exp(sc - m), axis=-1, keepdims=True)

    out = (jnp.exp(s_self - m) / denom).astype(x.dtype) * \
        _repeat_kv(v_new, n_rep).transpose(0, 2, 1, 3)   # (B,H,K,D)
    for sc, vv_c in zip(score_chunks, v_chunks, strict=True):
        p_c = (jnp.exp(sc - m) / denom).astype(x.dtype)
        out = out + jnp.einsum("bhqk,bkhd->bhqd", p_c, vv_c)
    out = out.transpose(0, 2, 1, 3).astype(x.dtype)      # (B,K,H,D)
    out = dense(out.reshape(b, kq, -1), params["attn.w_o"])
    return out, k_new, v_new


# ---------------------------------------------------------------------------
# MLA: DeepSeek-V3 multi-head latent attention
# ---------------------------------------------------------------------------

def mla_project_q(params, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    q_lat = dense(x, params["attn.w_dq"])                       # (B,S,q_rank)
    if "attn.q_lat_norm" in params:
        q_lat = rms_norm(q_lat, params["attn.q_lat_norm"], cfg.rms_eps)
    q = dense(q_lat, params["attn.w_uq"]).reshape(
        b, s, cfg.n_heads, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)

def mla_compress_kv(params, x, cfg, positions):
    """Returns the cached latent: (c_kv, k_rope)."""
    m = cfg.mla
    ckv = dense(x, params["attn.w_dkv"])                        # (B,S,rank+rope)
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]             # shared head
    return c_kv, k_rope

def mla_expand_kv(params, c_kv, k_rope, cfg):
    m = cfg.mla
    b, s, _ = c_kv.shape
    if "attn.kv_lat_norm" in params:
        c_kv = rms_norm(c_kv, params["attn.kv_lat_norm"], cfg.rms_eps)
    kv = dense(c_kv, params["attn.w_ukv"]).reshape(
        b, s, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (b, s, cfg.n_heads, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v

def mla_attention(params, x, cfg, *, causal=True, window=None):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q = mla_project_q(params, x, cfg, positions)
    c_kv, k_rope = mla_compress_kv(params, x, cfg, positions)
    k, v = mla_expand_kv(params, c_kv, k_rope, cfg)
    w = cfg.sliding_window if window is None else window
    out = attention_scores(q, k, v, causal=causal, window=w)
    return dense(out.reshape(b, s, -1), params["attn.w_o"])

def mla_decode(params, x, cfg, cache, cache_len):
    """Decode with the compressed-latent cache (B, S_max, rank+rope)."""
    m = cfg.mla
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q = mla_project_q(params, x, cfg, positions)
    c_new, krope_new = mla_compress_kv(params, x, cfg, positions)
    packed_new = jnp.concatenate([c_new, krope_new], axis=-1)
    s_max = cache["ckv"].shape[1]
    slot = (cache_len % s_max) if cfg.sliding_window else cache_len
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], packed_new.astype(cache["ckv"].dtype), (0, slot, 0))
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    k, v = mla_expand_kv(params, c_kv, k_rope, cfg)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    n_valid = jnp.minimum(cache_len + 1, s_max)
    valid = jnp.arange(s_max)[None, None, None, :] < n_valid
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).astype(x.dtype)
    out = dense(out.reshape(b, 1, -1), params["attn.w_o"])
    return out, {"ckv": ckv}


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(params, x, memory, cfg):
    """Decoder-to-encoder attention; no rope, no mask."""
    b, s, _ = x.shape
    sm = memory.shape[1]
    q = dense(x, params["xattn.w_q"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = dense(memory, params["xattn.w_k"]).reshape(b, sm, cfg.n_kv_heads,
                                                   cfg.head_dim)
    v = dense(memory, params["xattn.w_v"]).reshape(b, sm, cfg.n_kv_heads,
                                                   cfg.head_dim)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    out = attention_scores(q, k, v, causal=False)
    return dense(out.reshape(b, s, -1), params["xattn.w_o"])
