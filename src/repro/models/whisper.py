"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the assignment, the mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` provides precomputed frame embeddings (B, encoder_seq, D).
This module implements everything downstream: sinusoidal positions, the
bidirectional encoder stack, and the causal decoder with cross-attention,
all reusing the shared attention/MLP primitives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import cross_attention, gqa_attention, gqa_decode
from .layers import (cross_entropy, dense, embed_lookup, fan_in_init,
                     gated_mlp, lm_logits, rms_norm, sinusoidal_positions,
                     trunc_normal)


def _attn_params(key, cfg, prefix="attn"):
    k = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        f"{prefix}.w_q": fan_in_init(k[0], (d, cfg.q_dim)),
        f"{prefix}.w_k": fan_in_init(k[1], (d, cfg.kv_dim)),
        f"{prefix}.w_v": fan_in_init(k[2], (d, cfg.kv_dim)),
        f"{prefix}.w_o": fan_in_init(k[3], (cfg.q_dim, d)),
    }


def _ffn_params(key, cfg):
    k1, k2 = jax.random.split(key)
    up_mult = 2 if cfg.gated_act in ("swiglu", "geglu") else 1
    return {"ffn.w_up": fan_in_init(k1, (cfg.d_model, up_mult * cfg.d_ff)),
            "ffn.w_down": fan_in_init(k2, (cfg.d_ff, cfg.d_model))}


def init_whisper_params(key, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 6)

    def enc_layer(k):
        a, b, c = jax.random.split(k, 3)
        return {"norm_mixer": jnp.zeros((cfg.d_model,)),
                "norm_ffn": jnp.zeros((cfg.d_model,)),
                **_attn_params(a, cfg), **_ffn_params(b, cfg)}

    def dec_layer(k):
        a, b, c = jax.random.split(k, 3)
        return {"norm_mixer": jnp.zeros((cfg.d_model,)),
                "norm_xattn": jnp.zeros((cfg.d_model,)),
                "norm_ffn": jnp.zeros((cfg.d_model,)),
                **_attn_params(a, cfg),
                **_attn_params(b, cfg, prefix="xattn"),
                **_ffn_params(c, cfg)}

    enc_keys = jax.random.split(keys[0], cfg.encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "embed": trunc_normal(keys[2], (cfg.vocab, cfg.d_model)),
        "enc_final_norm": jnp.zeros((cfg.d_model,)),
        "final_norm": jnp.zeros((cfg.d_model,)),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
    }


def encode(cfg: ModelConfig, params, frames, *, unroll: bool = False):
    """frames: (B, T_enc, D) stub embeddings -> encoder memory."""
    t = frames.shape[1]
    pos = jnp.asarray(sinusoidal_positions(t, cfg.d_model), frames.dtype)
    h = frames + pos[None]

    def body(h, lp):
        hn = rms_norm(h, lp["norm_mixer"], cfg.rms_eps)
        h = h + gqa_attention(lp, hn, cfg, causal=False)
        hn = rms_norm(h, lp["norm_ffn"], cfg.rms_eps)
        h = h + gated_mlp(hn, lp["ffn.w_up"], lp["ffn.w_down"], cfg.gated_act)
        return h, None

    ckpt = jax.checkpoint(body)
    if unroll:
        for g in range(cfg.encoder_layers):
            h, _ = ckpt(h, jax.tree.map(lambda a, g=g: a[g],
                                        params["enc_layers"]))
    else:
        h, _ = jax.lax.scan(ckpt, h, params["enc_layers"])
    return rms_norm(h, params["enc_final_norm"], cfg.rms_eps)


def _dec_layer(cfg, lp, h, memory):
    hn = rms_norm(h, lp["norm_mixer"], cfg.rms_eps)
    h = h + gqa_attention(lp, hn, cfg, causal=True)
    hn = rms_norm(h, lp["norm_xattn"], cfg.rms_eps)
    h = h + cross_attention(lp, hn, memory, cfg)
    hn = rms_norm(h, lp["norm_ffn"], cfg.rms_eps)
    return h + gated_mlp(hn, lp["ffn.w_up"], lp["ffn.w_down"], cfg.gated_act)


def decoder_forward(cfg: ModelConfig, params, tokens, memory,
                    compute_dtype=jnp.bfloat16, *, unroll: bool = False):
    s = tokens.shape[1]
    pos = jnp.asarray(sinusoidal_positions(s, cfg.d_model), compute_dtype)
    h = embed_lookup(params["embed"], tokens).astype(compute_dtype) + pos[None]

    def body(h, lp):
        return _dec_layer(cfg, lp, h, memory), None

    ckpt = jax.checkpoint(body)
    if unroll:
        for g in range(cfg.n_layers):
            h, _ = ckpt(h, jax.tree.map(lambda a, g=g: a[g],
                                        params["dec_layers"]))
    else:
        h, _ = jax.lax.scan(ckpt, h, params["dec_layers"])
    return rms_norm(h, params["final_norm"], cfg.rms_eps)


def whisper_loss(cfg: ModelConfig, params, batch, *,
                 compute_dtype=jnp.bfloat16, unroll: bool = False):
    """batch: frames (B, T_enc, D), tokens (B, S), labels (B, S)."""
    memory = encode(cfg, params, batch["frames"].astype(compute_dtype),
                    unroll=unroll)
    h = decoder_forward(cfg, params, batch["tokens"], memory, compute_dtype,
                        unroll=unroll)
    logits = lm_logits(h, params["embed"], transpose=True)
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_whisper_cache(cfg: ModelConfig, batch: int, cache_seq: int,
                       dtype=jnp.bfloat16):
    """Self-attn KV caches (per decoder layer) + precomputed cross K/V."""
    kv = (cfg.n_layers, batch, cache_seq, cfg.n_kv_heads, cfg.head_dim)
    xkv = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype)}


def prefill_cross_cache(cfg, params, memory, cache):
    """Fill the cross-attention K/V from encoder memory (once per request)."""
    def one(lp):
        b, sm = memory.shape[:2]
        k = dense(memory, lp["xattn.w_k"]).reshape(b, sm, cfg.n_kv_heads,
                                                   cfg.head_dim)
        v = dense(memory, lp["xattn.w_v"]).reshape(b, sm, cfg.n_kv_heads,
                                                   cfg.head_dim)
        return k, v

    xk, xv = jax.vmap(one)(params["dec_layers"])
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


def whisper_decode_step(cfg: ModelConfig, params, cache, tokens, cache_len,
                        *, compute_dtype=jnp.bfloat16, unroll: bool = False):
    """One decoder token against self-KV + cross-KV caches."""
    from .attention import _repeat_kv, attention_scores
    b = tokens.shape[0]
    pos_table = jnp.asarray(
        sinusoidal_positions(cache["k"].shape[2] + 1, cfg.d_model),
        compute_dtype)
    h = embed_lookup(params["embed"], tokens).astype(compute_dtype)
    h = h + jax.lax.dynamic_slice_in_dim(pos_table, cache_len, 1)[None]

    def body(h, xs):
        lp, k_c, v_c, xk, xv = xs
        hn = rms_norm(h, lp["norm_mixer"], cfg.rms_eps)
        mix, new_kv = gqa_decode(lp, hn, cfg, {"k": k_c, "v": v_c}, cache_len)
        h = h + mix
        hn = rms_norm(h, lp["norm_xattn"], cfg.rms_eps)
        q = dense(hn, lp["xattn.w_q"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        out = attention_scores(q, _repeat_kv(xk, n_rep),
                               _repeat_kv(xv, n_rep), causal=False)
        h = h + dense(out.reshape(b, 1, -1), lp["xattn.w_o"])
        hn = rms_norm(h, lp["norm_ffn"], cfg.rms_eps)
        h = h + gated_mlp(hn, lp["ffn.w_up"], lp["ffn.w_down"], cfg.gated_act)
        return h, (new_kv["k"], new_kv["v"])

    xs_all = (params["dec_layers"], cache["k"], cache["v"],
              cache["xk"], cache["xv"])
    if unroll:
        new_k, new_v = cache["k"], cache["v"]
        for g in range(cfg.n_layers):
            h, (nk, nv) = body(h, jax.tree.map(lambda a, g=g: a[g], xs_all))
            # layer-axis write-back (a stack would gather sharded caches)
            new_k = new_k.at[g].set(nk)
            new_v = new_v.at[g].set(nv)
    else:
        h, (new_k, new_v) = jax.lax.scan(body, h, xs_all)
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = lm_logits(h, params["embed"], transpose=True)
    return logits, {**cache, "k": new_k, "v": new_v}
