"""Shared neural-net primitives (pure jnp; no framework)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight, eps: float = 1e-6):
    """RMSNorm with fp32 statistics but dtype-preserving elementwise math.

    Only the (…, 1) inverse-RMS is computed in fp32; the (B, S, D)-sized
    multiply stays in x.dtype so backward cotangents stay 16-bit
    (EXPERIMENTS.md §Perf iteration 3: full-size fp32 internals here were a
    top source of fp32 activation collectives)."""
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
                          + eps)
    return x * scale.astype(x.dtype) * (1.0 + weight).astype(x.dtype)


def dense(x, w):
    """x @ w.

    With 16-bit operands the dot stays 16-bit end to end (the TPU MXU
    accumulates fp32 internally for bf16 dots); an explicit
    preferred_element_type=f32 + cast pair would force fp32 COTANGENTS in
    the backward pass — measured as 2x activation-collective volume in the
    gemma-7b train HLO (EXPERIMENTS.md §Perf iteration 2).  Mixed-precision
    inputs still promote per jnp rules.
    """
    out = jnp.einsum("...i,io->...o", x, w)
    return out.astype(x.dtype) if out.dtype != x.dtype else out


def gated_mlp(x, w_up, w_down, kind: str, w_gate=None):
    """SwiGLU / GeGLU / plain-GELU MLP.

    Gate and up projections are SEPARATE tensors (not packed [gate; up]):
    splitting a packed tensor along the tensor-parallel-sharded output dim
    misaligns shards and forces an all-to-all per layer (§Perf iteration 4
    — measured as the dominant activation collective in gemma-7b train).
    """
    h = dense(x, w_up)
    if kind in ("swiglu", "geglu"):
        gate = dense(x, w_gate)
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        h = act * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown activation {kind!r}")
    return dense(h, w_down)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq).

    Angles/cos/sin in fp32, rotation applied in x.dtype (16-bit cotangents;
    see rms_norm note)."""
    head_dim = x.shape[-1]
    inv_freq = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (.., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def sinusoidal_positions(n_pos: int, dim: int):
    """Whisper-style fixed sinusoidal embeddings."""
    log_timescale = math.log(10_000.0) / (dim // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(dim // 2))
    scaled = np.arange(n_pos)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1).astype(
        np.float32)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def embed_lookup(table, tokens, *, scale: bool = False):
    out = jnp.take(table, tokens, axis=0)
    if scale:  # gemma multiplies by sqrt(d_model)
        out = out * jnp.asarray(math.sqrt(table.shape[1]), out.dtype)
    return out


def lm_logits(h, table_or_head, *, transpose: bool = False):
    """Final projection; ``transpose`` for tied embeddings (vocab, d).

    The dot runs in the activation dtype and is upcast AFTER — a
    preferred_element_type=f32 dot here seeds an fp32 cotangent that the
    dot transpose then propagates through the ENTIRE backward pass,
    doubling every activation collective (§Perf iteration 3: this one line
    was the root cause).  CE still reduces in fp32 over the upcast logits.
    """
    w = table_or_head.T if transpose else table_or_head
    return jnp.einsum("...d,dv->...v", h, w).astype(jnp.float32)


def cross_entropy(logits, labels, *, mask=None):
    """Mean token-level CE in fp32.  labels == -100 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) if mask is None else mask
    safe_labels = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_labels[..., None],
                               axis=-1).squeeze(-1)
    nll = (logz - gold) * valid.astype(jnp.float32)
    return nll.sum() / jnp.maximum(valid.sum().astype(jnp.float32), 1.0)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, std: float = 0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def fan_in_init(key, shape, dtype=jnp.float32):
    """1/sqrt(fan_in) trunc-normal; fan-in is the second-to-last axis so
    stacked weights (experts (E, in, out), per-head (H, in, out)) scale by
    their true contraction dim."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
