"""xLSTM blocks: chunk-parallel mLSTM + sequential sLSTM [arXiv:2405.04517].

mLSTM is a matrix-memory linear-attention recurrence

    C_t = f_t C_{t-1} + i_t k_t v_t^T,    n_t = f_t n_{t-1} + i_t k_t,
    y_t = (q_t^T C_t) / max(|q_t^T n_t|, 1)

evaluated chunkwise: within a chunk, the decay products form a banded
matrix D_{ts} = exp(logcum_f_t − logcum_f_s)·i_s applied to q·kᵀ (a masked
attention matmul — MXU-friendly); across chunks a ``lax.scan`` carries the
(heads, d_k, d_v) matrix state.  This is the TPU-native replacement for the
paper's fused CUDA kernels.

sLSTM has genuine recurrent (h_{t-1}-dependent) gating, so it runs as a
``lax.scan`` over time — cheap because xLSTM-1.3b places only one sLSTM
block per 8.

Simplifications vs the release (noted in DESIGN.md): sigmoid input gate
(instead of exp with stabilizer state) and headwise RMS output norm without
the learned output gate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, fan_in_init, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_mixer(params, x, cfg, *, state=None, return_state=False):
    """x: (B, L, D) -> (B, L, D).  state: (C (B,H,dk,dv), n (B,H,dk))."""
    s = cfg.ssm
    b, L, d = x.shape
    nh = cfg.n_heads
    di = s.d_inner(d)
    dk = di // nh

    q = dense(x, params["mlstm.w_q"]).reshape(b, L, nh, dk)
    k = dense(x, params["mlstm.w_k"]).reshape(b, L, nh, dk) / \
        jnp.sqrt(jnp.asarray(dk, x.dtype))
    v = dense(x, params["mlstm.w_v"]).reshape(b, L, nh, dk)

    gates = dense(x, params["mlstm.w_gates"]).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gates[..., :nh])                       # (B,L,H)
    f_gate = jax.nn.sigmoid(gates[..., nh:] + 4.0)                 # long memory

    chunk = min(s.chunk, L)
    if L % chunk:
        raise ValueError(f"L={L} % chunk={chunk}")
    nc = L // chunk

    def split_c(a):
        return a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = split_c(q), split_c(k), split_c(v)          # (nc,B,c,H,dk)
    ic, fc = split_c(i_gate), split_c(f_gate)                # (nc,B,c,H)

    if state is None:
        c0 = jnp.zeros((b, nh, dk, dk), jnp.float32)
        n0 = jnp.zeros((b, nh, dk), jnp.float32)
    else:
        c0, n0 = state

    def chunk_body(carry, xs):
        c_state, n_state = carry
        qs, ks, vs, isg, fsg = xs
        logf = jnp.log(fsg + 1e-9)                           # (B,c,H)
        cum = jnp.cumsum(logf, axis=1)
        # inter-chunk: q_t sees decayed initial state
        q32 = qs.astype(jnp.float32)
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "bchk,bhkv->bchv", q32, c_state)
        n_inter = jnp.exp(cum)[..., None] * n_state[:, None]
        # intra-chunk: banded decay attention
        dmat = cum[:, :, None, :] - cum[:, None, :, :]       # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(tri[None, :, :, None], jnp.exp(dmat), 0.0)
        w = w * isg[:, None, :, :]                           # i_s weighting
        scores = jnp.einsum("bthk,bshk->btsh", q32, ks.astype(jnp.float32))
        aw = scores * w
        y_intra = jnp.einsum("btsh,bshv->bthv", aw, vs.astype(jnp.float32))
        n_intra = jnp.einsum("btsh,bshk->bthk", w, ks.astype(jnp.float32))
        # state update to end of chunk
        tail = cum[:, -1:, :] - cum                          # decay to chunk end
        wk = (jnp.exp(tail) * isg)[..., None] * ks.astype(jnp.float32)
        c_new = jnp.exp(cum[:, -1])[..., None, None] * c_state + \
            jnp.einsum("bchk,bchv->bhkv", wk, vs.astype(jnp.float32))
        n_new = jnp.exp(cum[:, -1])[..., None] * n_state + wk.sum(axis=1)
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bchk,bchk->bch", q32, n_inter + n_intra)),
            1.0)[..., None]
        y = (y_inter + y_intra) / denom
        return (c_new, n_new), y

    chunk_body = jax.checkpoint(chunk_body)
    (c_f, n_f), ys = jax.lax.scan(chunk_body, (c0, n0), (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(b, L, nh, dk).astype(x.dtype)
    y = rms_norm(y, params["mlstm.out_norm"], cfg.rms_eps)
    out = dense(y.reshape(b, L, di), params["mlstm.w_o"])
    if return_state:
        return out, (c_f, n_f)
    return out


def mlstm_decode(params, x, cfg, cache):
    """One-token mLSTM update.  cache: {"c": (B,H,dk,dk), "n": (B,H,dk)}."""
    s = cfg.ssm
    b, one, d = x.shape
    nh = cfg.n_heads
    di = s.d_inner(d)
    dk = di // nh
    q = dense(x, params["mlstm.w_q"])[:, 0].reshape(b, nh, dk).astype(
        jnp.float32)
    k = (dense(x, params["mlstm.w_k"])[:, 0].reshape(b, nh, dk)
         / jnp.sqrt(jnp.asarray(dk, jnp.float32))).astype(jnp.float32)
    v = dense(x, params["mlstm.w_v"])[:, 0].reshape(b, nh, dk).astype(
        jnp.float32)
    gates = dense(x, params["mlstm.w_gates"])[:, 0].astype(jnp.float32)
    i_g = jax.nn.sigmoid(gates[..., :nh])[..., None]
    f_g = jax.nn.sigmoid(gates[..., nh:] + 4.0)[..., None]
    c = cache["c"] * f_g[..., None] + (i_g * k)[..., :, None] * v[..., None, :]
    n = cache["n"] * f_g + i_g * k
    y = jnp.einsum("bhk,bhkv->bhv", q, c)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), 1.0)
    y = (y / denom[..., None]).astype(x.dtype)
    y = rms_norm(y, params["mlstm.out_norm"], cfg.rms_eps)
    out = dense(y.reshape(b, 1, di), params["mlstm.w_o"])
    return out, {"c": c, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_mixer(params, x, cfg, *, state=None, return_state=False):
    """Sequential sLSTM.  x: (B, L, D) -> (B, L, D); state (h, c, n)."""
    b, L, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    x_pre = dense(x, params["slstm.w_x"])                 # (B, L, 4D)
    r = params["slstm.r"]                                 # (H, hd, 4hd)

    if state is None:
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        c0 = jnp.zeros((b, nh, hd), jnp.float32)
        n0 = jnp.ones((b, nh, hd), jnp.float32)
    else:
        h0, c0, n0 = state

    def step(carry, xt):
        h, c, n = carry                                   # (B,H,hd) each
        rec = jnp.einsum("bhk,hkf->bhf", h, r.astype(jnp.float32))
        pre = xt.reshape(b, nh, 4 * hd).astype(jnp.float32) + rec
        i_g, f_g, z_g, o_g = jnp.split(pre, 4, axis=-1)
        i_g = jax.nn.sigmoid(i_g)
        f_g = jax.nn.sigmoid(f_g + 1.0)
        z_g = jnp.tanh(z_g)
        o_g = jax.nn.sigmoid(o_g)
        c_new = f_g * c + i_g * z_g
        n_new = f_g * n + i_g
        h_new = o_g * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new), h_new

    (h_f, c_f, n_f), hs = jax.lax.scan(step, (h0, c0, n0),
                                       x_pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(b, L, d).astype(x.dtype)
    out = dense(y, params["slstm.w_o"])
    if return_state:
        return out, (h_f, c_f, n_f)
    return out


def slstm_decode(params, x, cfg, cache):
    """One-token sLSTM.  cache: {"h","c","n"} each (B, H, hd)."""
    b, one, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    x_pre = dense(x, params["slstm.w_x"])[:, 0]
    rec = jnp.einsum("bhk,hkf->bhf", cache["h"],
                     params["slstm.r"].astype(jnp.float32))
    pre = x_pre.reshape(b, nh, 4 * hd).astype(jnp.float32) + rec
    i_g, f_g, z_g, o_g = jnp.split(pre, 4, axis=-1)
    i_g = jax.nn.sigmoid(i_g)
    f_g = jax.nn.sigmoid(f_g + 1.0)
    z_g = jnp.tanh(z_g)
    o_g = jax.nn.sigmoid(o_g)
    c_new = f_g * cache["c"] + i_g * z_g
    n_new = f_g * cache["n"] + i_g
    h_new = o_g * c_new / jnp.maximum(n_new, 1.0)
    y = h_new.reshape(b, 1, d).astype(x.dtype)
    out = dense(y, params["slstm.w_o"])
    return out, {"h": h_new, "c": c_new, "n": n_new}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_mlstm_params(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = cfg.n_heads
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "mlstm.w_q": fan_in_init(k1, (d, di), dtype),
        "mlstm.w_k": fan_in_init(k4, (d, di), dtype),
        "mlstm.w_v": fan_in_init(k5, (d, di), dtype),
        "mlstm.w_gates": fan_in_init(k2, (d, 2 * nh), dtype),
        "mlstm.out_norm": jnp.zeros((di // nh,), dtype),
        "mlstm.w_o": fan_in_init(k3, (di, d), dtype),
    }


def init_slstm_params(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "slstm.w_x": fan_in_init(k1, (d, 4 * d), dtype),
        "slstm.r": 0.1 * fan_in_init(k2, (nh, hd, 4 * hd), dtype),
        "slstm.w_o": fan_in_init(k3, (d, d), dtype),
    }
