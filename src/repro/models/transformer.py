"""Unified decoder LM covering every assigned family.

One implementation, parameterized by :class:`~repro.configs.base.ModelConfig`:

* dense / GQA / MQA / qk-norm / GeGLU / sliding-window (gemma, starcoder2,
  qwen3-*, paligemma decoder),
* MoE with sort-based expert dispatch (phi-3.5-moe, deepseek-v3, jamba),
* MLA latent attention + MTP (deepseek-v3),
* Mamba mixers (jamba hybrid interleave),
* xLSTM mLSTM/sLSTM mixers (xlstm-1.3b),
* bidirectional-prefix VLM masking (paligemma; vision tower stubbed),
* whisper enc-dec lives in :mod:`repro.models.whisper` on top of the same
  blocks.

**Scan-over-layers**: layers repeat with period
``p = lcm(attn_period, moe_period, slstm_every)``; parameters are stacked
(G = n_layers/p groups) and the forward pass is a single ``lax.scan`` over
groups whose (rematerialized) body unrolls the p positions.  HLO size is
O(p), not O(n_layers) — DeepSeek's 61 layers compile as fast as 2.

Decode (`decode_step`) threads per-position caches through the same scan:
KV caches for attention (rolling-window when cfg.sliding_window>0), latent
caches for MLA, (conv, ssm) states for Mamba, (C, n) matrix states for
mLSTM, (h, c, n) for sLSTM.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import mamba as mamba_mod
from . import xlstm as xlstm_mod
from .attention import (gqa_attention, gqa_decode, mla_attention, mla_decode)
from .layers import (cross_entropy, dense, embed_lookup, fan_in_init,
                     gated_mlp, lm_logits, rms_norm, trunc_normal)
from .moe import moe_ffn


# ---------------------------------------------------------------------------
# Layer taxonomy
# ---------------------------------------------------------------------------

def mixer_kind(cfg: ModelConfig, layer: int) -> str:
    if cfg.family == "ssm":
        if cfg.ssm.kind == "xlstm":
            return "slstm" if layer % cfg.ssm.slstm_every == \
                cfg.ssm.slstm_every - 1 else "mlstm"
        return "mamba"
    if cfg.family == "hybrid" and not cfg.is_attention_layer(layer):
        return "mamba"
    return "mla" if cfg.mla is not None else "attn"


def ffn_kind(cfg: ModelConfig, layer: int) -> str:
    if cfg.moe is not None and layer % cfg.moe_period == cfg.moe_period - 1:
        return "moe"
    return "dense" if cfg.d_ff else "none"


def layer_period(cfg: ModelConfig) -> int:
    p = math.lcm(cfg.attn_period, cfg.moe_period)
    if cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        p = math.lcm(p, cfg.ssm.slstm_every)
    return min(p, cfg.n_layers)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def init_layer_params(key, cfg: ModelConfig, layer: int, dtype=jnp.float32):
    mk, fk = mixer_kind(cfg, layer), ffn_kind(cfg, layer)
    keys = iter(jax.random.split(key, 16))
    d = cfg.d_model
    p: dict = {"norm_mixer": jnp.zeros((d,), dtype)}

    if mk == "attn":
        p.update({
            "attn.w_q": fan_in_init(next(keys), (d, cfg.q_dim), dtype),
            "attn.w_k": fan_in_init(next(keys), (d, cfg.kv_dim), dtype),
            "attn.w_v": fan_in_init(next(keys), (d, cfg.kv_dim), dtype),
            "attn.w_o": fan_in_init(next(keys), (cfg.q_dim, d), dtype),
        })
        if cfg.qk_norm:
            p["attn.q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
            p["attn.k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    elif mk == "mla":
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        p.update({
            "attn.w_dq": fan_in_init(next(keys), (d, m.q_lora_rank), dtype),
            "attn.q_lat_norm": jnp.zeros((m.q_lora_rank,), dtype),
            "attn.w_uq": fan_in_init(next(keys),
                                     (m.q_lora_rank, cfg.n_heads * qk_head),
                                     dtype),
            "attn.w_dkv": fan_in_init(
                next(keys), (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
            "attn.kv_lat_norm": jnp.zeros((m.kv_lora_rank,), dtype),
            "attn.w_ukv": fan_in_init(
                next(keys),
                (m.kv_lora_rank,
                 cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)), dtype),
            "attn.w_o": fan_in_init(next(keys),
                                    (cfg.n_heads * m.v_head_dim, d), dtype),
        })
    elif mk == "mamba":
        p.update(mamba_mod.init_mamba_params(next(keys), cfg, dtype))
    elif mk == "mlstm":
        p.update(xlstm_mod.init_mlstm_params(next(keys), cfg, dtype))
    elif mk == "slstm":
        p.update(xlstm_mod.init_slstm_params(next(keys), cfg, dtype))

    if fk != "none":
        p["norm_ffn"] = jnp.zeros((d,), dtype)
    if fk == "dense":
        if cfg.gated_act in ("swiglu", "geglu"):
            p["ffn.w_gate"] = fan_in_init(next(keys), (d, cfg.d_ff), dtype)
        p["ffn.w_up"] = fan_in_init(next(keys), (d, cfg.d_ff), dtype)
        p["ffn.w_down"] = fan_in_init(next(keys), (cfg.d_ff, d), dtype)
    elif fk == "moe":
        e = cfg.moe
        p["moe.w_router"] = fan_in_init(next(keys), (d, e.n_experts), dtype)
        p["moe.w_gate"] = fan_in_init(next(keys),
                                      (e.n_experts, d, e.d_ff_expert), dtype)
        p["moe.w_up"] = fan_in_init(next(keys),
                                    (e.n_experts, d, e.d_ff_expert), dtype)
        p["moe.w_down"] = fan_in_init(next(keys),
                                      (e.n_experts, e.d_ff_expert, d), dtype)
        if e.n_shared:
            p["moe.shared_gate"] = fan_in_init(
                next(keys), (d, e.n_shared * e.d_ff_expert), dtype)
            p["moe.shared_up"] = fan_in_init(
                next(keys), (d, e.n_shared * e.d_ff_expert), dtype)
            p["moe.shared_down"] = fan_in_init(
                next(keys), (e.n_shared * e.d_ff_expert, d), dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    """Full parameter tree with period-stacked layer groups."""
    p = layer_period(cfg)
    n_groups = cfg.n_layers // p
    assert n_groups * p == cfg.n_layers, \
        f"{cfg.name}: n_layers={cfg.n_layers} not divisible by period={p}"
    keys = jax.random.split(key, p + 3)
    params: dict = {
        "embed": trunc_normal(keys[0], (cfg.vocab, cfg.d_model), 0.02, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = fan_in_init(keys[1], (cfg.d_model, cfg.vocab), dtype)
    groups = []
    for j in range(p):
        gkeys = jax.random.split(keys[2 + j], n_groups)
        stacked = jax.vmap(
            lambda k, j=j: init_layer_params(k, cfg, j, dtype))(gkeys)
        groups.append(stacked)
    params["groups"] = groups
    if cfg.mtp:
        mtp_key = keys[-1]
        k1, k2 = jax.random.split(mtp_key)
        params["mtp"] = init_layer_params(k1, cfg, cfg.n_layers - 1, dtype)
        params["mtp_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["mtp_proj"] = fan_in_init(k2, (2 * cfg.d_model, cfg.d_model),
                                         dtype)
    return params


# ---------------------------------------------------------------------------
# Layer application (full-sequence)
# ---------------------------------------------------------------------------

def apply_ffn(cfg: ModelConfig, fk: str, params, h):
    """Pre-norm FFN residual half of a block.  Returns (h, aux_loss).

    Shared by the full-sequence path, the jitted decode path, and the
    offload adapter's cached-decode applies — one definition keeps every
    execution mode numerically identical.
    """
    aux = jnp.zeros((), jnp.float32)
    if fk == "none":
        return h, aux
    hn = rms_norm(h, params["norm_ffn"], cfg.rms_eps)
    if fk == "dense":
        out = gated_mlp(hn, params["ffn.w_up"], params["ffn.w_down"],
                        cfg.gated_act, w_gate=params.get("ffn.w_gate"))
    else:
        out, aux = moe_ffn(params, hn, cfg)
    return h + out, aux


def apply_layer(cfg: ModelConfig, kinds: tuple[str, str], params, h, *,
                prefix_len: int = 0, causal: bool = True):
    """Pre-norm residual block: mixer + FFN.  Returns (h, aux_loss)."""
    mk, fk = kinds
    hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
    if mk == "attn":
        mix = gqa_attention(params, hn, cfg, causal=causal,
                            prefix_len=prefix_len)
    elif mk == "mla":
        mix = mla_attention(params, hn, cfg, causal=causal)
    elif mk == "mamba":
        mix = mamba_mod.mamba_mixer(params, hn, cfg)
    elif mk == "mlstm":
        mix = xlstm_mod.mlstm_mixer(params, hn, cfg)
    elif mk == "slstm":
        mix = xlstm_mod.slstm_mixer(params, hn, cfg)
    else:
        raise ValueError(mk)
    return apply_ffn(cfg, fk, params, h + mix)


def forward(cfg: ModelConfig, params, h, *, prefix_len: int = 0,
            causal: bool = True, remat: bool = True, unroll: bool = False,
            hint=None):
    """Run the layer stack over embedded inputs h: (B, S, D).

    ``unroll=True`` unrolls the group scan (used by the dry-run so XLA's
    cost analysis counts every layer instead of one while-loop body).
    ``hint`` (optional) re-asserts the activation sharding after every
    layer group — PERF iteration (EXPERIMENTS.md §Perf): without it the
    SPMD partitioner may reshard/replicate full-batch activations in the
    backward pass, which showed up as tens-of-GB fp32 collective-permutes
    in the gemma-7b train HLO.
    """
    p = layer_period(cfg)
    kinds = [(mixer_kind(cfg, j), ffn_kind(cfg, j)) for j in range(p)]
    hint = hint or (lambda x: x)

    def group_body(carry, gparams):
        h, aux = carry
        for j in range(p):
            h, a = apply_layer(cfg, kinds[j], gparams[j], h,
                               prefix_len=prefix_len, causal=causal)
            aux = aux + a
        return (hint(h), aux), None

    body = jax.checkpoint(group_body) if remat else group_body
    carry = (h, jnp.zeros((), jnp.float32))
    if unroll:
        # straight-line unroll (python loop, NOT scan-unroll): the dry-run's
        # cost calibration needs the BACKWARD pass unrolled too, and jax
        # lowers the grad of a scan to a rolled reverse scan regardless of
        # the fwd unroll setting.
        n_groups = jax.tree.leaves(params["groups"])[0].shape[0]
        for g in range(n_groups):
            gparams = jax.tree.map(lambda a, g=g: a[g],
                                   tuple(params["groups"]))
            carry, _ = body(carry, gparams)
        h, aux = carry
    else:
        (h, aux), _ = jax.lax.scan(body, carry, tuple(params["groups"]))
    return rms_norm(h, params["final_norm"], cfg.rms_eps), aux


def logits_fn(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings:
        return lm_logits(h, params["embed"], transpose=True)
    return lm_logits(h, params["head"])


def embed_tokens(cfg: ModelConfig, params, tokens, dtype):
    return embed_lookup(params["embed"], tokens,
                        scale=cfg.embed_scale).astype(dtype)


# ---------------------------------------------------------------------------
# Training losses
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, batch, *, compute_dtype=jnp.bfloat16,
            remat: bool = True, unroll: bool = False, hint=None,
            bf16_logits: bool = False):
    """Causal-LM loss.  batch: tokens (B,S), labels (B,S) [+ image_embeds].

    For VLM configs, ``image_embeds`` (B, prefix, D) are concatenated ahead
    of the text embeddings (bidirectional prefix); loss is taken on text
    positions only (labels already -100-masked for the prefix is the
    caller's choice — we mask structurally here).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    hint = hint or (lambda x: x)
    h = hint(embed_tokens(cfg, params, tokens, compute_dtype))
    prefix = 0
    if cfg.prefix_len:
        img = batch["image_embeds"].astype(compute_dtype)
        h = jnp.concatenate([img, h], axis=1)
        prefix = cfg.prefix_len
    h, aux = forward(cfg, params, h, prefix_len=prefix, remat=remat,
                     unroll=unroll, hint=hint)
    if prefix:
        h = h[:, prefix:]
    logits = logits_fn(cfg, params, h)
    if bf16_logits:
        # PERF (§Perf): keep the (B, S, vocab) tensor 16-bit; the CE below
        # still reduces in fp32.  Halves the largest activation tensor and
        # every collective that touches it.
        logits = logits.astype(jnp.bfloat16)
    loss = cross_entropy(logits, labels)

    if cfg.mtp:
        # DeepSeek MTP: one extra depth predicting t+2, weighted 0.3.
        emb_next = embed_tokens(cfg, params, jnp.roll(tokens, -1, axis=1),
                                compute_dtype)
        h_in = dense(jnp.concatenate(
            [rms_norm(h, params["mtp_norm"], cfg.rms_eps), emb_next], axis=-1),
            params["mtp_proj"])
        kinds = (mixer_kind(cfg, cfg.n_layers - 1),
                 ffn_kind(cfg, cfg.n_layers - 1))
        h_mtp, a2 = apply_layer(cfg, kinds, params["mtp"], h_in)
        logits2 = logits_fn(cfg, params, h_mtp)
        loss2 = cross_entropy(logits2, jnp.roll(labels, -1, axis=1))
        loss = loss + 0.3 * loss2
        aux = aux + a2
    return loss + aux


# ---------------------------------------------------------------------------
# Decode: caches + one-token step
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, layer: int, batch: int,
                     cache_seq: int, dtype=jnp.bfloat16):
    mk = mixer_kind(cfg, layer)
    if mk == "attn":
        s = min(cache_seq, cfg.sliding_window) if cfg.sliding_window \
            else cache_seq
        shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if mk == "mla":
        m = cfg.mla
        s = min(cache_seq, cfg.sliding_window) if cfg.sliding_window \
            else cache_seq
        return {"ckv": jnp.zeros(
            (batch, s, m.kv_lora_rank + m.qk_rope_head_dim), dtype)}
    if mk == "mamba":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        return {"conv": jnp.zeros((batch, s.conv_kernel - 1, di), dtype),
                "ssm": jnp.zeros((batch, di, s.d_state), jnp.float32)}
    if mk == "mlstm":
        di = cfg.ssm.d_inner(cfg.d_model)
        dk = di // cfg.n_heads
        return {"c": jnp.zeros((batch, cfg.n_heads, dk, dk), jnp.float32),
                "n": jnp.zeros((batch, cfg.n_heads, dk), jnp.float32)}
    if mk == "slstm":
        hd = cfg.d_model // cfg.n_heads
        z = jnp.zeros((batch, cfg.n_heads, hd), jnp.float32)
        return {"h": z, "c": z, "n": jnp.ones_like(z)}
    raise ValueError(mk)


def init_cache(cfg: ModelConfig, batch: int, cache_seq: int,
               dtype=jnp.bfloat16):
    """Stacked cache tree mirroring params['groups'] layout."""
    p = layer_period(cfg)
    n_groups = cfg.n_layers // p
    caches = []
    for j in range(p):
        one = init_layer_cache(cfg, j, batch, cache_seq, dtype)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_groups, *a.shape)), one))
    return tuple(caches)


def apply_layer_decode(cfg, kinds, params, h, cache, cache_len):
    mk, fk = kinds
    hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
    if mk == "attn":
        mix, cache = gqa_decode(params, hn, cfg, cache, cache_len)
    elif mk == "mla":
        mix, cache = mla_decode(params, hn, cfg, cache, cache_len)
    elif mk == "mamba":
        mix, cache = mamba_mod.mamba_decode(params, hn, cfg, cache)
    elif mk == "mlstm":
        mix, cache = xlstm_mod.mlstm_decode(params, hn, cfg, cache)
    elif mk == "slstm":
        mix, cache = xlstm_mod.slstm_decode(params, hn, cfg, cache)
    else:
        raise ValueError(mk)
    h, _aux = apply_ffn(cfg, fk, params, h + mix)
    return h, cache


def decode_step(cfg: ModelConfig, params, cache, tokens, cache_len,
                *, compute_dtype=jnp.bfloat16, unroll: bool = False):
    """One decode step: tokens (B, 1) + cache -> (logits (B,1,V), cache)."""
    p = layer_period(cfg)
    kinds = [(mixer_kind(cfg, j), ffn_kind(cfg, j)) for j in range(p)]
    h = embed_tokens(cfg, params, tokens, compute_dtype)

    def group_body(h, xs):
        gparams, gcache = xs
        new_caches = []
        for j in range(p):
            h, c = apply_layer_decode(cfg, kinds[j], gparams[j], h,
                                      gcache[j], cache_len)
            new_caches.append(c)
        return h, tuple(new_caches)

    if unroll:
        n_groups = jax.tree.leaves(params["groups"])[0].shape[0]
        new_cache = cache
        for g in range(n_groups):
            xs = jax.tree.map(lambda a, g=g: a[g],
                              (tuple(params["groups"]), cache))
            h, newc = group_body(h, xs)
            # write back along the (unsharded) leading layer axis — a
            # jnp.stack here would gather the seq-sharded caches and
            # contaminate the calibration measurement
            new_cache = jax.tree.map(
                lambda full, one, g=g: full.at[g].set(one), new_cache, newc)
    else:
        h, new_cache = jax.lax.scan(group_body, h,
                                    (tuple(params["groups"]), cache))
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    return logits_fn(cfg, params, h), new_cache
