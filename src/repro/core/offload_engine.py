"""The SSD-offloaded training engine (ZeRO-Infinity semantics + MemAscend).

This module holds the model-side interface (:class:`OffloadableModel`), the
policy layer (:class:`OffloadPolicy` — a validated, registry-addressable
description of which allocator/pool/overflow/store implementations to run),
and :class:`OffloadedTrainer`, kept as a thin back-compat shim.

The lifecycle itself — pool-slot checkout → async SSD read → H2D → compute
→ release, per training step:

  1. **Forward**, block-streamed: per unit (embedding, transformer blocks,
     LM head) the swapper prefetches compute-precision weights SSD→host pool
     slot; weights are put on device; the block runs; the slot is released.
     Block *inputs* are checkpointed (gradient checkpointing) and — in
     offloaded-GC mode — held in host memory, charged to the tracker.
  2. **Backward**, reverse-streamed: weights are re-fetched, the block is
     recomputed under ``jax.vjp``, and parameter gradients are written into
     the fp32 **gradient flat buffer** in host memory (ZeRO-Infinity's
     single contiguous partition buffer, §III-C).
  3. **Overflow check** over the flat buffer — chained baseline or
     MemAscend's fused single pass — then the dynamic loss scaler decides
     whether to apply the step.
  4. **Optimizer**, subgroup-streamed on the host: per parameter, read
     (master, m, v) from SSD, Adam-update, write back, emit fresh compute
     weights.

— now lives in :mod:`repro.core.session` as an executable schedule
(:mod:`repro.core.stream_plan`) with lookahead pipelining, shared by train,
eval, and offloaded decode.

Policies are selected by name through the registry::

    policy = OffloadPolicy.preset("memascend").with_store(root).build()

Two presets package the paper's comparison: ``zero-infinity`` (fixed pool +
pow2 pinned allocator + chained overflow check + per-tensor-file store) vs
``memascend`` (adaptive pool + alignment-free allocator + fused check +
direct NVMe engine); ``memascend-bf16`` adds the half-precision optimizer.
"""

from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .buffer_pool import (AdaptiveBufferPool, BufferPoolBase, FixedBufferPool,
                          PoolCensus, ShapeClass)
from .memory_tracker import MemoryTracker
from .nvme import DirectNVMeEngine, FilesystemEngine, TensorStore
from .optimizer import AdamConfig
from .pinned_alloc import (AlignmentFreeAllocator, PinnedAllocatorBase,
                           PowerOfTwoCachingAllocator)
from .session import OffloadSession


# ---------------------------------------------------------------------------
# Model-side interface
# ---------------------------------------------------------------------------

@dataclass
class OffloadUnit:
    """One streamable unit: the embedding, one transformer block, or the head.

    ``params`` are the fp32 initial values; ``kind`` is "standalone" or
    "block" (block units share shape classes; standalone units get dedicated
    pool slots, per paper §IV-B).
    """

    name: str
    kind: str                       # "standalone" | "block"
    params: dict[str, np.ndarray]


@dataclass
class OffloadableModel:
    """Pure-function model description consumed by the engine.

    apply signatures (all jittable; ``params`` is {name: jnp.ndarray}):
      embed_apply(params, tokens)              -> h
      block_apply(params, h)                   -> h
      head_loss(params, h, labels)             -> scalar loss (pre-scaling)
      head_logits(params, h)                   -> logits (optional; required
                                                  by decode StreamPlans)
      block_prefill(params, h)                 -> h, k, v (optional; cached
                                                  decode prompt pass)
      block_step(params, h, k_cache, v_cache, cache_len)
                                               -> h, k_new, v_new (optional;
                                                  cached decode step)
      block_verify(params, h, k_cache, v_cache, cache_len)
                                               -> h, k_new, v_new (optional;
                                                  (B, K) draft-window verify
                                                  step for spec decode)
    ``class_of(param_key)`` maps a parameter to its pool shape class;
    ``kv_shape(batch, time)`` is one block's host KV-slot shape (leading
    axis 2 packs K and V) for sessions built with a DecodeSpec.
    """

    units: list[OffloadUnit]
    embed_apply: Callable
    block_apply: Callable
    head_loss: Callable
    class_of: Callable[[str], str]
    head_logits: Callable | None = None
    block_prefill: Callable | None = None
    block_step: Callable | None = None
    block_verify: Callable | None = None
    kv_shape: Callable[[int, int], tuple] | None = None
    # route-aware expert paging (MoE): staged applies splitting one MoE
    # block into a routing half (device computes the expert assignment the
    # host reads back) and an expert half (consumes the routed expert
    # stacks the ExpertFetchOp staged).  ``expert_meta`` maps MoE unit
    # name -> {"n_experts": E, "experts": [(gate, up, down) param-name
    # triples in stack order]}; units absent from it stream densely.
    block_route: Callable | None = None
    block_moe: Callable | None = None
    block_moe_bwd: Callable | None = None
    block_prefill_route: Callable | None = None
    block_step_route: Callable | None = None
    block_verify_route: Callable | None = None
    expert_meta: dict | None = None

    def expert_params(self, unit_name: str) -> list[str]:
        """Per-expert param names of one paged-MoE unit ([] if dense)."""
        if not self.expert_meta or unit_name not in self.expert_meta:
            return []
        return [name for triple in self.expert_meta[unit_name]["experts"]
                for name in triple]

    def census(self, inflight_blocks: int = 2, bytes_per_elem: int = 2, *,
               expert_page_slots: int | None = None) -> PoolCensus:
        """Shape-class census over the units (drives both pool designs).

        With ``expert_page_slots`` set (expert paging on), paged-MoE
        units' routed-expert tensors leave the per-block streaming counts
        — they are individually fetched pages, not per-fetch streams —
        and their class gains that many standalone page slots instead
        (the expert-residency budget, mirroring ``PoolCensus.with_kv``).
        """
        per_block: dict[str, int] = {}
        standalone: dict[str, int] = {}
        nbytes: dict[str, int] = {}
        for unit in self.units:
            paged = set(self.expert_params(unit.name)) \
                if expert_page_slots is not None else set()
            counts: dict[str, int] = {}
            for key, value in unit.params.items():
                cls = self.class_of(key)
                compute_nbytes = value.size * bytes_per_elem  # compute dtype
                nbytes[cls] = max(nbytes.get(cls, 0), compute_nbytes)
                if key in paged:
                    continue    # paged tensors get standalone slots below
                counts[cls] = counts.get(cls, 0) + 1
            if unit.kind == "block":
                for cls, c in counts.items():
                    per_block[cls] = max(per_block.get(cls, 0), c)
            else:
                for cls, c in counts.items():
                    standalone[cls] = standalone.get(cls, 0) + c
        if expert_page_slots is not None:
            from .paged import EXPERT_PAGE_CLASS
            if EXPERT_PAGE_CLASS not in nbytes:
                raise ValueError("expert_page_slots set but no unit has "
                                 "expert-class tensors")
            standalone[EXPERT_PAGE_CLASS] = \
                standalone.get(EXPERT_PAGE_CLASS, 0) + expert_page_slots
        classes = []
        for cls in sorted(nbytes):
            classes.append(ShapeClass(cls, nbytes[cls],
                                      per_block.get(cls, 0),
                                      standalone.get(cls, 0)))
        return PoolCensus(tuple(classes), inflight_blocks)


# ---------------------------------------------------------------------------
# Policies (baseline vs MemAscend): validated dataclass + named registry
# ---------------------------------------------------------------------------

_POLICY_REGISTRY: dict[str, Callable[..., "OffloadPolicy"]] = {}


def register_policy(name: str):
    """Decorator: make ``factory(root, **kw) -> OffloadPolicy`` addressable
    as ``OffloadPolicy.preset(name)`` from launchers/benchmarks/examples."""
    def deco(factory):
        _POLICY_REGISTRY[name] = factory
        return factory
    return deco


def policy_names() -> list[str]:
    return sorted(_POLICY_REGISTRY)


@dataclass
class OffloadPolicy:
    """Which allocator/pool/overflow/store to run, validated on build.

    ``inflight_blocks`` is the prefetch depth N that sizes the pool (§IV-B);
    ``lookahead`` bounds how many upcoming plan fetches the session issues
    asynchronously (None → inflight_blocks; 1 → synchronous per-unit
    fetches, the seed engine's behaviour).

    ``overlap`` selects how much of the Fig. 6 pipeline runs on background
    threads (the bench ablation axis; numerics are identical across modes):

    * ``"sync"`` — SSD reads still prefetch under compute, but H2D blocks
      inside each FetchOp, gradient D2H runs on the compute thread, and the
      optimizer streams strictly after the backward pass (PR-1 behaviour),
    * ``"h2d"``  — adds the H2D worker + double-buffered device slots:
      host→device copies hide under the previous block's compute,
    * ``"full"`` — adds the gradient writer thread (backward D2H overlaps
      the next block's re-fetch/recompute) and runs the optimizer stage on
      its own worker so step *k*'s host Adam interleaves with step *k+1*'s
      forward prefetch window (cross-step pipelining).

    ``act_policy`` picks where each block's activation checkpoint lives
    between forward and backward (only meaningful with
    ``offload_checkpoints=True``; see
    :func:`repro.core.stream_plan.resolve_act_policy`):

    * ``"host"`` — pinned host memory, one resident buffer per block (the
      pre-PR-9 behaviour; footprint grows with depth × seq),
    * ``"ssd"`` — stream each checkpoint onward to the store and prefetch
      it back under the backward pass (SSDTrain-style; host footprint is
      the in-flight window, not the depth),
    * ``"recompute"`` — checkpoint every other block to SSD and re-run the
      forward for the rest (trade FLOPs for bytes),
    * a dict block-name → tier or a positional sequence for per-block
      mixes.
    """

    name: str
    allocator_cls: type
    pool_cls: type
    fused_overflow: bool
    store_factory: Callable[[], TensorStore]
    adam: AdamConfig = field(default_factory=AdamConfig)
    inflight_blocks: int = 2
    lookahead: int | None = None
    offload_checkpoints: bool = True   # offloaded gradient checkpointing
    overlap: str = "full"              # "sync" | "h2d" | "full" (Fig. 6)
    act_policy: object = "host"        # "host" | "ssd" | "recompute" |
    #                                    dict/sequence of per-block tiers
    expert_paging: str = "off"         # "off" | "all" | "routed": MoE
    #                                    expert residency (see paged.py) —
    #                                    "routed" fetches only the experts
    #                                    the router selected; "all" pages
    #                                    every expert (timing-independent
    #                                    prefetch baseline); "off" streams
    #                                    experts densely with the block
    expert_page_slots: int | None = None  # host expert-page budget (pages);
    #                                       None -> every page resident

    def __post_init__(self) -> None:
        if not self.name or not isinstance(self.name, str):
            raise ValueError("policy name must be a non-empty string")
        if not (isinstance(self.allocator_cls, type)
                and issubclass(self.allocator_cls, PinnedAllocatorBase)):
            raise ValueError(f"allocator_cls must be a PinnedAllocatorBase "
                             f"subclass, got {self.allocator_cls!r}")
        if not (isinstance(self.pool_cls, type)
                and issubclass(self.pool_cls, BufferPoolBase)):
            raise ValueError(f"pool_cls must be a BufferPoolBase subclass, "
                             f"got {self.pool_cls!r}")
        if not callable(self.store_factory):
            raise ValueError("store_factory must be callable")
        if self.inflight_blocks < 1:
            raise ValueError(f"inflight_blocks must be >= 1, got "
                             f"{self.inflight_blocks}")
        if self.lookahead is not None and not (
                1 <= self.lookahead <= self.inflight_blocks):
            raise ValueError(
                f"lookahead must be in [1, inflight_blocks="
                f"{self.inflight_blocks}], got {self.lookahead} — a deeper "
                f"window would oversubscribe the pool (§IV-B sizing)")
        if self.overlap not in ("sync", "h2d", "full"):
            raise ValueError(f"overlap must be one of 'sync'|'h2d'|'full', "
                             f"got {self.overlap!r}")
        _act_tiers = ("host", "ssd", "recompute")
        if isinstance(self.act_policy, str):
            if self.act_policy not in _act_tiers:
                raise ValueError(
                    f"act_policy must be one of {_act_tiers} (or a "
                    f"per-block dict/sequence), got {self.act_policy!r} — "
                    f"device-resident checkpoints are selected via "
                    f"offload_checkpoints=False")
        elif isinstance(self.act_policy, dict):
            bad = sorted(t for t in self.act_policy.values()
                         if t not in _act_tiers)
            if bad:
                raise ValueError(f"act_policy has unknown tier(s) {bad}; "
                                 f"expected {_act_tiers}")
        else:
            try:
                tiers = list(self.act_policy)
            except TypeError:
                raise ValueError(f"act_policy must be a tier name, dict, or "
                                 f"sequence, got {self.act_policy!r}") from None
            bad = sorted(t for t in tiers if t not in _act_tiers)
            if bad:
                raise ValueError(f"act_policy has unknown tier(s) {bad}; "
                                 f"expected {_act_tiers}")
        if self.expert_paging not in ("off", "all", "routed"):
            raise ValueError(f"expert_paging must be one of "
                             f"'off'|'all'|'routed', got "
                             f"{self.expert_paging!r}")
        if self.expert_page_slots is not None:
            if self.expert_paging == "off":
                raise ValueError("expert_page_slots needs expert_paging="
                                 "'all'|'routed' (no page pool exists "
                                 "under 'off')")
            if self.expert_page_slots < 2:
                raise ValueError(
                    f"expert_page_slots must be >= 2 (one page pinned for "
                    f"a copy, one turning over), got "
                    f"{self.expert_page_slots}")
        if self.adam.state_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"state_dtype must be float32|bfloat16, got "
                             f"{self.adam.state_dtype!r}")
        if self.adam.compute_dtype not in ("float32", "float16", "bfloat16"):
            raise ValueError(f"compute_dtype must be float32|float16|"
                             f"bfloat16, got {self.adam.compute_dtype!r}")

    # -- registry access -----------------------------------------------------

    @staticmethod
    def preset(name: str, **kwargs) -> "PolicyBuilder":
        """A builder seeded from the named registry preset."""
        try:
            factory = _POLICY_REGISTRY[name]
        except KeyError:
            raise KeyError(f"unknown offload policy {name!r}; registered: "
                           f"{policy_names()}") from None
        return PolicyBuilder(name, factory, **kwargs)

    @staticmethod
    def names() -> list[str]:
        return policy_names()

    def replace(self, **changes) -> "OffloadPolicy":
        """A validated copy with ``changes`` applied (re-runs validation)."""
        return dataclasses.replace(self, **changes)


# with_adam/with_store route through one factory-kwargs dict; these names
# let each method reject options that belong to the other group.
_ADAM_FIELDS = frozenset(f.name for f in dataclasses.fields(AdamConfig))


class PolicyBuilder:
    """Fluent, validated construction of an :class:`OffloadPolicy`.

    ``OffloadPolicy.preset("memascend").with_store(root)
    .with_adam(lr=1e-3).with_lookahead(2).build()`` — every ``with_*``
    returns the builder; :meth:`build` runs the preset factory and then the
    dataclass validation.
    """

    def __init__(self, name: str, factory: Callable, **factory_kwargs):
        self._name = name
        self._factory = factory
        self._factory_kwargs = dict(factory_kwargs)
        self._root: str | None = None
        self._store_factory: Callable[[], TensorStore] | None = None
        self._overrides: dict = {}

    def with_store(self, root: str | None = None, *,
                   factory: Callable[[], TensorStore] | None = None,
                   **store_kwargs) -> "PolicyBuilder":
        """Point the policy at SSD storage: a root directory for the
        preset's engine (``store_kwargs`` are forwarded to the preset
        factory, e.g. ``n_devices=`` for memascend), or an explicit
        zero-arg store factory."""
        if (root is None) == (factory is None):
            raise ValueError("with_store needs exactly one of root=/factory=")
        if factory is not None and store_kwargs:
            raise ValueError(
                f"store option(s) {sorted(store_kwargs)} only apply with "
                f"root= (they configure the preset's store engine); an "
                f"explicit factory= is already fully configured")
        misrouted = sorted(set(store_kwargs) & _ADAM_FIELDS)
        if misrouted:
            raise ValueError(f"with_store got Adam option(s) {misrouted}; "
                             f"use with_adam()")
        self._root = root
        self._store_factory = factory
        self._factory_kwargs.update(store_kwargs)
        return self

    def with_adam(self, **adam_kwargs) -> "PolicyBuilder":
        unknown = sorted(set(adam_kwargs) - _ADAM_FIELDS)
        if unknown:
            raise ValueError(
                f"with_adam got non-Adam option(s) {unknown}; AdamConfig "
                f"fields: {sorted(_ADAM_FIELDS)} (preset/store options go "
                f"via preset() or with_store())")
        self._factory_kwargs.update(adam_kwargs)
        return self

    def with_inflight_blocks(self, n: int) -> "PolicyBuilder":
        self._overrides["inflight_blocks"] = n
        return self

    def with_lookahead(self, n: int | None) -> "PolicyBuilder":
        self._overrides["lookahead"] = n
        return self

    def with_overlap(self, mode: str) -> "PolicyBuilder":
        """Pipeline-overlap ablation level: 'sync' | 'h2d' | 'full'."""
        self._overrides["overlap"] = mode
        return self

    def with_activations(self, policy) -> "PolicyBuilder":
        """Per-block activation-checkpoint tier: 'host' | 'ssd' |
        'recompute', or a dict/sequence of per-block tiers (see
        OffloadPolicy.act_policy)."""
        self._overrides["act_policy"] = policy
        return self

    def with_expert_paging(self, mode: str, *,
                           page_slots: int | None = None) -> "PolicyBuilder":
        """MoE expert residency: 'off' | 'all' | 'routed', with an
        optional host page budget (see OffloadPolicy.expert_paging)."""
        self._overrides["expert_paging"] = mode
        self._overrides["expert_page_slots"] = page_slots
        return self

    def with_overrides(self, **field_overrides) -> "PolicyBuilder":
        """Override any OffloadPolicy field post-factory (validated)."""
        self._overrides.update(field_overrides)
        return self

    def build(self) -> OffloadPolicy:
        if self._root is None and self._store_factory is None:
            raise ValueError(
                f"policy {self._name!r} has no store: call .with_store(root)")
        root = self._root if self._root is not None else "unused"
        try:
            policy = self._factory(root, **self._factory_kwargs)
        except TypeError as e:
            # Unknown kwargs would otherwise surface deep inside the preset
            # (e.g. AdamConfig), far from the with_store()/with_adam() call
            # that introduced them.
            raise ValueError(
                f"preset {self._name!r} rejected option(s) passed via "
                f"preset()/with_store()/with_adam(): {e}") from e
        changes = dict(self._overrides)
        if self._store_factory is not None:
            changes["store_factory"] = self._store_factory
        return policy.replace(**changes) if changes else policy


@register_policy("zero-infinity")
def zero_infinity_policy(root: str, **adam_kw) -> OffloadPolicy:
    return OffloadPolicy(
        name="zero-infinity",
        allocator_cls=PowerOfTwoCachingAllocator,
        pool_cls=FixedBufferPool,
        fused_overflow=False,
        store_factory=lambda r=root: FilesystemEngine(os.path.join(r, "fs_store")),
        adam=AdamConfig(**adam_kw),
    )


@register_policy("memascend")
def memascend_policy(root: str, *, bf16_optimizer: bool = False,
                     n_devices: int = 2, **adam_kw) -> OffloadPolicy:
    adam_kw.setdefault("state_dtype",
                       "bfloat16" if bf16_optimizer else "float32")
    return OffloadPolicy(
        name="memascend",
        allocator_cls=AlignmentFreeAllocator,
        pool_cls=AdaptiveBufferPool,
        fused_overflow=True,
        store_factory=lambda r=root: DirectNVMeEngine(
            os.path.join(r, "raw_store"), n_devices=n_devices),
        adam=AdamConfig(**adam_kw),
    )


@register_policy("memascend-bf16")
def memascend_bf16_policy(root: str, **kw) -> OffloadPolicy:
    kw.setdefault("bf16_optimizer", True)
    return memascend_policy(root, **kw).replace(name="memascend-bf16")


# ---------------------------------------------------------------------------
# Back-compat shim over OffloadSession
# ---------------------------------------------------------------------------

class OffloadedTrainer:
    """Thin shim: the seed trainer API, delegating to an OffloadSession.

    Prefer the session directly (context management, StreamPlans, lookahead
    control, serve mode); this class keeps the historical surface —
    ``train_step`` / ``eval_loss`` / ``master_param`` / ``close`` plus the
    ``store``/``pool``/``swapper``/``optimizer``/``scaler``/``flat``
    attributes — for existing callers and checkpoints.
    """

    def __init__(self, model: OffloadableModel, policy: OffloadPolicy,
                 *, tracker: MemoryTracker | None = None) -> None:
        self.session = OffloadSession(model, policy, tracker=tracker)

    def train_step(self, tokens: np.ndarray, labels: np.ndarray) -> dict:
        return self.session.train_step(tokens, labels)

    def eval_loss(self, tokens: np.ndarray, labels: np.ndarray) -> float:
        return self.session.eval_loss(tokens, labels)

    def master_param(self, unit_name: str, key: str) -> np.ndarray:
        return self.session.master_param(unit_name, key)

    def close(self) -> None:
        self.session.close()

    def __getattr__(self, name: str):
        # model/policy/tracker/store/pool/swapper/optimizer/scaler/flat/
        # total_params/metrics/... all live on the session.
        if name == "session":   # session construction itself failed
            raise AttributeError(name)
        return getattr(self.session, name)
