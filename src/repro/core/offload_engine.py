"""The SSD-offloaded training engine (ZeRO-Infinity semantics + MemAscend).

This is the end-to-end substrate the paper optimizes.  One training step:

  1. **Forward**, block-streamed: for each unit (embedding, transformer
     blocks, LM head) the swapper prefetches compute-precision weights
     SSD→host pool slot; weights are put on device; the block runs; the slot
     is released.  Block *inputs* are checkpointed (gradient checkpointing)
     and — in offloaded-GC mode — held in host memory, charged to the
     tracker (paper Eq. 1 term).
  2. **Backward**, reverse-streamed: weights are re-fetched, the block is
     recomputed under ``jax.vjp``, and parameter gradients are written into
     the fp32 **gradient flat buffer** in host memory (ZeRO-Infinity's
     single contiguous partition buffer, §III-C).
  3. **Overflow check** over the flat buffer — chained baseline or
     MemAscend's fused single pass — then the dynamic loss scaler decides
     whether to apply the step.
  4. **Optimizer**, subgroup-streamed on the host: for each parameter, read
     (master, m, v) from SSD, Adam-update, write back, emit fresh compute
     weights (fp32 or bf16 state per config).

Two :class:`OffloadPolicy` presets package the paper's comparison:
``zero_infinity_policy()`` (fixed pool + pow2 pinned allocator + chained
overflow check + per-tensor-file store) vs ``memascend_policy()`` (adaptive
pool + alignment-free allocator + fused check + direct NVMe engine).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from .buffer_pool import (AdaptiveBufferPool, BufferPoolBase, FixedBufferPool,
                          PoolCensus, ShapeClass)
from .loss_scale import DynamicLossScaler
from .memory_tracker import MemoryTracker
from .nvme import DirectNVMeEngine, FilesystemEngine, TensorStore
from .optimizer import AdamConfig, OffloadedAdam
from .overflow import baseline_overflow_check, fused_overflow_check
from .pinned_alloc import (AlignmentFreeAllocator, PinnedAllocatorBase,
                           PowerOfTwoCachingAllocator)
from .swapper import ParameterSwapper


# ---------------------------------------------------------------------------
# Model-side interface
# ---------------------------------------------------------------------------

@dataclass
class OffloadUnit:
    """One streamable unit: the embedding, one transformer block, or the head.

    ``params`` are the fp32 initial values; ``kind`` is "standalone" or
    "block" (block units share shape classes; standalone units get dedicated
    pool slots, per paper §IV-B).
    """

    name: str
    kind: str                       # "standalone" | "block"
    params: dict[str, np.ndarray]


@dataclass
class OffloadableModel:
    """Pure-function model description consumed by the engine.

    apply signatures (all jittable; ``params`` is {name: jnp.ndarray}):
      embed_apply(params, tokens)              -> h
      block_apply(params, h)                   -> h
      head_loss(params, h, labels)             -> scalar loss (pre-scaling)
    ``class_of(param_key)`` maps a parameter to its pool shape class.
    """

    units: list[OffloadUnit]
    embed_apply: Callable
    block_apply: Callable
    head_loss: Callable
    class_of: Callable[[str], str]

    def census(self, inflight_blocks: int = 2,
               bytes_per_elem: int = 2) -> PoolCensus:
        """Shape-class census over the units (drives both pool designs)."""
        per_block: dict[str, int] = {}
        standalone: dict[str, int] = {}
        nbytes: dict[str, int] = {}
        block_seen = False
        for unit in self.units:
            counts: dict[str, int] = {}
            for key, value in unit.params.items():
                cls = self.class_of(key)
                compute_nbytes = value.size * bytes_per_elem  # compute dtype
                nbytes[cls] = max(nbytes.get(cls, 0), compute_nbytes)
                counts[cls] = counts.get(cls, 0) + 1
            if unit.kind == "block":
                block_seen = True
                for cls, c in counts.items():
                    per_block[cls] = max(per_block.get(cls, 0), c)
            else:
                for cls, c in counts.items():
                    standalone[cls] = standalone.get(cls, 0) + c
        del block_seen
        classes = []
        for cls in sorted(nbytes):
            classes.append(ShapeClass(cls, nbytes[cls],
                                      per_block.get(cls, 0),
                                      standalone.get(cls, 0)))
        return PoolCensus(tuple(classes), inflight_blocks)


# ---------------------------------------------------------------------------
# Policies (baseline vs MemAscend)
# ---------------------------------------------------------------------------

@dataclass
class OffloadPolicy:
    name: str
    allocator_cls: type
    pool_cls: type
    fused_overflow: bool
    store_factory: Callable[[str], TensorStore]
    adam: AdamConfig = field(default_factory=AdamConfig)
    inflight_blocks: int = 2
    offload_checkpoints: bool = True   # offloaded gradient checkpointing


def zero_infinity_policy(root: str, **adam_kw) -> OffloadPolicy:
    return OffloadPolicy(
        name="zero-infinity",
        allocator_cls=PowerOfTwoCachingAllocator,
        pool_cls=FixedBufferPool,
        fused_overflow=False,
        store_factory=lambda r=root: FilesystemEngine(os.path.join(r, "fs_store")),
        adam=AdamConfig(**adam_kw),
    )


def memascend_policy(root: str, *, bf16_optimizer: bool = False,
                     n_devices: int = 2, **adam_kw) -> OffloadPolicy:
    adam_kw.setdefault("state_dtype",
                       "bfloat16" if bf16_optimizer else "float32")
    return OffloadPolicy(
        name="memascend",
        allocator_cls=AlignmentFreeAllocator,
        pool_cls=AdaptiveBufferPool,
        fused_overflow=True,
        store_factory=lambda r=root: DirectNVMeEngine(
            os.path.join(r, "raw_store"), n_devices=n_devices),
        adam=AdamConfig(**adam_kw),
    )


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class OffloadedTrainer:
    """Layer-streaming fwd/bwd + host optimizer over an OffloadableModel."""

    def __init__(self, model: OffloadableModel, policy: OffloadPolicy,
                 *, tracker: MemoryTracker | None = None) -> None:
        self.model = model
        self.policy = policy
        self.tracker = tracker or MemoryTracker()
        self.store = policy.store_factory()
        self.allocator = policy.allocator_cls(
            tracker=self.tracker, component="pinned", backing="numpy")
        census = model.census(
            policy.inflight_blocks,
            bytes_per_elem=policy.adam.compute_np_dtype.itemsize)
        self.pool = policy.pool_cls(census, self.allocator)
        class_of = {}
        for unit in model.units:
            for key in unit.params:
                cls = model.class_of(key)
                class_of[f"{unit.name}/{key}{OffloadedAdam.COMPUTE}"] = (
                    cls if isinstance(self.pool, AdaptiveBufferPool)
                    else FixedBufferPool.SLOT_CLASS)
        # For the fixed pool every request maps to the monolithic class via
        # the pool itself; pass the true class and let the pool decide.
        self.swapper = ParameterSwapper(self.store, self.pool, class_of={
            k: model.class_of(k.split("/", 1)[1].rsplit(".", 1)[0])
            for k in class_of})
        self.optimizer = OffloadedAdam(self.store, policy.adam,
                                       tracker=self.tracker)
        self.scaler = DynamicLossScaler()
        if policy.adam.compute_dtype != "float16":
            self.scaler.scale = 1.0  # only fp16 needs scaling; check stays on
        self.compute_dtype = {"bfloat16": jnp.bfloat16,
                              "float16": jnp.float16,
                              "float32": jnp.float32}[
            policy.adam.compute_dtype]

        # Register all parameters with the store/optimizer.
        self._unit_param_meta: list[tuple[OffloadUnit, dict]] = []
        total_params = 0
        for unit in model.units:
            meta = {}
            for key, value in unit.params.items():
                skey = f"{unit.name}/{key}"
                self.optimizer.register(skey, value)
                meta[key] = (value.shape, value.size)
                total_params += value.size
            self._unit_param_meta.append((unit, meta))
        self.total_params = total_params

        # Gradient flat buffer: fp32, whole partition, lives for the run.
        self._flat_buf = self.allocator.alloc(total_params * 4,
                                              tag="gradient_flat_buffer")
        self.flat = self._flat_buf.view(np.float32, (total_params,))
        self._flat_offsets: dict[str, tuple[int, int, tuple]] = {}
        off = 0
        for unit, meta in self._unit_param_meta:
            for key, (shape, size) in meta.items():
                self._flat_offsets[f"{unit.name}/{key}"] = (off, size, shape)
                off += size

        # jitted per-block functions (shared across blocks of equal shapes)
        self._jit_embed = jax.jit(model.embed_apply)
        self._jit_block = jax.jit(model.block_apply)
        self._jit_head = jax.jit(self._head_loss_and_grads)
        self._jit_block_bwd = jax.jit(self._block_bwd)
        self._jit_embed_bwd = jax.jit(
            lambda p, t, dy: jax.vjp(model.embed_apply, p, t)[1](dy)[0])

        self.metrics: dict = {}

    # -- jitted helpers ----------------------------------------------------------

    def _head_loss_and_grads(self, params, h, labels, scale):
        def scaled(params, h):
            return self.model.head_loss(params, h, labels) * scale
        (sloss), vjp = jax.vjp(scaled, params, h)
        dparams, dh = vjp(jnp.ones((), sloss.dtype))
        return sloss / scale, dparams, dh

    def _block_bwd(self, params, x, dy):
        _, vjp = jax.vjp(self.model.block_apply, params, x)
        dparams, dx = vjp(dy)
        return dparams, dx

    # -- weight streaming ----------------------------------------------------------

    def _fetch_unit_device_params(self, unit: OffloadUnit, meta: dict):
        """Stream one unit's compute weights SSD→pool→device."""
        cd = self.policy.adam.compute_np_dtype
        for key, (shape, _size) in meta.items():
            skey = f"{unit.name}/{key}{OffloadedAdam.COMPUTE}"
            self.swapper.prefetch(skey, cd, shape)
        device_params = {}
        for key, (shape, _size) in meta.items():
            skey = f"{unit.name}/{key}{OffloadedAdam.COMPUTE}"
            ticket = self.swapper.get(skey, cd, shape)
            host_view = ticket.buf.view(cd, shape)
            # H2D transfer. copy=True is essential: on the CPU backend jax
            # may alias host memory, and the pool slot is reused as soon as
            # it is released (the paper's lifecycle) — an alias would race
            # with async dispatch.
            device_params[key] = jnp.array(host_view, copy=True)
            ticket.release()                              # slot back to pool
        return device_params

    # -- checkpoint offload ----------------------------------------------------------

    def _save_checkpoint(self, h) -> tuple:
        if self.policy.offload_checkpoints:
            host = np.asarray(h)   # D2H into host memory
            handle = self.tracker.alloc("activation_checkpoints", host.nbytes,
                                        tag="block_input")
            return ("host", host, handle, h.dtype)
        return ("device", h, None, h.dtype)

    def _restore_checkpoint(self, ckpt):
        kind, payload, handle, dtype = ckpt
        if kind == "host":
            arr = jnp.asarray(payload, dtype=dtype)
            self.tracker.free(handle)
            return arr
        return payload

    # -- the step -------------------------------------------------------------------

    def train_step(self, tokens: np.ndarray, labels: np.ndarray) -> dict:
        model, meta_list = self.model, self._unit_param_meta
        embed_unit, embed_meta = meta_list[0]
        head_unit, head_meta = meta_list[-1]
        block_list = meta_list[1:-1]

        # ---- forward, block-streamed ----
        params = self._fetch_unit_device_params(embed_unit, embed_meta)
        h = self._jit_embed(params, jnp.asarray(tokens))
        del params
        checkpoints = []
        for unit, meta in block_list:
            checkpoints.append(self._save_checkpoint(h))
            params = self._fetch_unit_device_params(unit, meta)
            h = self._jit_block(params, h)
            del params

        # ---- head loss + initial cotangent ----
        params = self._fetch_unit_device_params(head_unit, head_meta)
        loss, head_grads, dh = self._jit_head(
            params, h, jnp.asarray(labels), jnp.asarray(
                self.scaler.scale, dtype=jnp.float32))
        del params
        self._write_grads(head_unit, head_meta, head_grads)

        # ---- backward, reverse block-streamed (recompute via vjp) ----
        for (unit, meta), ckpt in zip(reversed(block_list),
                                      reversed(checkpoints)):
            x = self._restore_checkpoint(ckpt)
            params = self._fetch_unit_device_params(unit, meta)
            dparams, dh = self._jit_block_bwd(params, x, dh)
            del params
            self._write_grads(unit, meta, dparams)

        # ---- embedding backward ----
        params = self._fetch_unit_device_params(embed_unit, embed_meta)
        dembed = self._jit_embed_bwd(params, jnp.asarray(tokens), dh)
        del params
        self._write_grads(embed_unit, embed_meta, dembed)

        # ---- overflow check on the flat buffer ----
        if self.policy.fused_overflow:
            overflowed = fused_overflow_check(self.flat, tracker=self.tracker)
        else:
            overflowed = baseline_overflow_check(self.flat, tracker=self.tracker)
        apply_step = self.scaler.update(overflowed)

        # ---- host optimizer, subgroup-streamed ----
        if apply_step:
            self.optimizer.begin_step()
            inv_scale = 1.0 / self.scaler.scale
            for unit, meta in meta_list:
                for key, (shape, size) in meta.items():
                    skey = f"{unit.name}/{key}"
                    off, size, shape = self._flat_offsets[skey]
                    grad = self.flat[off:off + size].reshape(shape) * np.float32(
                        inv_scale)
                    self.optimizer.step_subgroup(skey, grad)

        return {
            "loss": float(loss),
            "overflowed": overflowed,
            "applied": apply_step,
            "loss_scale": self.scaler.scale,
            "optimizer_io_bytes": self.optimizer.last_io_bytes,
            "peak_host_bytes": self.tracker.peak_allocated,
        }

    def _write_grads(self, unit: OffloadUnit, meta: dict, grads: dict) -> None:
        """Accumulate device grads into the fp32 host flat buffer."""
        for key in meta:
            off, size, shape = self._flat_offsets[f"{unit.name}/{key}"]
            g = np.asarray(grads[key], dtype=np.float32).reshape(-1)  # D2H
            self.flat[off:off + size] = g

    # -- eval / weights access ---------------------------------------------------------

    def eval_loss(self, tokens: np.ndarray, labels: np.ndarray) -> float:
        meta_list = self._unit_param_meta
        params = self._fetch_unit_device_params(*meta_list[0])
        h = self._jit_embed(params, jnp.asarray(tokens))
        for unit, meta in meta_list[1:-1]:
            params = self._fetch_unit_device_params(unit, meta)
            h = self._jit_block(params, h)
        params = self._fetch_unit_device_params(*meta_list[-1])
        loss = jax.jit(self.model.head_loss)(params, h, jnp.asarray(labels))
        return float(loss)

    def master_param(self, unit_name: str, key: str) -> np.ndarray:
        meta = next(m for u, m in self._unit_param_meta if u.name == unit_name)
        shape, _ = meta[key]
        sd = self.policy.adam.state_np_dtype
        return self.store.read_new(f"{unit_name}/{key}.master", sd, shape)

    def close(self) -> None:
        self.swapper.drain()
        self.pool.close()
        self._flat_buf.free()
        self.store.close()
