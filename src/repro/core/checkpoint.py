"""Checkpointing: save/restore parameter pytrees through a TensorStore.

In a MemAscend deployment the SSD store already holds the authoritative
training state (fp32 masters + optimizer moments, updated in place every
step) — checkpointing is a *manifest* plus optional export, not a copy of
device memory.  This module provides:

* :func:`save_pytree` / :func:`load_pytree` — write/read any jax/numpy
  pytree through a store (keys derived from tree paths, manifest with
  shapes/dtypes/treedef serialized alongside),
* :func:`snapshot_trainer` / :func:`restore_trainer_step` — persist the
  OffloadedTrainer's scalar state (step count, loss-scale) so a run can
  resume against its existing store.
"""

from __future__ import annotations

import json

import numpy as np

from .nvme import TensorStore

MANIFEST_KEY = "__manifest__"


def _path_key(path) -> str:
    parts = []
    for entry in path:
        if hasattr(entry, "key"):
            parts.append(str(entry.key))
        elif hasattr(entry, "idx"):
            parts.append(str(entry.idx))
        else:
            parts.append(str(entry))
    return "/".join(parts)


def save_pytree(store: TensorStore, prefix: str, tree) -> dict:
    """Write every leaf of ``tree`` to the store; returns the manifest."""
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"leaves": {}}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        key = f"{prefix}/{_path_key(path)}"
        store.write(key, arr)
        manifest["leaves"][_path_key(path)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape)}
    blob = np.frombuffer(json.dumps(manifest).encode(), dtype=np.uint8).copy()
    store.write(f"{prefix}/{MANIFEST_KEY}", blob)
    return manifest


def load_manifest(store: TensorStore, prefix: str) -> dict:
    # manifest size is unknown; stores record sizes internally for the raw
    # engine; for both engines we re-serialize via a probe: keep it simple
    # by requiring the caller to know nothing — read via stored metadata.
    key = f"{prefix}/{MANIFEST_KEY}"
    if hasattr(store, "_locations"):       # DirectNVMeEngine
        nbytes = sum(e.length for e in store._locations[key][2])
    else:                                   # FilesystemEngine
        import os
        nbytes = os.path.getsize(store._path(key))
    raw = store.read_new(key, np.uint8, (nbytes,))
    return json.loads(bytes(raw).decode())


def load_pytree(store: TensorStore, prefix: str, like):
    """Read a pytree previously saved with :func:`save_pytree`.

    ``like`` supplies the treedef (any pytree with the same structure,
    e.g. from ``jax.eval_shape`` of the init function).
    """
    import jax
    import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)
    manifest = load_manifest(store, prefix)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in flat:
        meta = manifest["leaves"][_path_key(path)]
        arr = store.read_new(f"{prefix}/{_path_key(path)}",
                             np.dtype(meta["dtype"]), tuple(meta["shape"]))
        leaves.append(arr)
    # treedef from tree_flatten (ignores paths)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _drain_pipeline(trainer) -> None:
    """Under full overlap an optimizer stage may still be streaming; the
    scalar state (step count) and the on-store masters are only coherent
    once it lands."""
    sync = getattr(trainer, "synchronize", None)
    if callable(sync):
        sync()


def snapshot_trainer(trainer, prefix: str = "ckpt") -> None:
    """Persist the trainer's scalar state; tensor state already lives on
    the store (masters/moments are updated in place each step)."""
    _drain_pipeline(trainer)
    state = {
        "optimizer_step": trainer.optimizer.step_count,
        "loss_scale": trainer.scaler.scale,
        "n_overflows": trainer.scaler.n_overflows,
        "n_steps": trainer.scaler.n_steps,
    }
    blob = np.frombuffer(json.dumps(state).encode(), np.uint8).copy()
    trainer.store.write(f"{prefix}/trainer_state", blob)


def restore_trainer_step(trainer, prefix: str = "ckpt") -> dict:
    _drain_pipeline(trainer)
    key = f"{prefix}/trainer_state"
    if hasattr(trainer.store, "_locations"):
        nbytes = sum(e.length for e in trainer.store._locations[key][2])
    else:
        import os
        nbytes = os.path.getsize(trainer.store._path(key))
    raw = trainer.store.read_new(key, np.uint8, (nbytes,))
    state = json.loads(bytes(raw).decode())
    trainer.optimizer.step_count = state["optimizer_step"]
    trainer.scaler.scale = state["loss_scale"]
    trainer.scaler.n_overflows = state["n_overflows"]
    trainer.scaler.n_steps = state["n_steps"]
    return state
