"""Parameter buffer pools: the paper's §III-A (problem) and §IV-B (fix).

SSD-offloaded training streams layer weights SSD→host→device with several
transformer blocks "in flight" (prefetch depth N).  The host staging region
is a *pool* of pinned buffers:

* **FixedBufferPool** (ZeRO-Infinity baseline): every slot is sized to the
  *largest* tensor in the model — almost always the embedding
  (vocab × hidden).  FFN/attention tensors are 10–100× smaller, so the pool
  carries massive internal fragmentation (paper: 70.82% for Llama-3 8B).

* **AdaptiveBufferPool** (MemAscend): one subpool per *shape class*
  (embed/LM-head, FFN projections, KV projections, QO projections, expert
  FFNs, SSM params, ...), each slot sized exactly to its class.  Following
  the paper, the subpools live inside ONE monolithic arena allocated up
  front, with a hashtable of {key -> (offset, size)} metadata, so management
  cost matches the baseline.

Both pools draw their arena through a pinned allocator
(:mod:`repro.core.pinned_alloc`), so the pow2-vs-exact policy compounds with
the pool policy exactly as in the paper's Fig. 8.

The census describing "what tensors does one model stream, and how many are
concurrently live" comes from the model config
(:func:`repro.configs.base.ModelConfig.pool_census`).
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

from .pinned_alloc import PinnedAllocatorBase, PinnedBuffer


@dataclass(frozen=True)
class ShapeClass:
    """One class of streamed tensors.

    ``per_block``:  tensors of this class needed per in-flight transformer
                    block (0 for standalone classes like the embedding).
    ``standalone``: tensors of this class that exist once per model and need
                    a dedicated slot (embedding, LM head).
    """

    name: str
    nbytes: int          # max payload bytes of a tensor in this class
    per_block: int = 0
    standalone: int = 0

    def slots(self, inflight_blocks: int) -> int:
        return self.per_block * inflight_blocks + self.standalone


# Shape class of KV-cache page slots (offloaded cached decode).  KV state
# streams through the same arena as the weights it attends against, but its
# slots are *persistent across steps* (a SpillableKVCache keeps them checked
# out and spills cold time-axis pages to SSD) rather than released at H2D.
# The same name doubles as the staged-KV device-slot class in the overlap
# executor's DeviceSlots budget.
KV_CLASS = "kv"


@dataclass(frozen=True)
class PoolCensus:
    """Shape-class census for one model (one data-parallel shard thereof)."""

    classes: tuple[ShapeClass, ...]
    inflight_blocks: int = 2   # prefetch depth N (paper uses small N)

    @property
    def max_tensor_bytes(self) -> int:
        return max(c.nbytes for c in self.classes)

    @property
    def total_slots(self) -> int:
        return sum(c.slots(self.inflight_blocks) for c in self.classes)

    def scaled(self, shard_count: int) -> "PoolCensus":
        """Census for one of ``shard_count`` ZeRO parameter partitions."""
        return PoolCensus(
            tuple(ShapeClass(c.name, -(-c.nbytes // shard_count), c.per_block,
                             c.standalone) for c in self.classes),
            self.inflight_blocks)

    def with_kv(self, nbytes: int, slots: int) -> "PoolCensus":
        """Census extended with ``slots`` dedicated KV-cache slots of
        ``nbytes`` each (one slot holds one time-axis *page* of one
        layer's K+V state — ``DecodeSpec.page_size`` tokens).

        The slots are standalone — their count is the *host-residency
        budget* for cached decode, not a per-inflight-block multiple; pages
        beyond it spill to SSD (see :mod:`repro.core.kv_cache`)."""
        if nbytes <= 0 or slots <= 0:
            raise ValueError(f"kv census needs nbytes>0 and slots>0, got "
                             f"nbytes={nbytes}, slots={slots}")
        if any(c.name == KV_CLASS for c in self.classes):
            raise ValueError(f"census already has a {KV_CLASS!r} class")
        return PoolCensus(
            self.classes + (ShapeClass(KV_CLASS, nbytes, standalone=slots),),
            self.inflight_blocks)


class PoolBuffer:
    """A checked-out pool slot; payload is a slice of the arena."""

    __slots__ = ("pool", "class_name", "slot_index", "offset", "capacity",
                 "requested", "tag", "released")

    def __init__(self, pool, class_name, slot_index, offset, capacity,
                 requested, tag):
        self.pool = pool
        self.class_name = class_name
        self.slot_index = slot_index
        self.offset = offset
        self.capacity = capacity
        self.requested = requested
        self.tag = tag
        self.released = False

    def view(self, dtype, shape):
        """Typed numpy view of this slot (numpy-backed pools only)."""
        import numpy as np
        arena = self.pool.arena
        if arena is None:
            raise RuntimeError("accounting-mode pool has no storage")
        nbytes = int(np.dtype(dtype).itemsize * np.prod(shape, dtype=np.int64))
        if nbytes > self.capacity:
            raise ValueError(
                f"view {nbytes} B > slot capacity {self.capacity} B "
                f"(class {self.class_name})")
        return arena[self.offset:self.offset + nbytes].view(dtype).reshape(shape)

    def release(self) -> None:
        self.pool.release(self)


class BufferPoolBase:
    """Slot management over a single monolithic pinned arena."""

    def __init__(self, census: PoolCensus, allocator: PinnedAllocatorBase,
                 *, name: str = "param_buffer_pool") -> None:
        self.census = census
        self.allocator = allocator
        self.name = name
        self._lock = threading.Condition()
        # subclass fills these (slot sizes/counts are immutable once
        # _layout returns; only the free lists mutate afterwards):
        self._slot_size: dict[str, int] = {}       # class -> slot bytes
        self._free_slots: dict[str, list[tuple[int, int]]] = {}  # guarded-by: _lock
        self._total_slots: dict[str, int] = {}
        self._layout()  # -> sets the above + self.pool_bytes
        self._arena_buf: PinnedBuffer = self.allocator.alloc(
            self.pool_bytes, tag=name)
        # fragmentation accounting
        self.in_use_payload = 0        # guarded-by: _lock
        self.peak_in_use_payload = 0   # guarded-by: _lock
        self.in_use_reserved = 0       # guarded-by: _lock
        self.peak_in_use_reserved = 0  # guarded-by: _lock
        # hashtable metadata, as in the paper: tag -> live PoolBuffers.
        # A tag can be checked out more than once concurrently (a unit's
        # forward ticket still staging while its backward re-fetch is
        # issued inside a deep lookahead window), so each entry is a list —
        # a plain {tag: buf} map silently overwrote the first buffer's
        # record and the first release then dropped the wrong one.
        self._live: dict[str, list[PoolBuffer]] = {}  # guarded-by: _lock

    # -- subclass interface --------------------------------------------------

    def _layout(self) -> None:  # analyze: pre-share
        raise NotImplementedError

    def _class_for(self, class_name: str) -> str:
        """Map a request's shape class to the backing slot class."""
        raise NotImplementedError

    # -- API -----------------------------------------------------------------

    @property
    def arena(self):
        return self._arena_buf.array  # None in accounting mode

    def acquire(self, class_name: str, nbytes: int, *, tag: str = "",
                timeout: float | None = 30.0) -> PoolBuffer:  # thread: any
        """Check out a slot able to hold ``nbytes`` of class ``class_name``.

        Blocks until a slot frees up (the prefetch pipeline naturally
        backpressures on pool capacity, as in ZeRO-Infinity).
        """
        slot_class = self._class_for(class_name)
        size = self._slot_size[slot_class]
        if nbytes > size:
            raise ValueError(
                f"tensor {tag!r} ({nbytes} B) exceeds slot size {size} B of "
                f"class {slot_class!r}")
        with self._lock:
            ok = self._lock.wait_for(
                lambda: bool(self._free_slots[slot_class]), timeout=timeout)
            if not ok:
                raise TimeoutError(
                    f"buffer pool exhausted for class {slot_class!r} "
                    f"({self._total_slots[slot_class]} slots)")
            idx, offset = self._free_slots[slot_class].pop()
            buf = PoolBuffer(self, slot_class, idx, offset, size, nbytes, tag)
            self.in_use_payload += nbytes
            self.in_use_reserved += size
            self.peak_in_use_payload = max(self.peak_in_use_payload,
                                           self.in_use_payload)
            self.peak_in_use_reserved = max(self.peak_in_use_reserved,
                                            self.in_use_reserved)
            if tag:
                self._live.setdefault(tag, []).append(buf)
            return buf

    def release(self, buf: PoolBuffer) -> None:  # thread: any
        with self._lock:
            if buf.released:
                raise ValueError(f"double release of pool slot {buf.tag!r}")
            buf.released = True
            self._free_slots[buf.class_name].append((buf.slot_index, buf.offset))
            self.in_use_payload -= buf.requested
            self.in_use_reserved -= buf.capacity
            live = self._live.get(buf.tag)
            if live is not None:
                with contextlib.suppress(ValueError):
                    live.remove(buf)    # this buffer's record, not the tag's
                if not live:
                    del self._live[buf.tag]
            self._lock.notify_all()

    def close(self) -> None:
        self._arena_buf.free()

    # -- reporting -------------------------------------------------------------

    def fragmentation(self) -> float:  # thread: any
        """Internal fragmentation: 1 − (peak payload / pool size).

        This is the paper's metric: the pool reserves ``pool_bytes`` but the
        maximum payload ever resident is ``peak_in_use_payload``.
        """
        with self._lock:
            return self._fragmentation_locked()

    def _fragmentation_locked(self) -> float:  # analyze: holds(_lock)
        if self.pool_bytes == 0:
            return 0.0
        return 1.0 - self.peak_in_use_payload / self.pool_bytes

    def stats(self) -> dict:  # thread: any
        # Snapshot under the lock: a mid-acquire read tore peak counters
        # against the free-list (observed as transient >100% utilisation
        # in metrics sampled from the serve scheduler thread).
        with self._lock:
            return {
                "pool_bytes": self.pool_bytes,
                "arena_reserved_bytes": self._arena_buf.capacity,
                "peak_in_use_payload": self.peak_in_use_payload,
                "peak_in_use_reserved": self.peak_in_use_reserved,
                "fragmentation": self._fragmentation_locked(),
                "slots": dict(self._total_slots),
                "slot_size": dict(self._slot_size),
            }


class FixedBufferPool(BufferPoolBase):
    """ZeRO-Infinity baseline: every slot sized to the largest tensor."""

    SLOT_CLASS = "__monolithic__"

    def _layout(self) -> None:  # analyze: pre-share
        slab = self.census.max_tensor_bytes
        n = self.census.total_slots
        self._slot_size = {self.SLOT_CLASS: slab}
        self._total_slots = {self.SLOT_CLASS: n}
        self._free_slots = {
            self.SLOT_CLASS: [(i, i * slab) for i in reversed(range(n))]}
        self.pool_bytes = slab * n

    def _class_for(self, class_name: str) -> str:
        return self.SLOT_CLASS


class AdaptiveBufferPool(BufferPoolBase):
    """MemAscend: per-shape-class subpools inside one arena (paper §IV-B)."""

    def _layout(self) -> None:  # analyze: pre-share
        self._slot_size = {}
        self._total_slots = {}
        self._free_slots = {}
        offset = 0
        for cls in self.census.classes:
            n = cls.slots(self.census.inflight_blocks)
            if n == 0:
                continue
            self._slot_size[cls.name] = cls.nbytes
            self._total_slots[cls.name] = n
            slots = []
            for i in reversed(range(n)):
                slots.append((i, offset + i * cls.nbytes))
            self._free_slots[cls.name] = slots
            offset += n * cls.nbytes
        self.pool_bytes = offset

    def _class_for(self, class_name: str) -> str:
        if class_name not in self._slot_size:
            raise KeyError(
                f"unknown shape class {class_name!r}; census has "
                f"{sorted(self._slot_size)}")
        return class_name
