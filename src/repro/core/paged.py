"""Shared paged-residency layer: the page machinery behind both the KV
cache and the expert-weight pager.

PRs 5–9 grew a page-granular residency engine inside the KV cache — a
block table of pool slots, MRU eviction (Belady under cyclic access),
dirty tracking, pin refcounts, prefetched refills, and an in-transit
capacity ledger that keeps two ensuring threads from oversubscribing the
pool.  None of that is KV-specific: the same machinery pages any set of
fixed-shape host tensors through a bounded pinned-slot budget with SSD as
the backing tier.

This module hoists that engine into :class:`PagedResidency`, keyed by
opaque page keys, with two page classes on top:

* :class:`~repro.core.kv_cache.SpillableKVCache` — keys are
  ``(unit, batch_slot, page_index)`` time-axis pages of decode state
  (read-write: decode dirties tail pages, eviction writes them back);
* :class:`ExpertPageCache` — keys are ``(unit, param_name)`` per-expert
  weight tensors of a MoE block (read-only: the SSD ``.compute`` copy the
  optimizer maintains is authoritative, so eviction is always a free
  ``clean_drop`` and a page is re-readable forever — every key is born
  spilled).

Thread contract
---------------

Same as the KV cache it was extracted from: all page/slot bookkeeping
lives under one non-reentrant lock (``_spill`` releases it around the
dirty-page store write, which only balances if no path ever acquires it
twice).  Two threads may ensure/evict concurrently (compute thread +
H2D staging worker), so a page view is only written or copied while
**pinned** — eviction skips pinned pages.  ``close`` must only run after
any staging worker has drained.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import numpy as np

from .buffer_pool import BufferPoolBase, PoolBuffer
from .nvme import TensorStore

# Pool shape class of expert weight pages (route-aware MoE streaming).
# Expert tensors of a paged-MoE unit leave the per-block streaming census
# and become standalone page slots sized by the expert-residency budget,
# exactly as KV_CLASS slots are sized by DecodeSpec's page budget.
EXPERT_PAGE_CLASS = "expert"


@dataclass
class PageStats:
    """Spill-pipeline effectiveness counters for one page class.

    All byte counters are page-granular: ``spill_bytes`` counts only
    *dirty* page writes (``clean_drops`` pages were evicted for free —
    their bytes were already on SSD and unchanged)."""

    spills: int = 0            # dirty page written to SSD + slot released
    clean_drops: int = 0       # clean page evicted without a write
    refills: int = 0           # SSD page read back into a slot (any path)
    prefetch_refills: int = 0  # refills issued ahead of use
    prefetch_hits: int = 0     # refill already complete when asked for
    sync_refills: int = 0      # ensure found nothing in flight
    spill_bytes: int = 0
    refill_bytes: int = 0
    wait_seconds: float = 0.0  # time blocked on outstanding refills

    _FIELDS = ("spills", "clean_drops", "refills", "prefetch_refills",
               "prefetch_hits", "sync_refills", "spill_bytes",
               "refill_bytes", "wait_seconds")

    def snapshot(self) -> dict:
        return {k: getattr(self, k) for k in self._FIELDS}


class PagedResidency:
    """Page-granular residency of fixed-shape host tensors in pool slots,
    spilled to / refilled from an SSD tensor store past a slot budget.

    Subclasses name the pages: they provide the store key, shape, dtype
    and byte size of a page via the ``_page_*`` hooks, own the public API
    (which validates user-facing arguments and builds opaque keys), and
    may reach into the protected maps for lifecycle surgery the generic
    layer does not know about (slot retirement, rollback, invalidation) —
    provided they follow the same locking and ``_in_transit`` discipline.
    """

    # error-string labels; subclasses override so messages keep naming
    # the concrete cache ("KV cache is closed", "expert cache is closed")
    _cache_label = "page cache"
    _page_label = "page"

    def __init__(self, pool: BufferPoolBase, store: TensorStore, *,
                 pool_class: str, total_pages: int,
                 resident_limit: int | None, stats: PageStats) -> None:
        self.pool = pool
        self.store = store
        self.pool_class = pool_class
        self.resident_limit = total_pages if resident_limit is None else \
            min(resident_limit, total_pages)
        if self.resident_limit < total_pages and self.resident_limit < 2:
            raise ValueError(
                f"resident_limit {self.resident_limit} < 2 cannot stream "
                f"{total_pages} pages (one page pinned for a copy, one "
                f"turning over)")
        # Below budget every page stays resident; at budget, reserve two
        # slots for the (in use, prefetching) pair cycling the cold pages.
        self._keep = total_pages if self.resident_limit >= total_pages \
            else max(0, self.resident_limit - 2)
        self.stats = stats                 # guarded-by: _lock
        self.closed = False                # guarded-by: _lock
        # A Condition, not a bare Lock: with two ensuring threads (compute
        # + staging worker) capacity can be transiently held entirely by
        # in-flight refills and mid-read ensures — a thread needing a slot
        # then waits for the next land/unpin/spill instead of failing.
        # Backed by a NON-reentrant Lock on purpose: _spill releases it
        # around the dirty-page store write, which only balances if no
        # path ever acquires it twice (an accidental nested acquire should
        # deadlock loudly, not silently unlock early).
        self._lock = threading.Condition(threading.Lock())
        # every map below is page/slot bookkeeping and lives under the
        # one lock; keys are subclass-defined opaque tuples
        self._slots: dict[tuple, PoolBuffer] = {}     # guarded-by: _lock
        self._futures: dict[tuple, tuple[PoolBuffer, Future]] = {}  # guarded-by: _lock
        self._spilled: set[tuple] = set()    # guarded-by: _lock
        self._dirty: set[tuple] = set()      # guarded-by: _lock
        self._evicting: set[tuple] = set()   # guarded-by: _lock
        self._pinned: dict[tuple, int] = {}  # guarded-by: _lock
        self._use_order: list[tuple] = []    # guarded-by: _lock
        # Pages whose buffer is held by an ensure mid-read (popped out of
        # _futures / freshly acquired, not yet landed in _slots).  Two
        # threads ensure concurrently (compute + staging worker), so
        # capacity math must count these or the pool oversubscribes.
        self._in_transit = 0               # guarded-by: _lock

    # -- subclass page-naming hooks -------------------------------------------

    def _store_key_of(self, key: tuple) -> str:
        raise NotImplementedError

    def _page_shape_of(self, key: tuple) -> tuple:
        raise NotImplementedError

    def _page_dtype_of(self, key: tuple) -> np.dtype:
        raise NotImplementedError

    def _page_nbytes_of(self, key: tuple) -> int:
        raise NotImplementedError

    # -- internals -----------------------------------------------------------

    def _touch(self, key: tuple) -> None:  # analyze: holds(_lock)
        if key in self._use_order:
            self._use_order.remove(key)
        self._use_order.append(key)

    def _acquire(self, key: tuple) -> PoolBuffer:  # analyze: holds(_lock)
        # Budget is self-managed: resident + in-flight never exceeds
        # resident_limit (the census slot count), so this never blocks —
        # a pool wait here would mean the capacity ledger is wrong, and
        # the 30s acquire timeout turns that bug into a loud failure.
        return self.pool.acquire(self.pool_class, self._page_nbytes_of(key),  # analyze: ignore[lock-blocking]
                                 tag=self._store_key_of(key))

    def _free_capacity(self) -> int:  # analyze: holds(_lock)
        return (self.resident_limit - len(self._slots) - len(self._futures)
                - self._in_transit)

    def _materialized(self, key: tuple) -> bool:  # analyze: holds(_lock)
        return (key in self._slots or key in self._futures
                or key in self._spilled or key in self._evicting)

    def _try_spill_one(self, exclude: set) -> bool:  # analyze: holds(_lock)
        """Evict the most-recently-used resident page (Belady under cyclic
        access) that is neither excluded nor pinned; False when every
        resident page is pinned/excluded (the caller waits for capacity)."""
        for key in reversed(self._use_order):
            if (key in self._slots and key not in exclude
                    and not self._pinned.get(key)):
                self._spill(key)
                return True
        return False

    def _spill(self, key: tuple) -> None:  # analyze: holds(_lock)
        """Evict one resident page.  Called with the lock held; a dirty
        page's store write runs with the lock RELEASED so the other
        thread can keep gathering/appending meanwhile — the page sits in
        ``_evicting`` for the duration (materialized-but-busy: ensure
        waits it out, eviction scans cannot see it).  A failed write puts
        the page back resident + dirty: the host copy is the only one."""
        buf = self._slots.pop(key)
        self._use_order.remove(key)
        if key in self._dirty:
            self._dirty.discard(key)
            self._evicting.add(key)
            self._in_transit += 1     # slot still held during the write
            self._lock.release()
            ok = False
            try:
                view = buf.view(self._page_dtype_of(key),
                                self._page_shape_of(key))
                self.store.write(self._store_key_of(key), view)
                ok = True
            finally:
                self._lock.acquire()
                self._evicting.discard(key)
                self._in_transit -= 1
                if not ok:
                    # failed write: the host copy is the only one — put
                    # the page back resident (and dirty) rather than leak
                    # the slot or forget the data; the error propagates
                    self._slots[key] = buf
                    self._use_order.append(key)
                    self._dirty.add(key)
                    self._lock.notify_all()
            self.stats.spills += 1
            self.stats.spill_bytes += self._page_nbytes_of(key)
        else:
            # clean page: its bytes already live on SSD, unchanged — the
            # paged design's whole point is that this write is free
            self.stats.clean_drops += 1
        buf.release()
        self._spilled.add(key)
        self._lock.notify_all()   # freed capacity: wake slot waiters

    def _maybe_spill_after_use(self) -> None:
        """Spill-after-use: once a unit's use is done, its pages' next use
        is a full cycle away — evict MRU pages over the keep line (skipping
        pinned pages; a concurrent gather may hold one mid-copy)."""
        with self._lock:
            while len(self._slots) > self._keep:
                if not self._try_spill_one(exclude=set()):
                    break

    def _prefetch_one(self, key: tuple) -> bool:  # analyze: holds(_lock)
        """Issue one async SSD refill for a spilled page into a free slot.
        No-op (True) for non-spilled/in-flight pages; False when fewer
        than two slots are free (the caller stops prefetching — one slot
        stays in reserve so a concurrent fresh-page write can always
        evict its way to a slot)."""
        if (key not in self._spilled or key in self._slots
                or key in self._futures):
            return True
        if self._free_capacity() < 2:
            return False
        buf = self._acquire(key)
        try:
            view = buf.view(self._page_dtype_of(key),
                            self._page_shape_of(key))
            future = self.store.read_async(self._store_key_of(key), view)
        except BaseException:
            # failed issue: the key is still in _spilled (the SSD copy is
            # intact) — only the slot must go back
            buf.release()
            raise
        self._futures[key] = (buf, future)
        self._spilled.discard(key)
        self.stats.prefetch_refills += 1
        return True

    def _ensure(self, key: tuple, *,
                pin: bool = False) -> np.ndarray:  # thread: executor, h2d-worker
        """Host view of one page, resident.  Waits out an in-flight refill;
        synchronously refills a spilled page; acquires (and zero-fills) a
        fresh slot for a never-written page.  With ``pin=True`` the page is
        returned pinned (evictions skip it) — the caller MUST unpin after
        its copy/write; writers must also mark the page dirty before
        unpinning or the write may be lost to a clean eviction."""
        with self._lock:
            if self.closed:
                raise RuntimeError(f"{self._cache_label} is closed")
            # A page mid-spill (dirty write in flight on the other thread,
            # lock dropped) is materialized but in no map: wait for the
            # write to land, then take the _spilled path below.
            while key in self._evicting:
                if not self._lock.wait(timeout=30.0):
                    raise RuntimeError(
                        f"{self._page_label} {key!r} stuck in eviction "
                        f"for 30s")
            entry = self._futures.pop(key, None)
            spilled = key in self._spilled
            if entry is not None:
                buf, future = entry
                hit = future.done()
            elif key in self._slots:
                self._touch(key)
                if pin:
                    self._pinned[key] = self._pinned.get(key, 0) + 1
                return self._slots[key].view(self._page_dtype_of(key),
                                             self._page_shape_of(key))
            else:
                # Sync path: spilled (refill now) or first touch (zero).
                # When no page is evictable (all pinned, or the capacity
                # sits in other pages' in-flight refills / mid-read
                # ensures), wait: the other thread's land/unpin frees it.
                while self._free_capacity() < 1:
                    if (not self._try_spill_one(exclude={key})
                            and not self._lock.wait(timeout=30.0)):
                        raise RuntimeError(
                                f"{self._cache_label} slot wait timed out "
                                f"for page {key!r}: every slot pinned or "
                                f"in flight for 30s (budget "
                                f"{self.resident_limit})")
                buf = self._acquire(key)
                future = None
                hit = False
            self._in_transit += 1   # buf held outside _slots/_futures
        t0 = time.perf_counter()
        try:
            view = buf.view(self._page_dtype_of(key),
                            self._page_shape_of(key))
            if future is not None:
                future.result()
            elif spilled:
                self.store.read(self._store_key_of(key), view)
            else:
                view[...] = np.zeros((), self._page_dtype_of(key))  # fresh
        except BaseException:
            with self._lock:
                self._in_transit -= 1
                if future is not None:
                    # a failed prefetched refill must not forget the page:
                    # the SSD copy is still valid (_prefetch_one removed
                    # the key from _spilled when it issued the read) — the
                    # sync path below keeps _spilled until success, this
                    # mirrors it so a retry refills instead of zero-fills
                    self._spilled.add(key)
                self._lock.notify_all()
            buf.release()   # slot must not leak on a failed read
            raise
        wait = time.perf_counter() - t0
        # Counters strictly under the lock: the staging worker and the
        # compute thread both run ensure/prefetch while refills land from
        # store workers — unlocked read-modify-writes tore the ledger.
        with self._lock:
            if future is not None:
                self.stats.refills += 1
                self.stats.refill_bytes += self._page_nbytes_of(key)
                self.stats.prefetch_hits += int(hit)
            elif spilled:
                self.stats.refills += 1
                self.stats.refill_bytes += self._page_nbytes_of(key)
                self.stats.sync_refills += 1
            self.stats.wait_seconds += wait
            self._in_transit -= 1
            self._spilled.discard(key)
            self._slots[key] = buf
            self._touch(key)
            if pin:
                self._pinned[key] = self._pinned.get(key, 0) + 1
            self._lock.notify_all()   # landed page is evictable again
        return view

    def _unpin(self, key: tuple) -> None:  # thread: executor, h2d-worker
        """Release one pin on a page (see :meth:`_ensure`)."""
        with self._lock:
            n = self._pinned.get(key, 0) - 1
            if n <= 0:
                self._pinned.pop(key, None)
                self._lock.notify_all()   # page is evictable again
            else:
                self._pinned[key] = n

    def close(self) -> None:  # thread: executor
        """Wait out in-flight refills and return every slot.  Idempotent;
        runs on error paths, so nothing may leak.  Callers must drain any
        worker still gathering first (the session's abort path does) —
        close does not wait for pins."""
        with self._lock:
            if self.closed:
                return
            self.closed = True
            futures = list(self._futures.values())
            self._futures.clear()
            slots = list(self._slots.values())
            self._slots.clear()
            self._use_order.clear()
            self._dirty.clear()
            self._pinned.clear()
        for buf, future in futures:
            try:
                future.result()
            except BaseException:
                pass            # data is being discarded
            finally:
                buf.release()
        for buf in slots:
            buf.release()


class ExpertPageCache(PagedResidency):
    """Per-expert weight tensors of paged-MoE units as individually
    fetchable pages.

    Page key = ``(unit_name, param_name)`` — e.g.
    ``("block_000", "moe.expert3.w_gate")``.  The backing bytes are the
    same ``{unit}/{param}.compute`` SSD copies the offloaded optimizer
    commits after each Adam step, so:

    * every key is born **spilled** (the SSD copy exists before the first
      fetch — the session writes initial compute-precision params during
      construction);
    * pages are never dirtied — eviction is always a free ``clean_drop``,
      refill is always a plain read of the authoritative copy;
    * after a unit's optimizer commit rewrites its ``.compute`` keys, the
      session calls :meth:`invalidate_unit` so stale resident pages are
      dropped back to spilled and the next fetch rereads fresh bytes.

    Thread contract: :meth:`ensure` / :meth:`unpin` run on the executor
    and the H2D staging worker (the expert stage task pins pages while
    building the routed stack); :meth:`prefetch` runs on the executor
    inside the lookahead window; :meth:`invalidate_unit` runs on the
    executor or the optimizer worker, strictly after the unit's expert
    stage for the step has drained (the readiness-gate ordering in the
    session guarantees no pins or in-flight reads for that unit).
    """

    _cache_label = "expert cache"
    _page_label = "expert page"

    def __init__(self, pages: dict[tuple[str, str], tuple],
                 dtype, pool: BufferPoolBase, store: TensorStore, *,
                 resident_limit: int | None = None,
                 store_suffix: str = "") -> None:
        """``pages`` maps ``(unit, param_name) -> shape``; ``store_suffix``
        is appended to ``{unit}/{param}`` when addressing the store (the
        session passes the optimizer's compute-copy suffix)."""
        if not pages:
            raise ValueError("expert cache needs at least one page")
        self._shapes = {tuple(k): tuple(v) for k, v in pages.items()}
        self.dtype = np.dtype(dtype)
        self._nbytes = {k: int(self.dtype.itemsize
                               * np.prod(s, dtype=np.int64))
                        for k, s in self._shapes.items()}
        self.page_nbytes = max(self._nbytes.values())
        self.store_suffix = store_suffix
        super().__init__(pool, store, pool_class=EXPERT_PAGE_CLASS,
                         total_pages=len(self._shapes),
                         resident_limit=resident_limit, stats=PageStats())
        # the SSD compute copies are authoritative and already written:
        # every page starts spilled (fetchable), none resident
        self._spilled.update(self._shapes)

    # -- page naming ----------------------------------------------------------

    def _store_key_of(self, key: tuple) -> str:
        unit, pname = key
        return f"{unit}/{pname}{self.store_suffix}"

    def _page_shape_of(self, key: tuple) -> tuple:
        return self._shapes[key]

    def _page_dtype_of(self, key: tuple) -> np.dtype:
        return self.dtype

    def _page_nbytes_of(self, key: tuple) -> int:
        return self._nbytes[key]

    # -- the session-facing API ----------------------------------------------

    def ensure(self, unit: str, pname: str, *,
               pin: bool = False) -> np.ndarray:  # thread: executor, h2d-worker
        """Host view of one expert tensor, resident (refilled from its
        SSD compute copy if spilled).  Pin across any copy out of the
        view; unpin via :meth:`unpin`."""
        key = (unit, pname)
        if key not in self._shapes:
            raise KeyError(f"unknown expert page {key!r}")
        return self._ensure(key, pin=pin)

    def unpin(self, unit: str, pname: str) -> None:  # thread: executor, h2d-worker
        self._unpin((unit, pname))

    def prefetch(self, unit: str,
                 pnames: list[str]) -> None:  # thread: executor
        """Hint that ``unit``'s named expert tensors are needed soon:
        issue async SSD refills into free slots, stopping when fewer than
        two slots are free."""
        with self._lock:
            if self.closed:
                return
            for pname in pnames:
                key = (unit, pname)
                if key not in self._shapes:
                    continue
                if not self._prefetch_one(key):
                    return
        self._drain_over_budget()

    def _drain_over_budget(self) -> None:
        """Expert pages are persistent-cold (a unit's next use is a full
        step away), so after each batch of work trim resident pages over
        the keep line — always free clean drops."""
        self._maybe_spill_after_use()

    def release_round(self) -> None:  # thread: executor
        """End of one unit's fetch round: trim MRU pages over the keep
        line so the budget has room for the next unit's pages."""
        self._maybe_spill_after_use()

    def invalidate_unit(self, unit: str) -> None:  # thread: executor, optim-worker
        """Drop a unit's resident and in-flight pages back to spilled —
        called after the unit's optimizer commit rewrote its SSD compute
        copies, so stale host bytes are never served again.  Raises if a
        page is pinned: the caller sequences invalidation strictly after
        the unit's stage work drained."""
        with self._lock:
            if self.closed:
                return
            keys = [k for k in self._shapes if k[0] == unit]
            pinned = [k for k in keys if self._pinned.get(k)]
            if pinned:
                raise RuntimeError(
                    f"invalidate_unit({unit!r}) with pinned pages "
                    f"{pinned!r}: invalidation must run after the unit's "
                    f"expert stage drained")
            fut_entries = [(k, self._futures.pop(k))
                           for k in keys if k in self._futures]
            # popped futures no longer count toward capacity via _futures;
            # hold their slots via _in_transit until the reads settle
            self._in_transit += len(fut_entries)
            dropped = []
            for k in keys:
                if k in self._slots:
                    dropped.append(self._slots.pop(k))
                    self._use_order.remove(k)
                self._spilled.add(k)
        for buf in dropped:
            buf.release()
        for _k, (buf, future) in fut_entries:
            try:
                future.result()   # the async read targets buf: settle first
            except BaseException:
                pass              # stale data is being discarded anyway
            finally:
                buf.release()
        with self._lock:
            self._in_transit -= len(fut_entries)
            self._lock.notify_all()   # freed capacity: wake slot waiters

    @property
    def resident_pages(self) -> list[tuple]:
        """Sorted ``(unit, param)`` keys currently host-resident."""
        with self._lock:
            return sorted(self._slots)
