"""Gradient-overflow checking: the paper's §III-C (problem) / §IV-D (fix).

Mixed-precision training with dynamic loss scaling must test, every
iteration, whether any gradient became Inf/NaN.  The ZeRO-Infinity/PyTorch
baseline does this with a chain of whole-tensor ops on the fp32 gradient
flat buffer::

    abs(G) -> isinf -> any   then   isnan(G) -> any

``isinf`` internally calls ``abs`` first, so the chain materializes a full
fp32 temporary (1.0x) plus boolean masks (0.25x each), pushing peak memory
to ~2.25x the flat buffer (67.3 GiB for an 8B model vs 29.9 GiB payload) and
costing seconds of latency per iteration.

MemAscend's fused check exploits IEEE-754: a value is Inf or NaN **iff its
exponent bits are all ones**.  One bitwise pass over the raw words — no
temporaries, early exit:

    overflow = any((bits & EXP_MASK) == EXP_MASK)

This module provides:

* :func:`baseline_overflow_check` — the faithful chained version.  In
  ``accounting`` mode it charges the temporaries to a MemoryTracker at any
  model scale; in real mode it also executes them on numpy (the host/AVX
  analogue).
* :func:`fused_overflow_check` — single-pass bitwise check, chunked so the
  working set stays cache-resident (the OpenMP-tile analogue), with early
  exit between chunks.
* jnp variants used inside jitted train steps; the TPU Pallas kernel lives in
  :mod:`repro.kernels.overflow_check` and is wrapped by
  :mod:`repro.kernels.ops`.
"""

from __future__ import annotations

import numpy as np

from .memory_tracker import MemoryTracker, GLOBAL_TRACKER

# IEEE-754 exponent masks per dtype (all-ones exponent <=> Inf or NaN).
_EXP_MASK = {
    np.dtype(np.float32): (np.uint32, np.uint32(0x7F80_0000)),
    np.dtype(np.float16): (np.uint16, np.uint16(0x7C00)),
}
# bfloat16: same exponent layout as fp32, packed in the top 16 bits.
_BF16_MASK = np.uint16(0x7F80)

#: chunk size (elements) for the fused pass — 4 MiB of fp32 stays in LLC,
#: mirroring the paper's OpenMP tile.
FUSED_CHUNK = 1 << 20


def _masks_for(dtype: np.dtype):
    dtype = np.dtype(dtype)
    if dtype == np.dtype(np.float32) or dtype == np.dtype(np.float16):
        return _EXP_MASK[dtype]
    # ml_dtypes bfloat16 (jax's host repr) — detect by name to avoid a hard dep.
    if dtype.name == "bfloat16":
        return (np.uint16, _BF16_MASK)
    raise TypeError(f"overflow check only defined for float types, got {dtype}")


def baseline_overflow_check(grad: np.ndarray, *,
                            tracker: MemoryTracker | None = None,
                            component: str = "overflow_tmp",
                            execute: bool = True) -> bool:
    """Chained isinf/isnan check, charging its temporaries.

    Timeline (matches the paper's Fig. 3):
      step 2: ``abs(G)``    -> full-size fp temporary          (+1.0x)
      step 3: ``isinf``     -> boolean mask                    (+0.25x for fp32)
      step 4: ``any``       -> scalar; abs temp still live
      step 5: ``isnan(G)``  -> boolean mask                    (+0.25x)
      step 6: ``any``       -> scalar
    Peak = payload * (1 + 1 + 0.25) = 2.25x for fp32.
    """
    tracker = tracker or GLOBAL_TRACKER
    nbytes = grad.nbytes
    bool_bytes = grad.size  # numpy/torch bool = 1 byte/elem

    h_abs = tracker.alloc(component, nbytes, tag="abs_tmp")
    try:
        a = np.abs(grad) if execute else None
        h_inf = tracker.alloc(component, bool_bytes, tag="isinf_mask")
        try:
            inf_any = bool(np.isinf(a).any()) if execute else False
        finally:
            tracker.free(h_inf)
    finally:
        tracker.free(h_abs)
        a = None

    h_nan = tracker.alloc(component, bool_bytes, tag="isnan_mask")
    try:
        nan_any = bool(np.isnan(grad).any()) if execute else False
    finally:
        tracker.free(h_nan)
    return inf_any or nan_any


def flat_overflow_check(grad: np.ndarray, *, fused: bool,
                        tracker: MemoryTracker | None = None,
                        component: str = "overflow_tmp") -> bool:
    """Policy-dispatched flat-buffer screen — the ``OverflowCheckOp`` entry
    point.  ``grad`` may be the whole gradient flat buffer or any subgroup
    region of it: both checks are pure elementwise reductions, so the OR
    of per-region verdicts over **any partition** of the buffer equals the
    whole-buffer verdict (the invariant the per-subgroup screen relies on;
    property-tested in ``tests/test_overflow_properties.py``).  The
    full-overlap executor screens each unit's region with
    :func:`check_region` as its gradient write-back lands and ORs the
    verdicts at the barrier instead of scanning the whole buffer there."""
    check = fused_overflow_check if fused else baseline_overflow_check
    return check(grad, tracker=tracker, component=component)


def check_region(flat: np.ndarray, lo: int, hi: int, *, fused: bool,
                 tracker: MemoryTracker | None = None,
                 component: str = "overflow_tmp") -> bool:
    """Screen one ``[lo, hi)`` element region of the gradient flat buffer —
    the per-subgroup half of the fused check (§IV-D run incrementally).
    The region slice is a view; no copy is made."""
    return flat_overflow_check(flat[lo:hi], fused=fused, tracker=tracker,
                               component=component)


def fused_overflow_check(grad: np.ndarray, *,
                         tracker: MemoryTracker | None = None,
                         component: str = "overflow_tmp",
                         chunk: int = FUSED_CHUNK) -> bool:
    """MemAscend's single-pass bitwise check (Algorithm 1), chunked.

    Peak extra memory is one chunk's boolean intermediate (<= 1 MiB),
    charged to the tracker for honest comparison; early-exits on the first
    overflowing chunk.
    """
    tracker = tracker or GLOBAL_TRACKER
    uint_t, mask = _masks_for(grad.dtype)
    flat = grad.reshape(-1).view(uint_t)
    n = flat.size
    chunk_bytes = min(chunk, n) * np.dtype(uint_t).itemsize
    handle = tracker.alloc(component, chunk_bytes, tag="fused_chunk")
    try:
        for start in range(0, n, chunk):
            piece = flat[start:start + chunk]
            # (bits & EXP_MASK) == EXP_MASK  <=> exponent all-ones <=> Inf/NaN
            if np.any((piece & mask) == mask):
                return True
        return False
    finally:
        tracker.free(handle)


# ---------------------------------------------------------------------------
# jnp variants (used inside jitted steps; the Pallas kernel in
# repro.kernels.overflow_check implements the same contract with explicit
# VMEM tiling).
# ---------------------------------------------------------------------------

def baseline_overflow_check_jnp(grad):
    """The chained formulation, for inclusion in a jitted graph.

    Note XLA may fuse this anyway on TPU — the paper's cost is on the *host*
    (eager torch); we keep this as the semantic baseline.
    """
    import jax.numpy as jnp
    a = jnp.abs(grad)
    return jnp.isinf(a).any() | jnp.isnan(grad).any()


def fused_overflow_check_jnp(grad):
    """Bitwise single-pass formulation in jnp."""
    import jax.numpy as jnp
    from jax import lax
    dtype = np.dtype(grad.dtype)
    if dtype == np.dtype(np.float32):
        uint_t, mask = jnp.uint32, 0x7F80_0000
    elif dtype.name == "bfloat16":
        uint_t, mask = jnp.uint16, 0x7F80
    elif dtype == np.dtype(np.float16):
        uint_t, mask = jnp.uint16, 0x7C00
    else:
        raise TypeError(f"unsupported dtype {dtype}")
    bits = lax.bitcast_convert_type(grad, uint_t)
    return jnp.any((bits & uint_t(mask)) == uint_t(mask))
