"""Paged spill-able KV cache: decode state streamed through the offload
machinery at time-axis page granularity.

Offloaded decode (PR 1) re-ran the full prefix per emitted token because a
per-layer KV cache would pin ``n_layers × (2, B, S, KH, D)`` of host memory
— exactly the "pin it all" design the paper exists to break.  PR 2 applied
MemAscend's core move to *decode state*: KV lives in a bounded number of
pool slots inside the same pinned arena the weights stream through (shape
class :data:`~repro.core.buffer_pool.KV_CLASS`), spilling to the SSD tensor
store past the budget.  This revision pages the **time axis** (vLLM-style
block tables, 10Cache-style sub-tensor migration units): the spill/refill
unit is one fixed-size *page* of ``page_tokens`` positions, not a layer's
whole ``max_seq`` slot, so

* eviction writes only **dirty** pages (a decode step dirties one tail page
  per layer; the read-only pages of older tokens spill once and are then
  dropped for free — ``clean_drops``),
* refills read only the pages covering the attended window, not the fixed
  ``max_seq`` extent,
* pages materialize lazily, so one slot budget backs several short
  sequences' layers before anything spills at all.

Residency policy: decode touches layers cyclically (0, 1, …, L−1, 0, …), so
the pages just used are the ones whose next use is farthest away — Belady's
choice is to evict *most-recently-used*, now applied over pages rather than
layers.  A budget of ``R`` page slots keeps the coldest-by-MRU pages
resident and cycles the rest through spill/refill, with prefetched refills
riding the executor's lookahead window.

:class:`DecodeSpec` carries the serving shape (batch, max sequence, time
bucket, page size, residency budget); the session sizes the pool census
from it and buckets the jitted decode stages so each bucket compiles once.

Thread contract (who may call what)
-----------------------------------

* **compute/executor thread** — :meth:`~SpillableKVCache.append`,
  :meth:`~SpillableKVCache.append_window`,
  :meth:`~SpillableKVCache.write_prefill`,
  :meth:`~SpillableKVCache.set_length` / :meth:`~SpillableKVCache.advance`,
  :meth:`~SpillableKVCache.prefetch_window`, and (sync overlap mode only)
  :meth:`~SpillableKVCache.gather_window`.
* **H2D staging worker** — :meth:`~SpillableKVCache.gather_window` for the
  *next* unit's window while the compute thread runs the current unit (the
  split KVReadOp's issue half; see :mod:`repro.core.session`).
* **store worker threads** — only complete the refill futures that
  :meth:`prefetch_window` issued; they never touch cache state directly.

All page/slot bookkeeping lives under one lock.  Because two threads may
now ensure/evict concurrently, a page view is only written or copied while
**pinned** (:meth:`ensure_page` ``pin=True`` → :meth:`unpin`): eviction
skips pinned pages, so a spill on one thread can never release the pool
slot another thread is mid-copy on.  :meth:`close` must only run after the
staging worker has drained (the session's abort path guarantees it).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from .buffer_pool import KV_CLASS, BufferPoolBase
from .nvme import TensorStore
from .paged import PagedResidency, PageStats


@dataclass(frozen=True)
class DecodeSpec:
    """Serving shape for cached offloaded decode.

    ``batch``            requests decoded together (jit shapes are fixed).
    ``max_seq``          prompt + generated tokens capacity per request.
    ``bucket``           time-bucket granularity: device-side cache slices
                         are padded to the next multiple, so each bucket
                         traces/compiles once and steps within it reuse it.
    ``resident_blocks``  host KV budget in layer-equivalents: the page-slot
                         budget is ``resident_blocks × pages_per_seq``;
                         ``None`` keeps every page resident (no spill I/O).
    ``page_tokens``      KV spill/refill page size in tokens (the paged
                         cache's block-table granularity).  Must align with
                         ``bucket`` (one must divide the other).  ``None``
                         uses ``bucket``.  ``page_tokens == max_seq``
                         degenerates to PR 2's whole-layer spill unit — the
                         bench ablation baseline.
    ``resident_pages``   host KV budget directly in page slots (overrides
                         ``resident_blocks``; the two are mutually
                         exclusive).  Must be >= 2 — the paged gather
                         copies page-by-page, so two slots (one pinned for
                         the copy, one turning over) already stream any
                         window length.
    """

    batch: int
    max_seq: int
    bucket: int = 64
    resident_blocks: int | None = None
    page_tokens: int | None = None
    resident_pages: int | None = None

    def __post_init__(self) -> None:
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")
        if not 1 <= self.bucket <= self.max_seq:
            raise ValueError(f"bucket must be in [1, max_seq={self.max_seq}]"
                             f", got {self.bucket}")
        if self.resident_blocks is not None and self.resident_blocks < 2:
            raise ValueError(
                f"resident_blocks must be >= 2 (one slot computing, one "
                f"prefetching), got {self.resident_blocks}")
        if self.page_tokens is not None:
            if not 1 <= self.page_tokens <= self.max_seq:
                raise ValueError(
                    f"page_tokens must be in [1, max_seq={self.max_seq}], "
                    f"got {self.page_tokens}")
            if (self.bucket % self.page_tokens != 0
                    and self.page_tokens % self.bucket != 0):
                raise ValueError(
                    f"page_tokens ({self.page_tokens}) must align with the "
                    f"time bucket ({self.bucket}): one must divide the "
                    f"other, so gathered windows cover whole pages")
        if self.resident_pages is not None:
            if self.resident_blocks is not None:
                raise ValueError(
                    "pass resident_blocks or resident_pages, not both "
                    "(they size the same page-slot budget)")
            if self.resident_pages < 2:
                raise ValueError(
                    f"resident_pages must be >= 2 (one page pinned for a "
                    f"copy, one turning over), got {self.resident_pages}")

    @property
    def page_size(self) -> int:
        """Tokens per KV page (the spill/refill granularity)."""
        return self.bucket if self.page_tokens is None else self.page_tokens

    @property
    def pages_per_seq(self) -> int:
        """Pages covering one request's full ``max_seq`` extent."""
        return -(-self.max_seq // self.page_size)

    def page_budget(self, n_blocks: int) -> int:
        """Resolved page-slot budget for ``n_blocks`` cached layers."""
        total = n_blocks * self.pages_per_seq
        if self.resident_pages is not None:
            return min(self.resident_pages, total)
        if self.resident_blocks is not None:
            return min(self.resident_blocks * self.pages_per_seq, total)
        return total

    def bucket_len(self, length: int) -> int:
        """Device-cache time extent covering ``length`` positions."""
        if length < 1:
            raise ValueError(f"length must be >= 1, got {length}")
        if length > self.max_seq:
            raise ValueError(f"length {length} exceeds max_seq {self.max_seq}")
        return min(self.max_seq, -(-length // self.bucket) * self.bucket)


@dataclass
class KVStats(PageStats):
    """Spill-pipeline effectiveness counters (mirrors SwapStats for KV).

    The generic page counters live in :class:`~repro.core.paged.PageStats`;
    the fields below are KV-only lifecycle events (slot retirement,
    spec-decode rollback)."""

    reclaims: int = 0          # pages dropped by slot retirement (no write)
    reclaim_bytes: int = 0     # bytes those reclaimed pages did NOT spill
    rollbacks: int = 0         # spec-decode rollback/commit calls
    rollback_pages: int = 0    # pages dropped past a rolled-back tail

    _FIELDS = PageStats._FIELDS + ("reclaims", "reclaim_bytes",
                                   "rollbacks", "rollback_pages")


class SpillableKVCache(PagedResidency):
    """Per-layer KV state in page-granular pool slots, spilled to SSD on
    budget.

    One instance covers one generate() call-sequence or serving session.
    The batch dimension is carved into ``slots`` independent *batch slots*
    (``slots == 1`` keeps the whole batch as one joint slot — the
    generate() path).  A (unit, slot)'s state is a sequence of *pages*,
    each one pool slot holding a
    ``(2, rows, page_tokens, kv_heads, head_dim)`` array (``[0]`` is K,
    ``[1]`` is V; ``rows`` is the whole batch for a joint cache and 1 per
    batch slot otherwise); page *p* covers absolute positions
    ``[p·page_tokens, (p+1)·page_tokens)``.  Pages materialize lazily on
    first write and are zero-filled (slot memory is recycled — stale bytes
    from a previous sequence would poison the masked softmax through
    ``0 × NaN``).

    Continuous batching (``slots > 1``): each batch slot independently
    :meth:`join`\\ s (drawn from a FIFO free list), prefills + decodes at
    its own per-slot length, and :meth:`retire`\\ s — page reclaim drops
    its dirty pages *without* a spill write, forgets its SSD keys (a
    reused slot reads zeros, never a previous request's bytes), and
    returns the slot to the free list.  :meth:`admissible` is the
    scheduler's KV-page admission check.

    The session writes via :meth:`append` / :meth:`write_prefill`, reads
    whole attended windows via :meth:`gather_window`, and hints upcoming
    units via :meth:`prefetch_window`.  See the module docstring for the
    thread contract (pinning protocol included); :meth:`join` /
    :meth:`retire` belong to the drive thread, *between* plan runs.
    """

    def __init__(self, units: list[str], page_shape: tuple, max_seq: int,
                 dtype, pool: BufferPoolBase, store: TensorStore, *,
                 resident_limit: int | None = None, slots: int = 1) -> None:
        self.units = list(units)
        self.page_shape = tuple(page_shape)
        self.page_tokens = int(self.page_shape[2])
        self.max_seq = int(max_seq)
        self.pages_per_unit = -(-self.max_seq // self.page_tokens)
        self.dtype = np.dtype(dtype)
        self.page_nbytes = int(self.dtype.itemsize *
                               np.prod(self.page_shape, dtype=np.int64))
        self.pool = pool
        self.store = store
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if slots > 1 and self.page_shape[1] != 1:
            raise ValueError(
                f"per-slot paging (slots={slots}) needs single-row pages, "
                f"got page batch dim {self.page_shape[1]} (pass the "
                f"model's kv_shape(1, page_tokens))")
        self.slots = int(slots)
        # rows in a gathered window: whole batch for a joint cache, one
        # row per batch slot otherwise
        self.batch = self.page_shape[1] if self.slots == 1 else self.slots
        total = len(self.units) * self.pages_per_unit * self.slots
        # the block table / eviction / pin / capacity machinery lives in
        # the shared paged-residency base; page key = (unit, batch_slot,
        # page_index)
        super().__init__(pool, store, pool_class=KV_CLASS,
                         total_pages=total, resident_limit=resident_limit,
                         stats=KVStats())
        # Per-slot cached-token counts.  All slots start active (the joint
        # generate() path drives them in lockstep); a serving engine
        # retires them into the free list first, then join/retire churns
        # them per request.
        # lengths/active are drive-thread state (executor-only between
        # worker quiesce points), not lock-guarded — see thread contract
        self.lengths = np.zeros(self.slots, dtype=np.int64)
        self.active: set[int] = set(range(self.slots))
        self._free: deque[int] = deque()   # guarded-by: _lock

    # -- internals -----------------------------------------------------------

    def _store_key(self, unit: str, slot: int, page: int) -> str:
        # joint caches keep the PR-5 key format (no slot segment) so their
        # on-SSD layout — and the tests pinned to it — is unchanged
        if self.slots == 1:
            return f"kv/{unit}/p{page:04d}"
        return f"kv/{unit}/s{slot:02d}/p{page:04d}"

    # page-naming hooks for the shared residency engine: every KV page
    # shares one shape/dtype/size; the store key carries unit/slot/page
    def _store_key_of(self, key: tuple) -> str:
        return self._store_key(*key)

    def _page_shape_of(self, key: tuple) -> tuple:
        return self.page_shape

    def _page_dtype_of(self, key: tuple) -> np.dtype:
        return self.dtype

    def _page_nbytes_of(self, key: tuple) -> int:
        return self.page_nbytes

    # -- the session-facing API ----------------------------------------------

    def pages_for(self, extent: int) -> int:
        """Pages covering ``extent`` positions (capped at the per-unit
        page count)."""
        return min(-(-extent // self.page_tokens), self.pages_per_unit)

    def prefetch_window(self, unit: str,
                        extent: int) -> None:  # thread: executor
        """Hint that ``unit``'s window of ``extent`` positions is needed
        soon: issue async SSD refills for its spilled pages into free
        slots.  No-op for unknown units, non-spilled pages, or when fewer
        than two slots are free (one is kept in reserve so a concurrent
        fresh-page write can always evict its way to a slot)."""
        if unit not in self.units or extent < 1:
            return
        with self._lock:
            if self.closed:
                return
            for slot in range(self.slots):
                for p in range(self.pages_for(extent)):
                    if not self._prefetch_one((unit, slot, p)):
                        return

    def ensure_page(self, unit: str, page: int, *, slot: int = 0,
                    pin: bool = False) -> np.ndarray:  # thread: executor, h2d-worker
        """Host view of one page, resident.  Waits out an in-flight refill;
        synchronously refills a spilled page; acquires (and zero-fills) a
        fresh slot for a never-written page.  With ``pin=True`` the page is
        returned pinned (evictions skip it) — the caller MUST :meth:`unpin`
        after its copy/write; writers must also mark the page dirty before
        unpinning or the write may be lost to a clean eviction."""
        if unit not in self.units:
            raise KeyError(f"unknown KV unit {unit!r}")
        if not 0 <= page < self.pages_per_unit:
            raise ValueError(f"page {page} outside [0, "
                             f"{self.pages_per_unit}) for unit {unit!r}")
        if not 0 <= slot < self.slots:
            raise ValueError(f"slot {slot} outside [0, {self.slots})")
        return self._ensure((unit, slot, page), pin=pin)

    def unpin(self, unit: str, page: int, *,
              slot: int = 0) -> None:  # thread: executor, h2d-worker
        """Release one pin on a page (see :meth:`ensure_page`)."""
        self._unpin((unit, slot, page))

    def gather_window(self, unit: str, extent: int  # thread: executor, h2d-worker
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Contiguous host (K, V) arrays of shape
        ``(batch, extent, kv_heads, head_dim)`` covering positions
        ``[0, extent)`` — the attended window one ``block_step`` H2Ds.

        Pages are ensured (refilled if spilled) and copied one at a time
        under a pin, so the budget floor is two slots, not a whole window.
        Never-materialized pages read as zeros: positions ``>= length`` are
        masked by the attention kernel, but the values must still be finite
        (``0 × NaN`` would poison the masked softmax).
        """
        if unit not in self.units:
            raise KeyError(f"unknown KV unit {unit!r}")
        if not 1 <= extent <= self.max_seq:
            raise ValueError(f"extent {extent} outside [1, {self.max_seq}]")
        pt = self.page_shape[2]
        kh, d = self.page_shape[3], self.page_shape[4]
        k_out = np.zeros((self.batch, extent, kh, d), self.dtype)
        v_out = np.zeros((self.batch, extent, kh, d), self.dtype)
        rows = slice(None) if self.slots == 1 else None
        for slot in range(self.slots):
            if self.slots > 1:
                rows = slice(slot, slot + 1)
            for p in range(self.pages_for(extent)):
                with self._lock:
                    materialized = self._materialized((unit, slot, p))
                if not materialized:
                    continue    # lazily never written: stays zero
                view = self.ensure_page(unit, p, slot=slot, pin=True)
                try:
                    lo = p * pt
                    m = min(pt, extent - lo)
                    k_out[rows, lo:lo + m] = view[0][:, :m]
                    v_out[rows, lo:lo + m] = view[1][:, :m]
                finally:
                    self.unpin(unit, p, slot=slot)
        return k_out, v_out

    def _rows(self, arr: np.ndarray, slot: int) -> np.ndarray:
        """The batch rows a slot owns: everything for a joint cache, one
        row (kept 2-D-leading) per batch slot otherwise."""
        return arr if self.slots == 1 else arr[slot:slot + 1]

    def append(self, unit: str, k_new: np.ndarray,
               v_new: np.ndarray) -> None:  # thread: executor
        """Write one decoded token's K/V (``(B, 1, KH, D)``) into each
        **active** slot's tail page at that slot's own length (advance once
        per step via :meth:`advance`) — the only pages a decode step
        dirties.  Inactive slots' rows are ignored (their lanes carry
        masked garbage)."""
        targets = sorted(self.active)
        if not targets:
            raise RuntimeError("append with no active slots")
        for s in targets:
            if self.lengths[s] >= self.max_seq:
                raise ValueError(f"KV cache full: slot {s} length "
                                 f"{int(self.lengths[s])} at capacity "
                                 f"{self.max_seq}")
        for s in targets:
            page, off = divmod(int(self.lengths[s]), self.page_tokens)
            view = self.ensure_page(unit, page, slot=s, pin=True)
            try:
                view[0][:, off] = self._rows(k_new, s)[:, 0]
                view[1][:, off] = self._rows(v_new, s)[:, 0]
                with self._lock:
                    self._dirty.add((unit, s, page))
            finally:
                self.unpin(unit, page, slot=s)
        self._maybe_spill_after_use()

    def append_window(self, unit: str, k_new: np.ndarray,
                      v_new: np.ndarray) -> None:  # thread: executor
        """Write a K-token draft window's K/V (``(B, K, KH, D)``) into
        each **active** slot's pages starting at that slot's own length,
        WITHOUT advancing it — the speculative-decode verify write.  The
        window may span several pages; each touched page is dirtied.  The
        host inspects the verify logits afterwards and calls
        :meth:`rollback` with ``length + accepted`` per slot, which both
        advances the slot over the accepted prefix and drops any page the
        rejected tail had materialized.  ``K == 1`` is :meth:`append`
        minus the advance."""
        kq = int(k_new.shape[1])
        targets = sorted(self.active)
        if not targets:
            raise RuntimeError("append_window with no active slots")
        if kq < 1:
            raise ValueError(f"window must be >= 1 token, got {kq}")
        for s in targets:
            if self.lengths[s] + kq > self.max_seq:
                raise ValueError(
                    f"KV cache full: slot {s} length "
                    f"{int(self.lengths[s])} + window {kq} exceeds "
                    f"capacity {self.max_seq}")
        pt = self.page_tokens
        for s in targets:
            kr, vr = self._rows(k_new, s), self._rows(v_new, s)
            start = int(self.lengths[s])
            done = 0
            while done < kq:
                page, off = divmod(start + done, pt)
                m = min(pt - off, kq - done)
                view = self.ensure_page(unit, page, slot=s, pin=True)
                try:
                    view[0][:, off:off + m] = kr[:, done:done + m]
                    view[1][:, off:off + m] = vr[:, done:done + m]
                    with self._lock:
                        self._dirty.add((unit, s, page))
                finally:
                    self.unpin(unit, page, slot=s)
                done += m
        self._maybe_spill_after_use()

    def write_prefill(self, unit: str, k: np.ndarray, v: np.ndarray, *,
                      slots: list[int] | None = None) -> None:  # thread: executor
        """Write the prefill pass's K/V (``(B, S_bucket, KH, D)``; entries
        past the true prompt length are masked garbage, overwritten by
        later appends), scattered page by page.  ``slots`` restricts the
        scatter to the named batch slots' rows — the continuous-batching
        joiner path, where the other lanes belong to mid-flight requests
        whose pages must not be touched."""
        s_extent = k.shape[1]
        if s_extent > self.max_seq:
            raise ValueError(f"prefill extent {s_extent} exceeds capacity "
                             f"{self.max_seq}")
        targets = range(self.slots) if slots is None else slots
        pt = self.page_tokens
        for slot in targets:
            if not 0 <= slot < self.slots:
                raise ValueError(f"slot {slot} outside [0, {self.slots})")
            kr, vr = self._rows(k, slot), self._rows(v, slot)
            for p in range(-(-s_extent // pt)):
                lo = p * pt
                m = min(pt, s_extent - lo)
                view = self.ensure_page(unit, p, slot=slot, pin=True)
                try:
                    view[0][:, :m] = kr[:, lo:lo + m]
                    view[1][:, :m] = vr[:, lo:lo + m]
                    with self._lock:
                        self._dirty.add((unit, slot, p))
                finally:
                    self.unpin(unit, p, slot=slot)
        self._maybe_spill_after_use()

    # -- lengths + slot lifecycle --------------------------------------------

    @property
    def length(self) -> int:
        """Longest cached sequence (all slots agree on the joint path)."""
        return int(self.lengths.max(initial=0))

    def slot_length(self, slot: int) -> int:
        return int(self.lengths[slot])

    def set_length(self, length: int) -> None:
        """Joint-path length update: every slot in lockstep."""
        if not 0 <= length <= self.max_seq:
            raise ValueError(f"length {length} outside [0, {self.max_seq}]")
        self.lengths[:] = length

    def set_slot_length(self, slot: int, length: int) -> None:
        """One slot's length (the serving prefill lands a joiner here)."""
        if not 0 <= length <= self.max_seq:
            raise ValueError(f"length {length} outside [0, {self.max_seq}]")
        if slot not in self.active:
            raise RuntimeError(f"slot {slot} is not active")
        self.lengths[slot] = length

    def advance(self, n: int = 1) -> None:  # thread: executor
        """Advance every **active** slot by ``n`` (one decode step)."""
        for s in self.active:
            new = int(self.lengths[s]) + n
            if not 0 <= new <= self.max_seq:
                raise ValueError(f"length {new} outside [0, {self.max_seq}] "
                                 f"for slot {s}")
        for s in self.active:
            self.lengths[s] += n

    @property
    def free_slots(self) -> int:
        """Batch slots available to :meth:`join`."""
        with self._lock:
            return len(self._free)

    def admissible(self, prompt_len: int) -> bool:
        """KV-page admission check: can a request with this prompt stream
        its own attended window?  Its per-unit prompt pages plus one
        turnover slot must fit the page budget — a longer prompt would
        evict a page it is about to read *within a single gather*, every
        step, forever (thrash, not progress), so the scheduler refuses it
        terminally rather than queueing it."""
        if not 1 <= prompt_len <= self.max_seq:
            return False
        return self.pages_for(prompt_len) + 1 <= self.resident_limit

    def join(self) -> int | None:  # thread: executor
        """Claim a retired batch slot for a new request (FIFO over the
        free list); ``None`` when every slot is mid-request.  The slot
        comes back empty: length 0, no pages materialized (its previous
        request's pages were reclaimed and its SSD keys forgotten by
        :meth:`retire`, so the first gather reads zeros)."""
        with self._lock:
            if self.closed:
                raise RuntimeError("KV cache is closed")
            if not self._free:
                return None
            slot = self._free.popleft()
            self.active.add(slot)
            self.lengths[slot] = 0
            return slot

    def retire(self, slot: int) -> None:  # thread: executor
        """Retire one batch slot: reclaim its pages and return it to the
        free list.  Reclaim is the cheap half of the spill machinery —
        resident pages (dirty or not) release their pool slots *without*
        a store write, in-flight refills are waited out and dropped, and
        the slot's SSD keys are forgotten so a rejoining request can
        never read the retired request's bytes.  Drive-thread only,
        between plan runs: pages of a retiring slot must not be pinned
        (the staging worker is quiesced between runs)."""
        if not 0 <= slot < self.slots:
            raise ValueError(f"slot {slot} outside [0, {self.slots})")
        with self._lock:
            if slot in self._free:
                raise RuntimeError(f"slot {slot} already retired")
            self.active.discard(slot)
            self.lengths[slot] = 0
            # wait out a dirty spill write mid-flight on another thread;
            # it lands the key in _spilled, which is forgotten below
            while any(k[1] == slot for k in self._evicting):
                if not self._lock.wait(timeout=30.0):
                    raise RuntimeError(
                        f"slot {slot} page stuck in eviction for 30s")
            if any(self._pinned.get(k) for k in self._slots
                   if k[1] == slot):
                raise RuntimeError(
                    f"retire({slot}) with pinned pages: retire only "
                    f"between plan runs, after staging has drained")
            fut_entries = [(k, self._futures.pop(k))
                           for k in [k for k in self._futures
                                     if k[1] == slot]]
            # popped futures no longer count toward capacity via _futures;
            # hold their slots via _in_transit until the reads settle
            self._in_transit += len(fut_entries)
            reclaimed = []
            for k in [k for k in self._slots if k[1] == slot]:
                reclaimed.append(self._slots.pop(k))
                self._use_order.remove(k)
                self._dirty.discard(k)
                self.stats.reclaims += 1
                self.stats.reclaim_bytes += self.page_nbytes
            for k in [k for k in self._spilled if k[1] == slot]:
                self._spilled.discard(k)   # SSD bytes orphaned, unreadable
            self._free.append(slot)
        for buf in reclaimed:
            buf.release()
        for _k, (buf, future) in fut_entries:
            try:
                future.result()   # the async read targets buf: settle first
            except BaseException:
                pass              # data is being discarded
            finally:
                buf.release()
        with self._lock:
            self._in_transit -= len(fut_entries)
            self.stats.reclaims += len(fut_entries)
            self.stats.reclaim_bytes += len(fut_entries) * self.page_nbytes
            self._lock.notify_all()   # freed capacity: wake slot waiters

    def rollback(self, slot: int, length: int) -> None:  # thread: executor
        """Declare ``length`` as one slot's authoritative cached extent
        and drop every page materialized past its tail.

        Two callers:

        * **spec-decode commit** — after :meth:`append_window` wrote a
          K-token draft window past ``lengths[slot]``, the host accepts
          ``c`` tokens and calls ``rollback(slot, old_length + c)``: the
          accepted prefix is kept (the slot advances over it), the
          rejected tail's pages are dropped;
        * **plain truncation** — ``length`` below the current length
          rewinds the slot (rejected slots in a mixed batch roll back
          independently while accepted slots advance).

        Pages covering ``[0, length)`` survive; the partial tail page is
        kept as-is — its bytes past ``length`` are masked by the
        attention kernel and overwritten by the next append, and for a
        spilled tail page the SSD copy still holds the only valid prefix
        bytes.  Fully-dropped pages release their pool slots without a
        store write, their in-flight refills are settled and discarded,
        and their SSD keys are **forgotten**: a dirty page that spilled
        while it still held rejected draft tokens must never resurrect
        those bytes on a later refill (``rollback_pages`` counts every
        drop).  Unlike :meth:`retire`, a dropped page pinned by an
        in-flight staged gather is *waited out*, not an error — the
        gather unpins in bounded time and reads data that was valid when
        it was staged (the accept decision only shrinks what later steps
        may attend to)."""
        if not 0 <= slot < self.slots:
            raise ValueError(f"slot {slot} outside [0, {self.slots})")
        if not 0 <= length <= self.max_seq:
            raise ValueError(f"length {length} outside [0, {self.max_seq}]")
        keep = self.pages_for(length)

        def _dropped(keys):
            return [k for k in keys if k[1] == slot and k[2] >= keep]

        with self._lock:
            if self.closed:
                raise RuntimeError("KV cache is closed")
            if slot in self._free:
                raise RuntimeError(f"rollback of retired slot {slot}")
            # wait out (a) dirty spill writes mid-flight on another
            # thread — they land the key in _spilled, forgotten below —
            # and (b) staged-gather pins on the dropped range
            while True:
                busy = [k for k in _dropped(self._evicting)]
                busy += [k for k in _dropped(self._slots)
                         if self._pinned.get(k)]
                if not busy:
                    break
                if not self._lock.wait(timeout=30.0):
                    raise RuntimeError(
                        f"rollback({slot}, {length}) waited 30s for busy "
                        f"pages {busy!r} (mid-eviction or pinned by a "
                        f"staged gather)")
            fut_entries = [(k, self._futures.pop(k))
                           for k in _dropped(self._futures)]
            # popped futures no longer count toward capacity via
            # _futures; hold their slots via _in_transit until settled
            self._in_transit += len(fut_entries)
            dropped = []
            for k in _dropped(self._slots):
                dropped.append(self._slots.pop(k))
                self._use_order.remove(k)
                self._dirty.discard(k)
                self.stats.rollback_pages += 1
            for k in _dropped(self._spilled):
                self._spilled.discard(k)   # SSD bytes orphaned, unreadable
                self.stats.rollback_pages += 1
            self.stats.rollbacks += 1
            self.lengths[slot] = length
        for buf in dropped:
            buf.release()
        for _k, (buf, future) in fut_entries:
            try:
                future.result()   # the async read targets buf: settle first
            except BaseException:
                pass              # data is being discarded
            finally:
                buf.release()
        with self._lock:
            self._in_transit -= len(fut_entries)
            self.stats.rollback_pages += len(fut_entries)
            self._lock.notify_all()   # freed capacity: wake slot waiters

    @property
    def resident_pages(self) -> list[tuple]:
        """Sorted keys currently host-resident: ``(unit, page)`` for a
        joint cache (the PR-5 shape), ``(unit, slot, page)`` otherwise."""
        with self._lock:
            keys = sorted(self._slots)
        if self.slots == 1:
            return [(u, p) for (u, _s, p) in keys]
        return keys

    # close() is inherited from PagedResidency: wait out in-flight
    # refills, return every slot; idempotent (generate()'s error path).
