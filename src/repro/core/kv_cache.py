"""Spill-able KV cache: decode state streamed through the offload machinery.

Offloaded decode (PR 1) re-ran the full prefix per emitted token because a
per-layer KV cache would pin ``n_layers × (2, B, S, KH, D)`` of host memory
— exactly the "pin it all" design the paper exists to break.  This module
applies MemAscend's core move to *decode state*: KV lives in a bounded
number of pool slots inside the same pinned arena the weights stream
through (shape class :data:`~repro.core.buffer_pool.KV_CLASS`), and layers
that do not fit the budget spill to the SSD tensor store, to be refilled —
ideally prefetched under the previous layer's compute — on their next turn.

Residency policy: decode touches layers cyclically (0, 1, …, L−1, 0, …), so
the block just used is the one whose next use is farthest away — Belady's
choice is to evict *most-recently-used*.  With a budget of ``R`` slots the
cache keeps the first ``R−2`` layers host-resident forever and cycles the
remaining layers through the last two slots (one in use, one prefetching),
giving a host footprint of ``R`` slots independent of model depth.

:class:`DecodeSpec` carries the serving shape (batch, max sequence, time
bucket, residency budget); the session sizes the pool census from it and
buckets the jitted decode stages so each bucket compiles once.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import numpy as np

from .buffer_pool import KV_CLASS, BufferPoolBase, PoolBuffer
from .nvme import TensorStore


@dataclass(frozen=True)
class DecodeSpec:
    """Serving shape for cached offloaded decode.

    ``batch``            requests decoded together (jit shapes are fixed).
    ``max_seq``          prompt + generated tokens capacity per request.
    ``bucket``           time-bucket granularity: device-side cache slices
                         are padded to the next multiple, so each bucket
                         traces/compiles once and steps within it reuse it.
    ``resident_blocks``  host KV budget in layers (pool slots); ``None``
                         keeps every layer resident (no spill I/O).
    """

    batch: int
    max_seq: int
    bucket: int = 64
    resident_blocks: int | None = None

    def __post_init__(self) -> None:
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")
        if not 1 <= self.bucket <= self.max_seq:
            raise ValueError(f"bucket must be in [1, max_seq={self.max_seq}]"
                             f", got {self.bucket}")
        if self.resident_blocks is not None and self.resident_blocks < 2:
            raise ValueError(
                f"resident_blocks must be >= 2 (one slot computing, one "
                f"prefetching), got {self.resident_blocks}")

    def bucket_len(self, length: int) -> int:
        """Device-cache time extent covering ``length`` positions."""
        if length < 1:
            raise ValueError(f"length must be >= 1, got {length}")
        if length > self.max_seq:
            raise ValueError(f"length {length} exceeds max_seq {self.max_seq}")
        return min(self.max_seq, -(-length // self.bucket) * self.bucket)


@dataclass
class KVStats:
    """Spill-pipeline effectiveness counters (mirrors SwapStats for KV)."""

    spills: int = 0            # host slot written to SSD + released
    refills: int = 0           # SSD read back into a slot (any path)
    prefetch_refills: int = 0  # refills issued ahead of use
    prefetch_hits: int = 0     # refill already complete when ensure() asked
    sync_refills: int = 0      # ensure() found nothing in flight
    spill_bytes: int = 0
    refill_bytes: int = 0
    wait_seconds: float = 0.0  # time ensure() blocked on outstanding refills

    def snapshot(self) -> dict:
        return {k: getattr(self, k) for k in (
            "spills", "refills", "prefetch_refills", "prefetch_hits",
            "sync_refills", "spill_bytes", "refill_bytes", "wait_seconds")}


class SpillableKVCache:
    """Per-layer KV state in pool slots, spilled to the SSD store on budget.

    One instance covers one generate() call-sequence: ``length`` tokens are
    cached for every unit in ``units``.  Each unit's state is one pool slot
    holding a ``(2, batch, max_seq, kv_heads, head_dim)`` array (``[0]`` is
    K, ``[1]`` is V).  The session reads host views via :meth:`ensure`
    (waiting out any in-flight refill), appends via :meth:`append` /
    :meth:`write_prefill`, and hints upcoming layers via :meth:`prefetch`.

    Thread-safety: refills land from store worker threads; all slot/state
    bookkeeping is under one lock.  Compute-side calls (ensure/append) come
    from the single executor thread.
    """

    def __init__(self, units: list[str], shape: tuple, dtype,
                 pool: BufferPoolBase, store: TensorStore, *,
                 resident_limit: int | None = None) -> None:
        self.units = list(units)
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self.nbytes = int(self.dtype.itemsize *
                          np.prod(self.shape, dtype=np.int64))
        self.pool = pool
        self.store = store
        n = len(self.units)
        self.resident_limit = n if resident_limit is None else \
            min(resident_limit, n)
        if self.resident_limit < n and self.resident_limit < 2:
            raise ValueError(
                f"resident_limit {self.resident_limit} < 2 cannot pipeline "
                f"{n} units (one slot computing, one prefetching)")
        # Below budget every unit stays resident; at budget, reserve two
        # slots for the (in use, prefetching) pair cycling the cold units.
        self._keep = n if self.resident_limit >= n else \
            max(0, self.resident_limit - 2)
        self.length = 0          # tokens cached so far (same for all units)
        self.stats = KVStats()
        self.closed = False
        self._lock = threading.Lock()
        self._slots: dict[str, PoolBuffer] = {}     # resident units
        self._futures: dict[str, tuple[PoolBuffer, Future]] = {}  # refilling
        self._spilled: set[str] = set()             # state lives on SSD
        self._use_order: list[str] = []             # LRU ... MRU

    # -- internals -----------------------------------------------------------

    def _store_key(self, unit: str) -> str:
        return f"kv/{unit}"

    def _touch(self, unit: str) -> None:
        if unit in self._use_order:
            self._use_order.remove(unit)
        self._use_order.append(unit)

    def _acquire(self, unit: str) -> PoolBuffer:
        # Budget is self-managed: resident + in-flight never exceeds
        # resident_limit (the census slot count), so this never blocks.
        return self.pool.acquire(KV_CLASS, self.nbytes,
                                 tag=self._store_key(unit))

    def _free_capacity(self) -> int:
        return self.resident_limit - len(self._slots) - len(self._futures)

    def _spill_one(self, exclude: set[str]) -> None:
        """Evict the most-recently-used resident unit (Belady under cyclic
        access) not in ``exclude``: write it to SSD, return the slot."""
        for unit in reversed(self._use_order):
            if unit in self._slots and unit not in exclude:
                self._spill(unit)
                return
        raise RuntimeError("KV cache cannot make room: every resident "
                           "slot is excluded from eviction")

    def _spill(self, unit: str) -> None:
        buf = self._slots.pop(unit)
        view = buf.view(self.dtype, self.shape)
        self.store.write(self._store_key(unit), view)
        buf.release()
        self._spilled.add(unit)
        self._use_order.remove(unit)
        self.stats.spills += 1
        self.stats.spill_bytes += self.nbytes

    def _maybe_spill_after_use(self, unit: str) -> None:
        """Spill-after-use: once a unit's append landed, its next use is a
        full cycle away — spill it (and anything else over the keep line)."""
        with self._lock:
            while len(self._slots) > self._keep:
                self._spill_one(exclude=set())

    # -- the session-facing API ----------------------------------------------

    def prefetch(self, unit: str) -> None:
        """Hint that ``unit`` is needed soon: issue an async SSD refill into
        a free slot.  No-op for non-KV units, resident/in-flight units,
        units with no spilled state, or when no slot is free."""
        with self._lock:
            if (self.closed or unit not in self.units
                    or unit in self._slots or unit in self._futures
                    or unit not in self._spilled
                    or self._free_capacity() < 1):
                return
            buf = self._acquire(unit)
            view = buf.view(self.dtype, self.shape)
            future = self.store.read_async(self._store_key(unit), view)
            self._futures[unit] = (buf, future)
            self._spilled.discard(unit)
            self.stats.prefetch_refills += 1

    def ensure(self, unit: str) -> np.ndarray:
        """Host view of ``unit``'s KV state, resident.  Waits out an
        in-flight refill; synchronously refills a spilled unit; acquires
        (and zero-fills) a fresh slot for a never-written unit."""
        if unit not in self.units:
            raise KeyError(f"unknown KV unit {unit!r}")
        with self._lock:
            if self.closed:
                raise RuntimeError("KV cache is closed")
            entry = self._futures.pop(unit, None)
            spilled = unit in self._spilled
            if entry is not None:
                buf, future = entry
                hit = future.done()
            elif unit in self._slots:
                self._touch(unit)
                return self._slots[unit].view(self.dtype, self.shape)
            else:
                # Sync path: spilled (refill now) or first touch (zero).
                if self._free_capacity() < 1:
                    self._spill_one(exclude={unit})
                buf = self._acquire(unit)
                future = None
                hit = False
        view = buf.view(self.dtype, self.shape)
        t0 = time.perf_counter()
        try:
            if future is not None:
                future.result()
            elif spilled:
                self.store.read(self._store_key(unit), view)
            else:
                view[...] = np.zeros((), self.dtype)  # fresh state
        except BaseException:
            buf.release()   # slot must not leak on a failed read
            raise
        wait = time.perf_counter() - t0
        # Counters strictly under the lock: prefetch() bumps its stats from
        # the executor thread while refills land from store workers, and
        # under the full-overlap executor more threads observe snapshots —
        # unlocked read-modify-writes here tore the ledger.
        with self._lock:
            if future is not None:
                self.stats.refills += 1
                self.stats.refill_bytes += self.nbytes
                self.stats.prefetch_hits += int(hit)
            elif spilled:
                self.stats.refills += 1
                self.stats.refill_bytes += self.nbytes
                self.stats.sync_refills += 1
            self.stats.wait_seconds += wait
            self._spilled.discard(unit)
            self._slots[unit] = buf
            self._touch(unit)
        return view

    def append(self, unit: str, k_new: np.ndarray, v_new: np.ndarray) -> None:
        """Write one decoded token's K/V (``(B, 1, KH, D)``) at position
        ``length`` (advance once per step via :meth:`advance`)."""
        if self.length >= self.shape[2]:
            raise ValueError(f"KV cache full: length {self.length} at "
                             f"capacity {self.shape[2]}")
        view = self.ensure(unit)
        view[0][:, self.length] = k_new[:, 0]
        view[1][:, self.length] = v_new[:, 0]
        self._maybe_spill_after_use(unit)

    def write_prefill(self, unit: str, k: np.ndarray, v: np.ndarray) -> None:
        """Write the prefill pass's K/V (``(B, S_bucket, KH, D)``; entries
        past the true prompt length are masked garbage, overwritten by later
        appends)."""
        s = k.shape[1]
        if s > self.shape[2]:
            raise ValueError(f"prefill extent {s} exceeds capacity "
                             f"{self.shape[2]}")
        view = self.ensure(unit)
        view[0][:, :s] = k
        view[1][:, :s] = v
        self._maybe_spill_after_use(unit)

    def set_length(self, length: int) -> None:
        if not 0 <= length <= self.shape[2]:
            raise ValueError(f"length {length} outside [0, {self.shape[2]}]")
        self.length = length

    def advance(self, n: int = 1) -> None:
        self.set_length(self.length + n)

    @property
    def resident_units(self) -> list[str]:
        with self._lock:
            return sorted(self._slots)

    def close(self) -> None:
        """Wait out in-flight refills and return every slot.  Idempotent;
        runs on generate()'s error path, so nothing may leak."""
        with self._lock:
            if self.closed:
                return
            self.closed = True
            futures = list(self._futures.values())
            self._futures.clear()
            slots = list(self._slots.values())
            self._slots.clear()
            self._use_order.clear()
        for buf, future in futures:
            try:
                future.result()
            except BaseException:
                pass            # data is being discarded
            finally:
                buf.release()
        for buf in slots:
            buf.release()
