"""Background-pipeline primitives for the full-overlap executor (Fig. 6).

The paper's pipeline has four legs that should all hide under compute:

  SSD→host read   — async since PR 1 (:class:`~repro.core.swapper.
                    ParameterSwapper` lookahead prefetch),
  host→device H2D — staged by a :class:`SerialWorker` into a bounded set of
                    :class:`DeviceSlots` (the device-side double buffer),
  device→host D2H — gradient write-back enqueued on a second SerialWorker
                    (the writer thread), drained before the overflow check,
  optimizer       — step *k*'s subgroup-streamed host Adam runs on a third
                    SerialWorker, interleaved with step *k+1*'s forward
                    prefetch window (SSDTrain-style cross-step pipelining).
                    Inside that stage a fourth SerialWorker (the
                    state-prefetch worker) streams subgroup *k+1*'s
                    (master, m, v) into a double-buffered staging arena and
                    drains subgroup *k−1*'s write-backs while subgroup *k*'s
                    arithmetic runs — the Adam stage's own store I/O hides
                    under its own compute.

Cached-decode KV windows ride the same H2D staging worker: the executor
queues a page-gather + H2D task per block (the split KVReadOp's issue
half) behind that block's weight staging, bounded by a dedicated ``kv``
device-slot class, so the serving path's last synchronous transfer also
hides under the previous block's compute.

Activation checkpoints (train) ride both workers: ActSaveOp's D2H + SSD
write runs on the gradient-writer thread (idle during the forward pass),
and ActFetchOp's SSD read + H2D staging rides the H2D worker behind the
backward pass's weight staging, bounded by the dedicated
:data:`ACT_CLASS` device-slot class — block *i−1*'s checkpoint streams
back under block *i*'s ``block_bwd``.

This module holds the machinery shared by those legs; the session wires it
to the StreamPlan executor (:mod:`repro.core.session`).  Everything here is
model-agnostic: a SerialWorker is just an order-preserving single-thread
task queue with latched-error semantics, and DeviceSlots is a counted
per-shape-class staging budget.

Thread contract (who may call what)
-----------------------------------

* :meth:`SerialWorker.submit` may be called from any thread (it only
  enqueues; a bounded queue blocks the *producer*), but each worker's
  tasks run strictly FIFO on its single daemon thread — tasks never need
  locks against each other, only against state shared with other threads.
* :meth:`SerialWorker.drain` / :meth:`SerialWorker.close` re-raise the
  latched first failure exactly once; callers that already delivered a
  task's exception out-of-band must :meth:`SerialWorker.consume_error` it
  first or teardown double-reports.
* :meth:`DeviceSlots.acquire` is only ever called by the single H2D
  staging worker, in fetch order; :meth:`DeviceSlots.release_all` is
  called by the executor thread (at ``ReleaseOp`` / abort).  That pairing
  is the deadlock-freedom argument: every blocked acquire sits at or
  before the worker's queue head, with all earlier units' slots already
  releasable by the live executor.
* :class:`OverlapStats` plain fields are executor-thread-only; counters
  accrued on worker threads go through
  :meth:`OverlapStats.add_worker_seconds`, which locks.
"""

from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from dataclasses import dataclass, field


# Device-slot class bounding staged activation-checkpoint H2Ds (train
# backward).  Depth 2 = one checkpoint consumed by the current block_bwd
# plus one being staged for the next — the same double-buffer rotation as
# the weight classes, and the same deadlock-freedom argument: the single
# H2D worker acquires, the executor's block_bwd consume releases.
ACT_CLASS = "__act__"

# Device-slot class bounding staged expert-stack H2Ds (route-aware MoE
# paging).  Depth 2 = one unit's routed expert stacks consumed by the
# current block_moe plus one being staged for the next MoE unit — the
# same rotation and deadlock-freedom argument as ACT_CLASS.
EXPERT_CLASS = "__expert__"


def done_future(value=None) -> Future:
    """An already-resolved Future (sync-mode stand-in for a queued task)."""
    fut: Future = Future()
    fut.set_result(value)
    return fut


class SerialWorker:
    """One daemon thread executing submitted callables strictly FIFO.

    The executor's async legs all need the same contract:

    * **order**: tasks run in submission order (grad scatters must land in
      plan order; optimizer subgroups must follow their ``begin_step``),
    * **bounded memory**: ``maxsize`` backpressures the producer (the
      compute thread) instead of queueing unbounded device arrays,
    * **no lost errors**: with ``latch=True`` the first task failure is
      latched and re-raised at the next :meth:`drain` or :meth:`close` (and
      each task's own :class:`Future` carries its exception for callers
      that wait on it directly).  Workers whose every future *is* awaited
      (the H2D stage) pass ``latch=False`` so an already-delivered failure
      is not re-raised a second time at teardown; latching callers that
      deliver a failure out-of-band call :meth:`consume_error`.

    A worker is *not* a thread pool — single-threaded by design, so tasks
    need no internal locking against each other.
    """

    def __init__(self, name: str, *, maxsize: int = 0,
                 latch: bool = True) -> None:
        self.name = name
        self._q: queue.Queue = queue.Queue(maxsize)
        self._latch = latch
        self._error: BaseException | None = None   # guarded-by: _error_lock
        # Consumed error INSTANCES (strong refs, identity semantics): a
        # poisoned pipeline re-raises the same object from later tasks,
        # which must not re-latch; holding the object (not its id) keeps
        # a recycled address from masking an unrelated future failure.
        self._delivered: list[BaseException] = []  # guarded-by: _error_lock
        self._error_lock = threading.Lock()
        self._closed = False
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                fn, fut = item
                if not fut.set_running_or_notify_cancel():
                    continue
                try:
                    fut.set_result(fn())
                except BaseException as e:
                    fut.set_exception(e)
                    if self._latch:
                        with self._error_lock:
                            if self._error is None and not any(
                                    e is d for d in self._delivered):
                                self._error = e
            finally:
                self._q.task_done()

    def submit(self, fn) -> Future:  # thread: any
        """Queue ``fn``; blocks when the queue is full (backpressure)."""
        if self._closed:
            raise RuntimeError(f"worker {self.name!r} is closed")
        fut: Future = Future()
        self._q.put((fn, fut))
        return fut

    def consume_error(self, error: BaseException) -> None:
        """Mark ``error`` as delivered: a caller that just re-raised a task
        future's exception clears the latch so drain()/close() don't report
        the same failure again.  The instance is remembered, so a *later*
        task that fails with the very same exception object (a poisoned
        pipeline failing fast — see the session's Adam stage) can never
        re-latch a failure that was already delivered."""
        with self._error_lock:
            if not any(error is d for d in self._delivered):
                self._delivered.append(error)
            if self._error is error:
                self._error = None

    def drain(self) -> None:
        """Wait until every queued task ran; re-raise the first failure.

        The latched error is cleared once raised — error paths that drain
        again (to guarantee the queue is empty) don't see it twice.
        """
        self._q.join()
        with self._error_lock:
            error, self._error = self._error, None
        if error is not None:
            raise error

    def close(self) -> None:
        """Run out the queue, stop the thread, re-raise a latched failure.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._thread.join()
        with self._error_lock:
            error, self._error = self._error, None
        if error is not None:
            raise error


class DeviceSlots:
    """Counted device-staging budget per shape class (the H2D double buffer).

    ``depths[cls]`` is 2 × the largest number of class-``cls`` tensors any
    single unit streams: one unit's worth resident for compute plus one
    being staged by the H2D worker.  :meth:`acquire` blocks the *worker*
    (never the compute thread) until ``ReleaseOp`` returns the older unit's
    slots, which is exactly the Fig. 6 rotation.

    Deadlock-freedom: only the single H2D worker acquires, strictly in
    fetch order, and every unit the compute thread is waiting on sits at or
    before the worker's queue head, with all earlier units already released
    — so the blocked acquire always has a live releaser.
    """

    def __init__(self, depths: dict[str, int]) -> None:
        for cls, d in depths.items():
            if d < 2:
                raise ValueError(f"device slot class {cls!r} needs depth >= "
                                 f"2 (compute + staging), got {d}")
        self._depths = dict(depths)          # immutable after init
        self._free = dict(depths)            # guarded-by: _cv
        self._cv = threading.Condition()

    def acquire(self, class_name: str) -> None:  # thread: h2d-worker
        with self._cv:
            while self._free[class_name] < 1:
                self._cv.wait()
            self._free[class_name] -= 1

    def release_all(self, class_names) -> None:  # thread: executor, h2d-worker
        """Return one slot per entry of ``class_names`` (a unit's tokens)."""
        with self._cv:
            for cls in class_names:
                if self._free[cls] >= self._depths[cls]:
                    raise ValueError(f"over-release of device slot class "
                                     f"{cls!r}")
                self._free[cls] += 1
            self._cv.notify_all()

    def idle(self) -> bool:
        """True when every slot is free — the leak probe for tests."""
        with self._cv:
            return self._free == self._depths


@dataclass
class OverlapStats:
    """Compute-thread-visible stall counters for the overlapped legs.

    ``h2d_wait_seconds`` is what :class:`~repro.core.swapper.SwapStats.
    wait_seconds` is to SSD reads: the time the executor actually blocked
    at a FetchOp waiting for staged device weights.  Under full overlap the
    swapper's own wait moves onto the H2D worker thread (off the critical
    path) and this is the number that should stay near zero instead.
    ``kv_stage_wait_seconds`` is the cached-decode analogue: executor
    blocking at a KVReadOp for a staged KV window (page refill waits move
    onto the staging worker and into the KV cache's own wait ledger).

    Most fields are mutated by the single executor thread only.  The two
    worker-side counters — ``optim_prefetch_wait_seconds`` (the optimizer
    worker blocked on a state-prefetch future inside the Adam stage) and
    ``overflow_screen_seconds`` (per-region Inf/NaN screens, paid on the
    gradient-writer thread under full overlap) — are accumulated through
    :meth:`add_worker_seconds`, which locks.
    """

    fetch_seconds: float = 0.0  # total FetchOp blocking: read wait + H2D,
    #                             whichever thread originally paid it — the
    #                             mode-comparable "fetch+H2D wait" number
    h2d_gets: int = 0           # FetchOps served from the staging pipeline
    h2d_hits: int = 0           # device weights ready when the FetchOp asked
    h2d_wait_seconds: float = 0.0
    kv_stage_gets: int = 0      # KVReadOps served from the staging pipeline
    kv_stage_hits: int = 0      # KV window staged when the KVReadOp asked
    kv_stage_wait_seconds: float = 0.0  # executor blocked on staged KV
    gradwrite_drain_seconds: float = 0.0  # OverflowCheckOp writer-drain stall
    optim_gate_seconds: float = 0.0       # prefetch blocked on step k-1 Adam
    act_save_wait_seconds: float = 0.0  # executor blocked on an act save
    #                                     (ActFetchOp gating on its unit's
    #                                     still-pending save, or sync-mode
    #                                     inline D2H + store write)
    act_fetch_wait_seconds: float = 0.0  # executor blocked at an ActFetchOp
    #                                      for a staged checkpoint
    act_stage_gets: int = 0     # ActFetchOps served from the staging pipeline
    act_stage_hits: int = 0     # checkpoint staged when the ActFetchOp asked
    expert_stage_gets: int = 0  # ExpertFetchOps served from the pipeline
    expert_stage_hits: int = 0  # routed set covered by the prestaged stack
    expert_fetch_wait_seconds: float = 0.0  # executor blocked at an
    #                                         ExpertFetchOp for staged stacks
    expert_fetch_bytes: int = 0  # expert bytes copied into H2D stacks
    #                              (routed-only vs all-resident ledger);
    #                              accrued via bump() on the staging worker
    optim_prefetch_wait_seconds: float = 0.0  # Adam blocked on staged state
    overflow_screen_seconds: float = 0.0      # per-region Inf/NaN screens
    act_save_seconds: float = 0.0  # D2H + store write on the writer thread
    act_write_failures: int = 0    # SSD act writes that fell back to host
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add_worker_seconds(self, name: str, dt: float) -> None:
        """Accumulate a worker-thread stall into ``name`` (lock-guarded —
        the Adam stage and the gradient writer report from their own
        threads while the executor reads snapshots)."""
        with self._lock:
            setattr(self, name, getattr(self, name) + dt)

    def bump(self, name: str, n: int = 1) -> None:
        """Increment a worker-thread counter (lock-guarded — e.g. the
        gradient writer recording an act-write SSD fallback)."""
        with self._lock:
            setattr(self, name, getattr(self, name) + n)

    def snapshot(self) -> dict:
        with self._lock:
            worker = {
                "optim_prefetch_wait_seconds": self.optim_prefetch_wait_seconds,
                "overflow_screen_seconds": self.overflow_screen_seconds,
                "act_save_seconds": self.act_save_seconds,
                "act_write_failures": self.act_write_failures}
        return {"fetch_seconds": self.fetch_seconds,
                "h2d_gets": self.h2d_gets, "h2d_hits": self.h2d_hits,
                "h2d_wait_seconds": self.h2d_wait_seconds,
                "kv_stage_gets": self.kv_stage_gets,
                "kv_stage_hits": self.kv_stage_hits,
                "kv_stage_wait_seconds": self.kv_stage_wait_seconds,
                "gradwrite_drain_seconds": self.gradwrite_drain_seconds,
                "optim_gate_seconds": self.optim_gate_seconds,
                "act_save_wait_seconds": self.act_save_wait_seconds,
                "act_fetch_wait_seconds": self.act_fetch_wait_seconds,
                "act_stage_gets": self.act_stage_gets,
                "act_stage_hits": self.act_stage_hits,
                "expert_stage_gets": self.expert_stage_gets,
                "expert_stage_hits": self.expert_stage_hits,
                "expert_fetch_wait_seconds": self.expert_fetch_wait_seconds,
                "expert_fetch_bytes": self.expert_fetch_bytes, **worker}
