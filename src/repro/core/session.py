"""OffloadSession: owns the offload lifecycle and executes StreamPlans.

One session = one open store/allocator/pool/swapper(/optimizer) stack over
an :class:`~repro.core.offload_engine.OffloadableModel`.  It is a context
manager — ``with OffloadSession(model, policy) as s: s.train_step(...)`` —
so the pinned arena, gradient flat buffer, and in-flight SSD reads are
always drained and returned, success or error.

Execution is plan-driven (:mod:`repro.core.stream_plan`) with **lookahead-N
pipelining**: when the executor reaches a :class:`FetchOp` it first issues
async SSD reads for the next ``lookahead`` units in the plan's fetch order,
then blocks only on the unit it needs *now*.  Block *i+1*'s read therefore
overlaps block *i*'s H2D + compute; depth is bounded by
``policy.inflight_blocks``, which is exactly what sizes the pool (paper
§IV-B), so the prefetch window can never oversubscribe pool slots — the
pool's own backpressure is the safety net.  ``lookahead=1`` degenerates to
the seed engine's synchronous per-unit fetches (the benchmark baseline).

On top of the read pipeline, ``policy.overlap`` turns on the remaining legs
of the paper's Fig. 6 full overlap (see :mod:`repro.core.overlap`):

* ``"h2d"``  — FetchOp splits into an issue half and a wait half.  An H2D
  worker stages completed SSD reads into double-buffered device slots
  (two units' worth per shape class) under the previous block's compute;
  the FetchOp then only waits for staged device weights.
* ``"full"`` — additionally, GradWriteOp enqueues its D2H + flat-buffer
  scatter on a bounded writer thread (backward D2H overlaps the next
  block's re-fetch/recompute), and the plan's OptimStepOps run on an
  optimizer worker: step *k*'s subgroup-streamed host Adam interleaves
  with step *k+1*'s forward prefetch window, with per-unit readiness
  futures gating the next step's fetch (weights must be post-update on
  the store) and grad write-back (the flat-buffer region must have been
  consumed).  The Adam stage is itself pipelined: a state-prefetch worker
  streams subgroup *k+1*'s (master, m, v) into a double-buffered staging
  arena while subgroup *k*'s arithmetic runs, and subgroup *k−1*'s
  write-backs drain behind them; readiness futures resolve at commit.
  SSDTrain (arXiv 2408.10013) pipelines across steps the same way.
  Fused-check policies also screen each unit's flat-buffer region for
  Inf/NaN as its write-back lands (on the writer thread), so the overflow
  barrier only ORs per-region verdicts instead of scanning the whole
  buffer.  Numerics are identical in every mode — the same float ops run
  in the same order, only the thread that pays the wait changes.

Activation checkpoints stream the same way (``policy.act_policy``): each
block's ActSaveOp runs its D2H + optional SSD write on the gradient-writer
thread under full overlap (the forward no longer pays a blocking
``np.asarray`` on the executor), and the backward's ActFetchOps split into
issue/wait halves riding the H2D staging worker under a dedicated
ACT-class device slot, so block *i−1*'s checkpoint streams back under
block *i*'s ``block_bwd``.  ``recompute``-tier blocks save nothing and
re-run the previous block's forward instead (see
:func:`repro.core.stream_plan.resolve_act_policy`).

The session runs four workloads through the same machinery:

* ``train_step``   — compile_train plan: forward/backward streaming +
                     overflow screen + loss scaler + subgroup-streamed
                     host Adam, all as plan ops,
* ``eval_loss``    — compile_eval plan (jitted head loss cached once),
* ``decode_logits``— compile_decode plan (weight-streamed serving,
                     uncached full-prefix pass; see
                     :mod:`repro.serve.offloaded`),
* ``prefill`` / ``decode_step`` — cached decode over a *paged* spill-able
                     KV cache (:mod:`repro.core.kv_cache`): sessions built
                     with ``decode=DecodeSpec(...)`` reserve page-granular
                     ``kv``-class pool slots in the census, stream each
                     layer's KV pages next to its weights, and bucket the
                     time axis so every jitted stage compiles once per
                     bucket.  Under ``overlap`` ≠ ``"sync"`` the KVReadOp
                     splits like FetchOp: the attended window's page
                     gather + H2D runs on the staging worker under the
                     previous block's compute, double-buffered by a ``kv``
                     device-slot class — no synchronous transfer is left
                     in the serving hot loop.

``mode="serve"`` opens a leaner session: no optimizer state is written to
the store and no gradient flat buffer is pinned — only the compute-precision
weights stream.
"""

from __future__ import annotations

import contextlib
import functools
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

import jax
import jax.numpy as jnp

from .buffer_pool import KV_CLASS
from .kv_cache import DecodeSpec, SpillableKVCache
from .loss_scale import DynamicLossScaler
from .memory_tracker import MemoryTracker
from .optimizer import OffloadedAdam
from .overflow import check_region, flat_overflow_check
from .overlap import (ACT_CLASS, EXPERT_CLASS, DeviceSlots, OverlapStats,
                      SerialWorker, done_future)
from .paged import ExpertPageCache
from .stream_plan import (ActFetchOp, ActSaveOp, ComputeOp, ExpertFetchOp,
                          ExpertReleaseOp, FetchOp, GradWriteOp, KVReadOp,
                          KVWriteOp, OptimStepOp, OverflowCheckOp, ReleaseOp,
                          StreamPlan, compile_decode, compile_decode_cached,
                          compile_decode_verify, compile_eval,
                          compile_prefill, compile_train,
                          resolve_act_policy)
from .swapper import ParameterSwapper

COMPUTE_SUFFIX = OffloadedAdam.COMPUTE


def jit_cache_size(fn) -> int:
    """Compiled-trace count of one ``jax.jit`` callable.

    jax exposes this only through the private ``_cache_size`` probe on the
    jitted wrapper — stable across the versions this repo pins, but not
    public API.  Guarded here (the single place the repo touches it) so a
    jax upgrade that removes the probe fails with a pointed message at the
    probe site instead of an ``AttributeError`` deep inside a benchmark.
    """
    probe = getattr(fn, "_cache_size", None)
    if not callable(probe):
        raise RuntimeError(
            "this jax build exposes no jit trace-count probe (the private "
            "_cache_size method); update repro.core.session.jit_cache_size "
            "for its replacement")
    return int(probe())


def verify_bucket(n: int) -> int:
    """Speculative-verify window K bucketed to the next power of two.

    The verify plan's jitted stages are shape-polymorphic over the window
    width K, so K is time-bucketed like every other decode shape: padding
    a draft of ``n`` real tokens to the covering power of two keeps the
    warm trace set bounded by ``{1, 2, 4, ...} × extent buckets`` no
    matter how ragged the drafts run.  Padding token K/V is appended and
    then rolled back with the rejected tail (the accept prefix can never
    reach into the padding — a draft's real length bounds it)."""
    if n < 1:
        raise ValueError(f"verify window must be >= 1 token, got {n}")
    return 1 << (n - 1).bit_length()


class _ActCkpt:
    """One block's activation checkpoint, tracked through its tiers.

    ``tier`` walks ``device`` (just saved: ``value`` is the device array)
    → ``host`` (ActSaveOp D2H'd it: ``value`` is a host ndarray, ``handle``
    its tracker allocation) → ``ssd`` (the store holds the bytes; only
    ``shape``/``np_dtype`` remain) → ``ready`` (ActFetchOp staged it back:
    ``value`` is a device array again, ``slot`` set if it holds an
    ACT_CLASS device slot).  ``fut`` is the in-flight ActSaveOp future
    while the gradient-writer thread runs the offload; the executor only
    reads the tier fields after ``fut`` resolves (the Future is the
    happens-before edge), or after an inline save on its own thread."""

    __slots__ = ("unit", "tier", "value", "handle", "shape", "np_dtype",
                 "dtype", "fut", "slot")

    def __init__(self, unit, value):
        self.unit = unit
        self.tier = "device"
        self.value = value
        self.handle = None      # tracker handle while a host copy is live
        self.shape = None       # ssd tier: host array shape
        self.np_dtype = None    # ssd tier: host array dtype
        self.dtype = value.dtype
        self.fut = None         # pending ActSaveOp (writer-thread) future
        self.slot = False       # value holds an ACT_CLASS device slot


class _ExecState:
    """Per-plan-run bindings and carried activations/cotangents."""

    __slots__ = ("tokens", "labels", "scale", "grad_scale", "h", "dh",
                 "loss", "logits", "live", "live_slots", "h2d", "grads",
                 "checkpoints", "overflowed", "apply", "optim_begun",
                 "kv", "kv_live", "kv_append", "kv_time", "cache_len",
                 "last_pos", "kv_stage", "kv_slots", "kv_write_slots",
                 "stage_seq", "act_order", "act_next", "act_stage",
                 "act_reads", "act_slots_out", "expert_route",
                 "expert_stage", "expert_live", "expert_slots",
                 "expert_slots_out")

    def __init__(self, tokens=None, labels=None, scale=1.0):
        self.tokens = None if tokens is None else jnp.asarray(tokens)
        self.labels = None if labels is None else jnp.asarray(labels)
        self.scale = jnp.asarray(scale, dtype=jnp.float32)
        self.grad_scale = float(scale)   # host copy for the optimizer ops
        self.h = self.dh = self.loss = self.logits = None
        self.live: dict[str, dict] = {}     # unit -> device params
        self.live_slots: dict[str, tuple] = {}  # unit -> device-slot tokens
        self.h2d: dict[str, deque] = {}     # unit -> staged-fetch futures
        self.grads: dict[str, dict] = {}    # unit -> device grads
        self.checkpoints: dict[str, tuple] = {}  # unit -> saved block input
        self.overflowed: bool | None = None  # set by OverflowCheckOp
        self.apply: bool | None = None       # set by OverflowCheckOp
        self.optim_begun = False             # begin_step() sequenced once
        # cached-decode bindings (prefill / decode_cached plans only)
        self.kv: SpillableKVCache | None = None
        self.kv_live: dict[str, tuple] = {}    # unit -> device (k, v) window
        self.kv_append: dict[str, tuple] = {}  # unit -> device (k, v) to land
        self.kv_stage: dict[str, Future] = {}  # unit -> staged-KV future
        self.kv_slots: dict[str, tuple] = {}   # unit -> kv device-slot tokens
        self.kv_time = 0          # device-cache bucket extent this run
        self.cache_len = None     # traced: tokens already cached (scalar on
        #                           the joint path, (B,) per-slot vector on
        #                           the continuous-batching path)
        self.last_pos = None      # traced: last prompt index (prefill head;
        #                           scalar or (B,) like cache_len)
        self.kv_write_slots = None  # prefill-scatter target slots (runtime
        #                             state, NOT plan state: plans stay
        #                             static across join/retire churn)
        # (kind, unit) per staging-worker submission, in FIFO order —
        # "w" weight stages, "kv" window stages, and "act" checkpoint
        # stages interleave on ONE worker, so the abort path must drain
        # them in this exact order
        self.stage_seq: list[tuple[str, str]] = []
        # activation-checkpoint streaming (train plans with host/ssd tiers)
        self.act_order: list[str] = []   # plan's ActFetchOp units, in order
        self.act_next = 0                # first act fetch not yet issued
        self.act_stage: dict[str, Future] = {}  # unit -> staged-ckpt future
        self.act_reads: dict[str, tuple] = {}   # unit -> (fut, buf, handle)
        #                                         sync-mode SSD act reads
        self.act_slots_out = 0   # ACT_CLASS submissions not yet consumed —
        #                          capped at the slot depth so the staging
        #                          worker's acquire can never block
        # expert paging (paged-MoE plans only): the routing indices persist
        # for the WHOLE plan run — the backward's ExpertFetchOp reuses the
        # forward's routing decision, so its prestage is an exact hit
        self.expert_route: dict[str, np.ndarray] = {}  # unit -> host (T,k)
        self.expert_stage: dict[str, deque] = {}  # unit -> staged-stack futs
        self.expert_live: dict[str, tuple] = {}   # unit -> device stacks
        self.expert_slots: dict[str, tuple] = {}  # unit -> EXPERT_CLASS tokens
        self.expert_slots_out = 0  # EXPERT_CLASS submissions whose slot has
        #                            not been returned yet — capped at the
        #                            slot depth so the staging worker's
        #                            acquire can never block the pipeline


class OffloadSession:
    """Executes StreamPlans over one open offload stack (context manager)."""

    def __init__(self, model, policy, *, tracker: MemoryTracker | None = None,
                 mode: str = "train",
                 decode: DecodeSpec | None = None) -> None:
        if mode not in ("train", "serve"):
            raise ValueError(f"mode must be 'train' or 'serve', got {mode!r}")
        self.model = model
        self.policy = policy
        self.mode = mode
        self.tracker = tracker or MemoryTracker()
        self.store = policy.store_factory()
        # The store is open from here on: if any later construction step
        # fails (disk-full while seeding optimizer state, MemoryError on
        # the flat buffer), __enter__ never runs and no caller can close()
        # — release whatever was acquired before re-raising.
        self._closed = False
        try:
            self._construct(model, policy, mode, decode)
        except BaseException:
            self.close()
            raise

    # pre-share: runs inside __init__, before any worker thread exists
    def _construct(self, model, policy, mode: str,  # analyze: pre-share
                   decode: DecodeSpec | None) -> None:
        self.allocator = policy.allocator_cls(
            tracker=self.tracker, component="pinned", backing="numpy")
        # Expert paging (paged MoE): resolved before the census because the
        # paged units' per-expert tensors leave the per-block streaming
        # counts and become standalone expert-page slots instead.
        self._expert_mode = policy.expert_paging
        self._expert_meta = getattr(model, "expert_meta", None) or {}
        if self._expert_mode != "off" and not self._expert_meta:
            raise ValueError(
                f"expert_paging={self._expert_mode!r} but the model has no "
                f"paged-MoE units; build it with make_offloadable_lm(..., "
                f"expert_paging=...) so expert tensors split into pages")
        if self._expert_mode == "off" and self._expert_meta:
            raise ValueError(
                "model was built with per-expert pages (expert_meta set) "
                "but the policy streams experts densely "
                "(expert_paging='off'); the dense block apply would miss "
                "the stacked expert weights — align the two knobs")
        self._paged_params: dict[str, frozenset] = (
            {u: frozenset(model.expert_params(u)) for u in self._expert_meta}
            if self._expert_mode != "off" else {})
        expert_pages: dict[tuple[str, str], tuple] = {}
        if self._expert_mode != "off":
            for uname in self._expert_meta:
                unit = next(u for u in model.units if u.name == uname)
                for pname in self._paged_params[uname]:
                    expert_pages[(uname, pname)] = unit.params[pname].shape
            budget = policy.expert_page_slots or len(expert_pages)
        self._expert_cache: ExpertPageCache | None = None
        self._expert_prior: dict[str, np.ndarray] = {}
        census = model.census(
            policy.inflight_blocks,
            bytes_per_elem=policy.adam.compute_np_dtype.itemsize,
            expert_page_slots=(budget if self._expert_mode != "off"
                               else None))
        # Cached decode: the KV cache draws slots from the same pool arena
        # the weights stream through, so its residency budget is part of
        # the census (paper §IV-B sizing, extended to decode state).
        self.decode_spec = decode
        self._kv_units = tuple(u.name for u in model.units[1:-1])
        self._kv_page_shape = None
        self._kv_resident = 0
        self._kv_cache: SpillableKVCache | None = None
        if decode is not None:
            if model.block_step is None or model.kv_shape is None:
                raise ValueError(
                    "model has no cached-decode applies (block_step/"
                    "kv_shape); decode=DecodeSpec(...) needs an attention-"
                    "mixer family (see model_adapter.make_offloadable_lm)")
            if not self._kv_units:
                raise ValueError("model has no block units to cache KV for")
            # Page-granular census: one kv-class slot per page of
            # ``spec.page_size`` tokens; the budget is the paged cache's
            # host-residency limit (paper §IV-B sizing, extended to decode
            # state at block-table granularity).  Pages are per batch slot
            # (single-row) so continuous batching can reclaim one request's
            # pages without touching its neighbours'; the spec's
            # per-request budget scales by batch to keep the same bytes.
            self._kv_resident = (decode.page_budget(len(self._kv_units))
                                 * decode.batch)
            self._kv_page_shape = tuple(
                model.kv_shape(1, decode.page_size))
            kv_nbytes = int(policy.adam.compute_np_dtype.itemsize * np.prod(
                self._kv_page_shape, dtype=np.int64))
            census = census.with_kv(kv_nbytes, self._kv_resident)
        self.pool = policy.pool_cls(census, self.allocator)
        # Paged expert tensors are NOT swapper-streamed: they go through
        # the expert page cache below, one page per (unit, param).
        self.swapper = ParameterSwapper(self.store, self.pool, class_of={
            f"{unit.name}/{key}{COMPUTE_SUFFIX}": model.class_of(key)
            for unit in model.units for key in unit.params
            if key not in self._paged_params.get(unit.name, frozenset())})
        if self._expert_mode != "off":
            # lazy reads: pages are born spilled against the {key}.compute
            # store copies the registration loop below writes, so creating
            # the cache before them is safe — nothing reads until a fetch
            self._expert_cache = ExpertPageCache(
                expert_pages, policy.adam.compute_np_dtype, self.pool,
                self.store, resident_limit=budget,
                store_suffix=COMPUTE_SUFFIX)
        self.scaler = DynamicLossScaler()
        if policy.adam.compute_dtype != "float16":
            self.scaler.scale = 1.0  # only fp16 needs scaling; check stays on
        self.compute_dtype = {"bfloat16": jnp.bfloat16,
                              "float16": jnp.float16,
                              "float32": jnp.float32}[
            policy.adam.compute_dtype]
        lookahead = policy.lookahead or policy.inflight_blocks
        self.lookahead = max(1, min(lookahead, policy.inflight_blocks))

        # Per-block activation-checkpoint tiers (train mode): resolved once
        # so a bad act_policy fails here, not at the first train_step.
        # offload_checkpoints=False keeps every checkpoint on device.
        block_names = [u.name for u in model.units[1:-1]]
        self._act_tiers: tuple[str, ...] = ()
        if mode == "train" and block_names:
            self._act_tiers = resolve_act_policy(
                block_names,
                policy.act_policy if policy.offload_checkpoints
                else "device")

        # Full-overlap machinery (policy.overlap; see module docstring and
        # repro.core.overlap).  Created before the store writes below so a
        # mid-construction failure still finds them on the close() path.
        self.overlap = policy.overlap
        self._ostats = OverlapStats()
        self._optim_lock = threading.Lock()
        self._optim_futures: dict[str, Future] = {}  # guarded-by: _optim_lock
        self._optim_io_completed = 0                 # guarded-by: _optim_lock
        self._device_slots: DeviceSlots | None = None
        self._h2d: SerialWorker | None = None
        self._grad_writer: SerialWorker | None = None
        self._optim_worker: SerialWorker | None = None
        self._optim_prefetch: SerialWorker | None = None
        # Adam-stage subgroup pipeline bookkeeping (see _exec_optim):
        # _adam_work is appended by the executor thread under _adam_lock
        # and read by the optimizer worker; the issue counter and in-flight
        # deque are touched by the optimizer worker only (tasks are FIFO
        # on its single thread).
        self._adam_lock = threading.Lock()
        # (unit, param key) pairs:
        self._adam_work: list[tuple[str, str]] = []   # guarded-by: _adam_lock
        self._adam_issued = 0
        self._adam_inflight: deque = deque()          # (index, staged fut)
        self._adam_poison: BaseException | None = None
        # per-subgroup overflow screen: verdicts land per unit (writer
        # thread under full overlap) and are OR-ed at the barrier.
        self._screen_lock = threading.Lock()
        self._region_verdicts: dict[str, bool] = {}  # guarded-by: _screen_lock
        self._screen_regions = policy.fused_overflow and mode == "train"
        if policy.overlap in ("h2d", "full"):
            per_unit: dict[str, int] = {}
            for unit in model.units:
                paged = self._paged_params.get(unit.name, frozenset())
                counts: dict[str, int] = {}
                for key in unit.params:
                    if key in paged:
                        continue   # staged as (E, ...) stacks, not per-key
                    cls = model.class_of(key)
                    counts[cls] = counts.get(cls, 0) + 1
                for cls, c in counts.items():
                    per_unit[cls] = max(per_unit.get(cls, 0), c)
            # Two units' worth of device buffers per shape class: one in
            # use by compute, one being staged — the Fig. 6 double buffer.
            depths = {cls: 2 * c for cls, c in per_unit.items()}
            if decode is not None:
                # staged KV windows double-buffer too: one block's (K, V)
                # in use by compute, one being gathered + H2D'd
                depths[KV_CLASS] = 2
            if any(t in ("host", "ssd") for t in self._act_tiers):
                # staged activation checkpoints double-buffer the same way:
                # one consumed by the current block_bwd, one being staged
                depths[ACT_CLASS] = 2
            if self._expert_mode != "off":
                # staged expert (E, ...) stacks double-buffer: one triple
                # feeding the current block_moe, one being staged ahead
                depths[EXPERT_CLASS] = 2
            self._device_slots = DeviceSlots(depths)
            # latch=False: every staging future is awaited by the executor
            # (FetchOp wait half, or the abort path), which delivers any
            # failure — a close()-time re-raise would double-report it.
            self._h2d = SerialWorker("offload-h2d", latch=False)
        if policy.overlap == "full" and mode == "train":
            self._grad_writer = SerialWorker("offload-gradwrite", maxsize=4)
            self._optim_worker = SerialWorker("offload-optim")
            # The Adam stage's own I/O thread: issues (state reads into the
            # double-buffered staging arena) and commits (write-backs)
            # both run here, submitted in an order that keeps the arena's
            # blocking acquire always satisfiable (see
            # _optim_unit_pipelined).  latch=False: every future is
            # awaited by the optimizer worker, which delivers failures
            # through the unit readiness future — a close()-time re-raise
            # would double-report.
            self._optim_prefetch = SerialWorker("offload-optim-prefetch",
                                                latch=False)

        # Register every parameter.  Train mode seeds master weights + Adam
        # moments on the store; serve mode writes only compute weights.
        self.optimizer = (OffloadedAdam(self.store, policy.adam,
                                        tracker=self.tracker)
                          if mode == "train" else None)
        if self.optimizer is not None:
            # stale-read guard on the Adam commit's compute-weight write:
            # the per-unit readiness gates guarantee no prefetched read of
            # a unit's weights is in flight while its commit writes them —
            # assert it at the write site (see swapper.assert_not_in_flight)
            self.optimizer.write_guard = self._guard_compute_write
        cd = policy.adam.compute_np_dtype
        self._unit_param_meta: list[tuple] = []
        self._units: dict[str, tuple] = {}
        total_params = 0
        for unit in model.units:
            meta = {}
            for key, value in unit.params.items():
                if self.optimizer is not None:
                    self.optimizer.register(f"{unit.name}/{key}", value)
                else:
                    self.store.write(f"{unit.name}/{key}{COMPUTE_SUFFIX}",
                                     value.astype(cd))
                meta[key] = (value.shape, value.size)
                total_params += value.size
            self._unit_param_meta.append((unit, meta))
            self._units[unit.name] = (unit, meta)
        self.total_params = total_params

        # Gradient flat buffer: fp32, whole partition, lives for the session
        # (train mode only — serving never materializes gradients).
        if mode == "train":
            self._flat_buf = self.allocator.alloc(total_params * 4,
                                                  tag="gradient_flat_buffer")
            self.flat = self._flat_buf.view(np.float32, (total_params,))
            self._flat_offsets: dict[str, tuple[int, int, tuple]] = {}
            self._unit_flat_region: dict[str, tuple[int, int]] = {}
            off = 0
            for unit, meta in self._unit_param_meta:
                lo = off
                for key, (shape, size) in meta.items():
                    self._flat_offsets[f"{unit.name}/{key}"] = (
                        off, size, shape)
                    off += size
                # a unit's parameters are contiguous in the flat buffer:
                # [lo, off) is the region its per-subgroup screen covers
                self._unit_flat_region[unit.name] = (lo, off)
        else:
            self._flat_buf = None
            self.flat = None

        # jitted per-stage functions (shared across blocks of equal shapes);
        # the eval head loss is jitted ONCE here, not per eval_loss call.
        self._jit_embed = jax.jit(model.embed_apply)
        self._jit_block = jax.jit(model.block_apply)
        self._jit_head = jax.jit(self._head_loss_and_grads)
        self._jit_head_loss = jax.jit(model.head_loss)
        self._jit_block_bwd = jax.jit(self._block_bwd)
        self._jit_embed_bwd = jax.jit(
            lambda p, t, dy: jax.vjp(model.embed_apply, p, t)[1](dy)[0])
        self._jit_head_logits = (jax.jit(model.head_logits)
                                 if getattr(model, "head_logits", None)
                                 else None)
        self._jit_block_prefill = (jax.jit(model.block_prefill)
                                   if getattr(model, "block_prefill", None)
                                   else None)
        # chunk is static: it selects the reduction grid that makes a
        # row's attention bitwise invariant to the shared device extent
        # (without it, a co-lane crossing a bucket boundary regroups the
        # softmax/PV reductions and can flip a near-tie greedy argmax)
        self._jit_block_step = (jax.jit(model.block_step,
                                        static_argnames=("chunk",))
                                if getattr(model, "block_step", None)
                                else None)
        self._jit_block_verify = (jax.jit(model.block_verify,
                                          static_argnames=("chunk",))
                                  if getattr(model, "block_verify", None)
                                  else None)
        # expert-paged MoE stages (route half / expert half / backward,
        # plus the cached-decode route variants)
        paged_moe = self._expert_mode != "off"
        self._jit_block_route = (jax.jit(model.block_route)
                                 if paged_moe else None)
        self._jit_block_moe = (jax.jit(model.block_moe)
                               if paged_moe else None)
        self._jit_block_moe_bwd = (jax.jit(model.block_moe_bwd)
                                   if paged_moe else None)
        self._jit_prefill_route = (
            jax.jit(model.block_prefill_route) if paged_moe
            and getattr(model, "block_prefill_route", None) else None)
        self._jit_step_route = (
            jax.jit(model.block_step_route, static_argnames=("chunk",))
            if paged_moe and getattr(model, "block_step_route", None)
            else None)
        self._jit_verify_route = (
            jax.jit(model.block_verify_route, static_argnames=("chunk",))
            if paged_moe and getattr(model, "block_verify_route", None)
            else None)
        self._jit_head_last = None
        if self._jit_head_logits is not None and \
                self._jit_block_prefill is not None:
            def _head_last(params, h, pos):
                # pos is traced: slicing the last valid prompt position out
                # of the padded bucket costs no retrace per prompt length.
                # A scalar pos selects one position for the whole batch
                # (joint prefill); a (B,) pos selects per row (serving
                # prefill, where joiners' prompt lengths differ).
                h_last = (
                    jax.lax.dynamic_slice_in_dim(h, pos, 1, axis=1)
                    if pos.ndim == 0
                    else jnp.take_along_axis(h, pos[:, None, None], axis=1))
                return model.head_logits(params, h_last)
            self._jit_head_last = jax.jit(_head_last)

        self._plans: dict[str, StreamPlan] = {}
        self.metrics: dict = {}

    # -- lifecycle ----------------------------------------------------------

    def __enter__(self) -> "OffloadSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:  # thread: executor
        """Drain in-flight reads and pipeline workers, return the arena +
        flat buffer, close the store.  Idempotent; runs on the error path
        via ``__exit__`` and on partially-constructed sessions (attributes
        may not exist yet).

        Worker order matters: the H2D worker goes first (its queued jobs
        own swapper tickets), then the gradient writer (its tasks may gate
        on optimizer futures, so the optimizer worker must still be alive),
        then the optimizer worker (whose unit tasks wait on state-prefetch
        futures, so that worker must still be alive), then the
        state-prefetch worker, and only then the swapper drain that sweeps
        any ticket nobody claimed.  The optimizer's staging arena is freed
        after every worker that touches it has stopped."""
        if getattr(self, "_closed", True):
            return
        self._closed = True
        steps = []
        if getattr(self, "_kv_cache", None) is not None:
            steps.append(self._kv_cache.close)
        if getattr(self, "_expert_cache", None) is not None:
            steps.append(self._expert_cache.close)
        for worker_attr in ("_h2d", "_grad_writer", "_optim_worker",
                            "_optim_prefetch"):
            worker = getattr(self, worker_attr, None)
            if worker is not None:
                steps.append(worker.close)
        if getattr(self, "optimizer", None) is not None:
            steps.append(self.optimizer.close)
        if getattr(self, "swapper", None) is not None:
            steps.append(self.swapper.drain)
        if getattr(self, "pool", None) is not None:
            steps.append(self.pool.close)
        if getattr(self, "_flat_buf", None) is not None:
            steps.append(self._flat_buf.free)
        steps.append(self.store.close)
        # every step must run even if an earlier one raises (e.g. an
        # interrupt re-raised out of drain) — otherwise the arena/flat
        # buffer/store leak with no way to retry; first failure re-raises.
        failure = None
        for step in steps:
            try:
                step()
            except BaseException as e:
                if failure is None:
                    failure = e
        if failure is not None:
            raise failure

    def synchronize(self) -> None:  # thread: executor
        """Drain the cross-step pipeline: wait out queued gradient
        write-backs and the in-flight optimizer stage, re-raising their
        failures.  The executor's per-unit readiness gates make this
        unnecessary for correctness between train steps; call it to close
        a timing window, read complete ``optimizer_io_bytes``, or compare
        state across overlap modes."""
        if self._grad_writer is not None:
            self._grad_writer.drain()
        if self._optim_worker is not None:
            self._optim_worker.drain()
        if self._optim_prefetch is not None:
            # empty by construction once the optimizer worker drained (unit
            # tasks wait out their own commits); drained for completeness
            self._optim_prefetch.drain()

    # -- plans --------------------------------------------------------------

    def plan(self, name: str) -> StreamPlan:
        """The session's compiled plan for ``name``
        (train/eval/decode/prefill/decode_cached/decode_verify)."""
        if name not in self._plans:
            if name == "train":
                # the resolved per-block tiers ARE the policy (a dict/
                # sequence spec was normalized at construction)
                self._plans[name] = compile_train(
                    self.model, act_policy=self._act_tiers or None)
            else:
                compiler = {"eval": compile_eval,
                            "decode": compile_decode,
                            "prefill": compile_prefill,
                            "decode_cached": compile_decode_cached,
                            "decode_verify": compile_decode_verify}[name]
                self._plans[name] = compiler(self.model)
        return self._plans[name]

    # -- jitted helpers ------------------------------------------------------

    def _head_loss_and_grads(self, params, h, labels, scale):
        def scaled(params, h):
            return self.model.head_loss(params, h, labels) * scale
        sloss, vjp = jax.vjp(scaled, params, h)
        dparams, dh = vjp(jnp.ones((), sloss.dtype))
        return sloss / scale, dparams, dh

    def _block_bwd(self, params, x, dy):
        _, vjp = jax.vjp(self.model.block_apply, params, x)
        dparams, dx = vjp(dy)
        return dparams, dx

    # -- weight streaming ----------------------------------------------------

    def _param_keys(self, unit_name: str):
        unit, meta = self._units[unit_name]
        cd = self.policy.adam.compute_np_dtype
        paged = self._paged_params.get(unit_name, frozenset())
        for key, (shape, _size) in meta.items():
            if key in paged:
                continue   # streamed as expert pages, not with the unit
            yield key, f"{unit.name}/{key}{COMPUTE_SUFFIX}", cd, shape

    def _prefetch_unit(self, unit_name: str) -> None:
        for _key, skey, cd, shape in self._param_keys(unit_name):
            self.swapper.prefetch(skey, cd, shape)

    def _unit_in_flight(self, unit_name: str) -> bool:
        return any(self.swapper.in_flight(skey)
                   for _key, skey, _cd, _shape in
                   self._param_keys(unit_name))

    def _h2d_copy(self, host_view):
        """H2D transfer.  ``copy=True`` alone is NOT enough: jax dispatches
        the copy asynchronously, so without the barrier the pool slot can be
        released, reacquired, and overwritten by the next unit's SSD pread
        before the bytes were actually read — the caller then computes with
        another tensor's weights.  ``block_until_ready`` pins the slot's
        contents until the copy has landed; it blocks the *staging* worker
        (or, in sync mode, the compute thread that was going to wait
        anyway), never an overlapped compute."""
        arr = jnp.array(host_view, copy=True)
        arr.block_until_ready()
        return arr

    def _submit_h2d(self, unit_name: str, state: _ExecState) -> None:
        """Issue half of the split FetchOp: queue SSD-read-wait + H2D onto
        the staging worker; the wait half pops the future in fetch order."""
        fut = self._h2d.submit(
            functools.partial(self._h2d_stage_unit, unit_name))
        state.h2d.setdefault(unit_name, deque()).append(fut)
        state.stage_seq.append(("w", unit_name))

    def _submit_kv_stage(self, unit_name: str, state: _ExecState) -> None:
        """Issue half of the split KVReadOp: queue page-refill waits +
        window gather + H2D onto the staging worker, behind the same
        unit's weight staging; KVReadOp pops the future (wait half)."""
        fut = self._h2d.submit(functools.partial(
            self._stage_kv_unit, state.kv, unit_name, state.kv_time))
        state.kv_stage[unit_name] = fut
        state.stage_seq.append(("kv", unit_name))

    def _stage_kv_unit(self, kv: SpillableKVCache, unit_name: str,  # thread: h2d-worker
                       extent: int) -> tuple:
        """H2D-worker body for one unit's KV window: gather the attended
        window's pages (waiting out / refilling spilled ones) and stage
        device copies under a counted ``kv`` device slot.  The acquire
        blocks the *worker*, never the compute thread, until ReleaseOp
        returns the older window's slot — the same Fig. 6 rotation as the
        weight double buffer."""
        k_host, v_host = kv.gather_window(unit_name, extent)
        self._device_slots.acquire(KV_CLASS)
        try:
            return self._h2d_copy(k_host), self._h2d_copy(v_host)
        except BaseException:
            self._device_slots.release_all([KV_CLASS])
            raise

    def _h2d_stage_unit(self, unit_name: str) -> tuple[dict, list]:  # thread: h2d-worker
        """H2D-worker body: claim the unit's tickets, wait each read,
        stage into device slots, release the pool slots.  Returns
        ``(device_params, slot_tokens)``; on any failure every claimed
        ticket and acquired slot token is returned before re-raising."""
        claims = []
        device_params: dict = {}
        tokens: list[str] = []
        try:
            # Claiming inside the try: a claim pops the ticket out of the
            # swapper's in-flight map (drain() can no longer see it), so a
            # mid-loop failure must release the earlier claims here.
            for key, skey, cd, shape in self._param_keys(unit_name):
                ticket, hit, fallback = self.swapper.claim(skey, cd, shape)
                claims.append([key, skey, ticket, hit, fallback, cd, shape])
            for entry in claims:
                key, skey, ticket, hit, fallback, cd, shape = entry
                t0 = time.perf_counter()
                host_view = ticket.wait()
                self.swapper.record_get(
                    hit=hit, fallback=fallback,
                    wait_seconds=time.perf_counter() - t0)
                self._device_slots.acquire(self.swapper.class_of[skey])
                tokens.append(self.swapper.class_of[skey])
                try:
                    device_params[key] = self._h2d_copy(host_view)
                finally:
                    ticket.release()
                    entry[2] = None       # consumed: skip in cleanup
        except BaseException:
            for entry in claims:
                ticket = entry[2]
                if ticket is None:
                    continue
                try:
                    ticket.wait()
                except BaseException:
                    pass          # data is being discarded
                finally:
                    ticket.release()
            self._device_slots.release_all(tokens)
            raise
        return device_params, tokens

    def _fetch_unit(self, unit_name: str, state: _ExecState) -> dict:
        """Blocking half of the lifecycle: wait for staged device weights
        (overlap mode) or wait the reads + H2D inline (sync mode)."""
        pending = state.h2d.get(unit_name)
        if pending:
            fut = pending.popleft()
            if not pending:
                del state.h2d[unit_name]
            hit = fut.done()
            t0 = time.perf_counter()
            device_params, tokens = fut.result()
            self._ostats.h2d_wait_seconds += time.perf_counter() - t0
            self._ostats.h2d_gets += 1
            self._ostats.h2d_hits += int(hit)
            state.live_slots[unit_name] = tuple(tokens)
            return device_params
        device_params = {}
        for key, skey, cd, shape in self._param_keys(unit_name):
            ticket = self.swapper.get(skey, cd, shape)
            try:
                device_params[key] = self._h2d_copy(
                    ticket.buf.view(cd, shape))
            finally:
                ticket.release()                          # slot back to pool
        return device_params

    # -- cross-step optimizer readiness --------------------------------------

    def _guard_compute_write(self, key: str) -> None:  # thread: executor, optim-worker
        """Adam-commit hook: refreshing ``key``'s compute weights on the
        store while a prefetched read of them is in flight would race the
        pread (the readiness gates forbid it; this asserts it)."""
        self.swapper.assert_not_in_flight(key + COMPUTE_SUFFIX)

    def _optim_ready(self, unit_name: str) -> bool:  # thread: executor
        """True when the unit's previous-step Adam landed *successfully* —
        a done-with-exception future is NOT ready (the store still holds
        pre-update weights), so the window stalls on it until the head
        position's :meth:`_optim_wait` delivers the failure."""
        with self._optim_lock:
            fut = self._optim_futures.get(unit_name)
        return fut is None or (fut.done() and fut.exception() is None)

    def _optim_wait(self, unit_name: str) -> None:  # thread: executor
        """Block until the unit's previous-step Adam write-back landed
        (re-raising an optimizer-worker failure here, at the point the
        stale weights would otherwise have been read)."""
        with self._optim_lock:
            fut = self._optim_futures.get(unit_name)
        if fut is None:
            return
        t0 = time.perf_counter()
        try:
            fut.result()
        except BaseException as e:
            if self._optim_worker is not None:
                self._optim_worker.consume_error(e)   # delivered here
            raise
        self._ostats.optim_gate_seconds += time.perf_counter() - t0

    # -- activation-checkpoint streaming -------------------------------------
    #
    # Lifecycle (mirrors the weight stream's split issue/wait halves):
    #
    #   save    ComputeOp(save_input) binds the device array as an _ActCkpt;
    #           ActSaveOp runs _act_offload on the gradient-writer thread
    #           under full overlap (the D2H + SSD write hide under the next
    #           block's forward compute) and inline otherwise,
    #   fetch   _act_issue_ahead (called inside the FetchOp lookahead
    #           window and at each ActFetchOp) starts the SSD read + H2D
    #           staging for upcoming act fetches, bounded by the ACT_CLASS
    #           device-slot budget; ActFetchOp's _act_fetch only waits,
    #   consume block_bwd takes the device array and returns the slot.
    #
    # Deadlock-freedom of the staged path: the executor never submits an
    # act stage while act_slots_out >= the ACT_CLASS depth, so the staging
    # worker's ACT acquire is always immediately satisfiable — it can
    # never wedge the shared FIFO worker behind an unreleasable slot.

    def _act_key(self, unit: str, nbytes: int) -> str:
        # nbytes in the key: DirectNVMeEngine reuses an existing key's
        # extents and rejects size changes, so a seq-length change must
        # land under a fresh key (keys are overwritten per step, never
        # deleted — the store reuses their extents)
        return f"__act__/{unit}/{nbytes}"

    def _exec_act_save(self, op: ActSaveOp, state: _ExecState) -> None:  # thread: executor
        """ActSaveOp: offload the unit's just-saved checkpoint — on the
        gradient-writer thread (full overlap; idle during the forward
        pass) or inline."""
        rec = state.checkpoints[op.unit]
        if self._grad_writer is not None:
            rec.fut = self._grad_writer.submit(
                functools.partial(self._act_offload, rec, op.tier))
        else:
            t0 = time.perf_counter()
            self._act_offload(rec, op.tier)
            self._ostats.act_save_wait_seconds += time.perf_counter() - t0

    def _act_offload(self, rec: _ActCkpt, tier: str) -> None:  # thread: executor, writer
        """D2H the checkpoint and, for the ssd tier, write it onward to
        the store and free the host copy.  A failed SSD write degrades
        gracefully: the host copy stays live (tracked) and the checkpoint
        serves from the host tier — no data loss, no raised step."""
        t0 = time.perf_counter()
        try:
            host = np.asarray(rec.value)   # D2H
            handle = self.tracker.alloc("activation_checkpoints",
                                        host.nbytes, tag="block_input")
            try:
                if tier == "ssd":
                    try:
                        self.store.write(self._act_key(rec.unit, host.nbytes),
                                         host)
                    except Exception:
                        self._ostats.bump("act_write_failures")
                    else:
                        self.tracker.free(handle)
                        rec.shape, rec.np_dtype = host.shape, host.dtype
                        rec.value, rec.handle = None, None
                        rec.tier = "ssd"
                        return
                rec.value, rec.handle = host, handle
                rec.tier = "host"
            except BaseException:
                # rec stays device-tier; the abort path discards it safely
                self.tracker.free(handle)
                raise
        finally:
            self._ostats.add_worker_seconds("act_save_seconds",
                                            time.perf_counter() - t0)

    def _act_issue_ahead(self, state: _ExecState) -> None:  # thread: executor
        """Issue half of upcoming ActFetchOps: start SSD reads + H2D
        staging for the next offloaded checkpoints, in plan order, so
        block *i−1*'s checkpoint streams back under block *i*'s
        ``block_bwd``.  Stops at a checkpoint whose save is still in
        flight (or failed — the failure surfaces at its ActFetchOp gate)
        and at the ACT slot / lookahead budget."""
        order = state.act_order
        while state.act_next < len(order):
            unit = order[state.act_next]
            rec = state.checkpoints.get(unit)
            if rec is None:
                break              # forward has not saved this one yet
            fut = rec.fut
            if fut is not None:
                if not fut.done():
                    break          # save still in flight on the writer
                if fut.exception() is not None:
                    break          # delivered at the ActFetchOp gate
            if rec.tier not in ("host", "ssd") or unit in state.act_stage \
                    or unit in state.act_reads:
                state.act_next += 1
                continue
            if self._h2d is not None:
                if state.act_slots_out >= 2:
                    break          # ACT_CLASS budget: acquire never blocks
                self._issue_act_stage(unit, rec, state)
            elif rec.tier == "ssd":
                if len(state.act_reads) >= self.lookahead:
                    break
                self._issue_act_read(unit, rec, state)
            # sync-mode host tier: nothing to issue — the H2D is the wait
            state.act_next += 1

    def _issue_act_stage(self, unit: str, rec: _ActCkpt,  # thread: executor
                         state: _ExecState) -> None:
        """Queue one checkpoint's H2D staging (and, for ssd, its async
        store read) on the staging worker, behind the backward pass's
        weight stages."""
        if rec.tier == "ssd":
            buf = np.empty(rec.shape, rec.np_dtype)
            handle = self.tracker.alloc("activation_checkpoints", buf.nbytes,
                                        tag="act_fetch_staging")
            try:
                read_fut = self.store.read_async(
                    self._act_key(unit, buf.nbytes), buf)
            except BaseException:
                self.tracker.free(handle)
                raise
            task = functools.partial(self._act_stage_ssd, read_fut, buf,
                                     handle)
        else:
            task = functools.partial(self._act_stage_host, rec)
        state.act_stage[unit] = self._h2d.submit(task)
        state.stage_seq.append(("act", unit))
        state.act_slots_out += 1

    def _act_stage_ssd(self, read_fut: Future, buf: np.ndarray,  # thread: h2d-worker
                       handle) -> object:
        """Staging-worker body: wait the SSD read, H2D under a counted ACT
        device slot, free the staging buffer.  On failure the slot is
        returned here; the read buffer's tracker handle is always freed
        (the bytes live on device or nowhere)."""
        self._device_slots.acquire(ACT_CLASS)
        try:
            try:
                read_fut.result()
                return self._h2d_copy(buf)
            finally:
                self.tracker.free(handle)
        except BaseException:
            self._device_slots.release_all([ACT_CLASS])
            raise

    def _act_stage_host(self, rec: _ActCkpt) -> object:  # thread: h2d-worker
        """Staging-worker body for a host-tier checkpoint: H2D under a
        counted ACT device slot (the host copy's tracker handle is freed
        by the executor when the staged array is consumed)."""
        self._device_slots.acquire(ACT_CLASS)
        try:
            return self._h2d_copy(rec.value)
        except BaseException:
            self._device_slots.release_all([ACT_CLASS])
            raise

    def _issue_act_read(self, unit: str, rec: _ActCkpt,  # thread: executor
                        state: _ExecState) -> None:
        """Sync-mode issue half: async SSD read into a tracked host
        buffer; the ActFetchOp waits it out and H2Ds inline."""
        buf = np.empty(rec.shape, rec.np_dtype)
        handle = self.tracker.alloc("activation_checkpoints", buf.nbytes,
                                    tag="act_fetch_staging")
        try:
            fut = self.store.read_async(self._act_key(unit, buf.nbytes), buf)
        except BaseException:
            self.tracker.free(handle)
            raise
        state.act_reads[unit] = (fut, buf, handle)

    def _act_fetch(self, op: ActFetchOp, state: _ExecState) -> None:  # thread: executor
        """Wait half of the split ActFetchOp: surface a failed save
        exactly once, top up the issue window, then make the checkpoint
        device-resident from whichever tier it landed in."""
        unit = op.unit
        rec = state.checkpoints[unit]
        if rec.fut is not None:
            t0 = time.perf_counter()
            try:
                rec.fut.result()
            except BaseException as e:
                if self._grad_writer is not None:
                    self._grad_writer.consume_error(e)  # delivered here
                raise
            finally:
                rec.fut = None
                self._ostats.act_save_wait_seconds += \
                    time.perf_counter() - t0
        self._act_issue_ahead(state)
        t0 = time.perf_counter()
        staged = state.act_stage.pop(unit, None)
        if staged is not None:
            hit = staged.done()
            try:
                arr = staged.result()
            finally:
                # satellite fix: free under finally — a failed stage must
                # not leak the host copy's tracker handle
                if rec.handle is not None:
                    self.tracker.free(rec.handle)
                    rec.handle = None
            self._ostats.act_stage_gets += 1
            self._ostats.act_stage_hits += int(hit)
            rec.value, rec.tier, rec.slot = arr, "ready", True
        elif unit in state.act_reads:
            read_fut, buf, handle = state.act_reads.pop(unit)
            try:
                read_fut.result()
                arr = jnp.asarray(buf, dtype=rec.dtype)
            finally:
                self.tracker.free(handle)
            rec.value, rec.tier = arr, "ready"
        elif rec.tier == "host":
            # inline H2D; free under try/finally — the pre-PR-9 restore
            # leaked the tracker handle when jnp.asarray raised
            try:
                arr = jnp.asarray(rec.value, dtype=rec.dtype)
            finally:
                self.tracker.free(rec.handle)
                rec.handle = None
            rec.value, rec.tier = arr, "ready"
        elif rec.tier == "ssd":
            # cold path (defensive): read + H2D inline
            buf = np.empty(rec.shape, rec.np_dtype)
            handle = self.tracker.alloc("activation_checkpoints", buf.nbytes,
                                        tag="act_fetch_staging")
            try:
                self.store.read(self._act_key(unit, buf.nbytes), buf)
                arr = jnp.asarray(buf, dtype=rec.dtype)
            finally:
                self.tracker.free(handle)
            rec.value, rec.tier = arr, "ready"
        self._ostats.act_fetch_wait_seconds += time.perf_counter() - t0

    def _consume_checkpoint(self, unit: str, state: _ExecState):  # thread: executor
        """block_bwd's checkpoint take: pop the record, return its device
        array, and give back its ACT device slot."""
        rec = state.checkpoints.pop(unit)
        if rec.slot:
            self._device_slots.release_all([ACT_CLASS])
            state.act_slots_out -= 1
            rec.slot = False
        if rec.tier in ("device", "ready"):
            return rec.value
        # validated at plan build (block_bwd only consumes saved/ready);
        # defensive
        raise RuntimeError(f"checkpoint for {unit!r} is {rec.tier!r}, not "
                           f"device-resident")

    def _discard_checkpoint(self, rec: _ActCkpt,  # thread: executor
                            state: _ExecState) -> None:
        """Abort-path release of one checkpoint record: wait out an
        in-flight save (the writer thread may still be mutating the
        record), return its device slot, free its host handle."""
        if rec.fut is not None:
            with contextlib.suppress(BaseException):
                rec.fut.result()
            rec.fut = None
        if rec.slot:
            self._device_slots.release_all([ACT_CLASS])
            state.act_slots_out -= 1
            rec.slot = False
        if rec.handle is not None:
            self.tracker.free(rec.handle)
            rec.handle = None

    # -- expert-page streaming (paged MoE) -----------------------------------
    #
    # Lifecycle (mirrors the weight stream's split issue/wait halves):
    #
    #   route   block_route (or a cached-decode route variant) computes the
    #           expert assignment; the executor reads the indices back and
    #           binds them for the WHOLE plan run (the backward reuses the
    #           forward's routing),
    #   issue   the FetchOp lookahead window prestages the PREDICTED
    #           routed set — this plan's own routing when already known
    #           (backward: exact), else the previous step's actual set,
    #           or every expert under expert_paging="all" — as zero-
    #           initialized (E, ...) host stacks H2D'd under a counted
    #           __expert__ device slot on the staging worker,
    #   wait    ExpertFetchOp resolves the ACTUAL routed set; a staged set
    #           that covers it is a hit, otherwise the stale stacks are
    #           dropped (slot returned) and the actual set is staged
    #           on demand,
    #   consume block_moe / block_moe_bwd read the stacks; ExpertReleaseOp
    #           returns the device slot and trims the page cache back
    #           under its residency budget.
    #
    # Deadlock-freedom of the staged path: the executor never submits an
    # expert stage while expert_slots_out >= the EXPERT_CLASS depth, so
    # the staging worker's acquire is always immediately satisfiable — it
    # can never wedge the shared FIFO worker behind an unreleasable slot.
    # Unrouted experts are never read by moe_ffn's combine (dropped slots
    # carry weight zero), so routed-only stacks are bit-identical to
    # all-resident ones by construction.

    def _expert_predict(self, unit: str, state: _ExecState):  # thread: executor
        """Predicted routed set for a window prestage: every expert under
        "all", this plan's own routing when the route already ran (the
        backward re-fetch — exact by construction), else the previous
        step's actual set (None before any step routed this unit)."""
        if self._expert_mode == "all":
            return np.arange(self._expert_meta[unit]["n_experts"])
        route = state.expert_route.get(unit)
        if route is not None:
            return np.unique(route.reshape(-1))
        return self._expert_prior.get(unit)

    def _build_expert_stacks(self, unit: str, ids) -> list:  # thread: executor, h2d-worker
        """Zero-initialized (E, ...) host stacks with the routed experts'
        pages memcpy'd in (pinned across each copy).  Rows of unrouted
        experts stay zero — never read by the combine — so the stacks are
        shape-identical to the all-resident ones and the jitted program
        is shared.  Byte accounting lands here: only routed pages cost
        SSD/memcpy traffic."""
        meta = self._expert_meta[unit]
        triples = meta["experts"]
        _unit, umeta = self._units[unit]
        cd = self.policy.adam.compute_np_dtype
        stacks = [np.zeros((meta["n_experts"],) + tuple(umeta[pname][0]), cd)
                  for pname in triples[0]]
        nbytes = 0
        for i in ids:
            for j, pname in enumerate(triples[int(i)]):
                view = self._expert_cache.ensure(unit, pname, pin=True)
                try:
                    stacks[j][int(i)] = view
                finally:
                    self._expert_cache.unpin(unit, pname)
                nbytes += view.nbytes
        self._ostats.bump("expert_fetch_bytes", nbytes)
        return stacks

    def _stage_experts(self, unit: str, ids: tuple) -> tuple:  # thread: h2d-worker
        """Staging-worker body: build the routed stacks, then H2D under a
        counted __expert__ device slot.  The stacks are built BEFORE the
        acquire so a failed expert SSD read surfaces at the fetch gate
        with no device slot held."""
        stacks = self._build_expert_stacks(unit, ids)
        self._device_slots.acquire(EXPERT_CLASS)
        try:
            return (frozenset(int(i) for i in ids),
                    tuple(self._h2d_copy(a) for a in stacks))
        except BaseException:
            self._device_slots.release_all([EXPERT_CLASS])
            raise

    def _submit_expert_stage(self, unit: str, ids,  # thread: executor
                             state: _ExecState) -> None:
        """Issue half: queue one unit's expert staging on the staging
        worker, behind the same unit's weight (and KV) stages."""
        fut = self._h2d.submit(
            functools.partial(self._stage_experts, unit, tuple(ids)))
        state.expert_stage.setdefault(unit, deque()).append(fut)
        state.stage_seq.append(("ex", unit))
        state.expert_slots_out += 1

    def _expert_fetch_now(self, unit: str, ids,  # thread: executor
                          state: _ExecState) -> tuple:
        """On-demand stage (miss, or no prestage was issued): through the
        staging worker when an EXPERT slot is guaranteed free — the
        executor is about to block on the result, so the worker's acquire
        must not be able to block — else built + copied inline without a
        slot (transient, accounted to the fetch wait)."""
        if self._h2d is not None and state.expert_slots_out < 2:
            state.expert_slots_out += 1
            fut = self._h2d.submit(
                functools.partial(self._stage_experts, unit, tuple(ids)))
            # NOT in stage_seq: consumed synchronously right here, even on
            # error (the worker released any slot it held before raising)
            try:
                _ids, stacks = fut.result()
            except BaseException:
                state.expert_slots_out -= 1
                raise
            return stacks, (EXPERT_CLASS,)
        stacks = tuple(self._h2d_copy(a)
                       for a in self._build_expert_stacks(unit, ids))
        return stacks, ()

    def _expert_fetch(self, op: ExpertFetchOp,  # thread: executor
                      state: _ExecState) -> None:
        """Wait half of the split ExpertFetchOp: resolve the actual routed
        set, take a covering staged prediction, restage on a miss."""
        unit = op.unit
        if self._expert_mode == "all":
            actual = np.arange(self._expert_meta[unit]["n_experts"])
        else:
            actual = np.unique(state.expert_route[unit].reshape(-1))
        self._expert_prior[unit] = actual
        t0 = time.perf_counter()
        stacks = tokens = None
        pending = state.expert_stage.get(unit)
        if pending:
            fut = pending.popleft()
            if not pending:
                del state.expert_stage[unit]
            self._ostats.expert_stage_gets += 1
            try:
                staged_ids, staged = fut.result()
            except BaseException:
                # a failed expert SSD read surfaces exactly once, here;
                # the worker held no slot (stacks build precedes acquire)
                state.expert_slots_out -= 1
                raise
            if set(int(i) for i in actual) <= staged_ids:
                self._ostats.expert_stage_hits += 1
                stacks, tokens = staged, (EXPERT_CLASS,)
            else:
                # stale prediction: drop the stacks, return the slot, and
                # stage the actual routed set on demand
                del staged
                self._device_slots.release_all([EXPERT_CLASS])
                state.expert_slots_out -= 1
        if stacks is None:
            stacks, tokens = self._expert_fetch_now(unit, actual, state)
        state.expert_live[unit] = tuple(stacks)
        state.expert_slots[unit] = tokens
        self._ostats.expert_fetch_wait_seconds += time.perf_counter() - t0

    def _expert_release(self, op: ExpertReleaseOp,  # thread: executor
                        state: _ExecState) -> None:
        """ExpertReleaseOp: drop the staged device stacks, return the
        __expert__ slot, and trim the page cache over its keep line (the
        host pages themselves stay cached for future steps)."""
        state.expert_live.pop(op.unit, None)
        tokens = state.expert_slots.pop(op.unit, ())
        if tokens:
            self._device_slots.release_all(tokens)
            state.expert_slots_out -= 1
        self._expert_cache.release_round()

    # -- the executor --------------------------------------------------------

    def execute(self, plan: StreamPlan, state: _ExecState) -> _ExecState:  # thread: executor
        """Walk the plan with lookahead-N prefetch; drain on any error."""
        if self._closed:
            raise RuntimeError("session is closed")
        fetch_order = plan.fetch_order
        fetch_pos = 0       # index of the FetchOp being executed
        next_prefetch = 0   # first fetch position not yet issued async
        # Units whose KV window this plan reads (decode_cached blocks):
        # only they get KV refill prefetch + staged-gather submissions —
        # prefill plans overwrite whole pages, so refilling ahead of a
        # write would be wasted I/O.
        kv_read_units = (frozenset(
            op.unit for op in plan.ops if isinstance(op, KVReadOp))
            if state.kv is not None else frozenset())
        expert_units = frozenset(
            op.unit for op in plan.ops if isinstance(op, ExpertFetchOp))
        state.act_order = [op.unit for op in plan.ops
                           if isinstance(op, ActFetchOp)]
        state.act_next = 0
        try:
            for op in plan.ops:
                if isinstance(op, FetchOp):
                    if state.act_order:
                        # checkpoint fetches ride the same window — issued
                        # BEFORE this dispatch's weight stages so they are
                        # not queued behind a weight stage that is parked
                        # on a device slot the backward has yet to release
                        self._act_issue_ahead(state)
                    limit = min(fetch_pos + self.lookahead, len(fetch_order))
                    while next_prefetch < limit:
                        unit = fetch_order[next_prefetch]
                        head = next_prefetch == fetch_pos
                        # Cross-step gate: the unit's previous-step Adam
                        # write-back must land before its weights are
                        # re-read from the store.  Ahead-of-need positions
                        # stall the window instead of blocking compute; the
                        # head position always goes through the wait, which
                        # is also where a failed Adam stage is delivered
                        # (a done-with-exception future is NOT ready —
                        # fetching would read stale weights).
                        if head:
                            self._optim_wait(unit)
                        elif not self._optim_ready(unit):
                            break
                        # A unit can appear twice inside the window (forward
                        # + backward re-fetch).  prefetch() is idempotent per
                        # key, so issuing the later position while the earlier
                        # ticket is still in flight would alias onto it and
                        # the later FetchOp would fall back to a synchronous
                        # read.  Stall the window here; the position is
                        # re-tried at the next FetchOp, after the earlier
                        # fetch has been consumed.
                        if not head and self._unit_in_flight(unit):
                            break
                        self._prefetch_unit(unit)
                        if self._h2d is not None:
                            self._submit_h2d(unit, state)
                        if unit in kv_read_units:
                            # ride the same window: block i+1's KV page
                            # refills + window gather/H2D overlap block
                            # i's compute (refill is a no-op for pages
                            # that are resident or never spilled)
                            state.kv.prefetch_window(unit, state.kv_time)
                            if self._h2d is not None and \
                                    unit not in state.kv_stage:
                                self._submit_kv_stage(unit, state)
                        if unit in expert_units and self._h2d is not None \
                                and state.expert_slots_out < 2:
                            # prestage the predicted routed set behind the
                            # unit's weight/KV stages; skipped when the
                            # prediction is unknown (first step) or the
                            # EXPERT slot budget is out — the ExpertFetchOp
                            # then stages on demand
                            pred = self._expert_predict(unit, state)
                            if pred is not None and len(pred):
                                self._submit_expert_stage(unit, pred, state)
                        next_prefetch += 1
                    t_fetch = time.perf_counter()
                    state.live[op.unit] = self._fetch_unit(op.unit, state)
                    self._ostats.fetch_seconds += \
                        time.perf_counter() - t_fetch
                    fetch_pos += 1
                elif isinstance(op, ComputeOp):
                    self._compute(op, state)
                elif isinstance(op, KVReadOp):
                    self._read_kv(op.unit, state)
                elif isinstance(op, KVWriteOp):
                    self._write_kv(op, state)
                elif isinstance(op, ActSaveOp):
                    self._exec_act_save(op, state)
                elif isinstance(op, ActFetchOp):
                    self._act_fetch(op, state)
                elif isinstance(op, ExpertFetchOp):
                    self._expert_fetch(op, state)
                elif isinstance(op, ExpertReleaseOp):
                    self._expert_release(op, state)
                elif isinstance(op, GradWriteOp):
                    self._dispatch_grad_write(op.unit, state)
                elif isinstance(op, OverflowCheckOp):
                    self._exec_overflow(op, state)
                elif isinstance(op, OptimStepOp):
                    self._exec_optim(op.unit, state)
                elif isinstance(op, ReleaseOp):
                    state.live.pop(op.unit, None)
                    tokens = state.live_slots.pop(op.unit, None)
                    if tokens:
                        self._device_slots.release_all(tokens)
                    kv_tokens = state.kv_slots.pop(op.unit, None)
                    if kv_tokens:
                        self._device_slots.release_all(kv_tokens)
                    if state.act_order:
                        # a block_bwd just gave an ACT slot back — top the
                        # issue window up ahead of the next weight stages
                        self._act_issue_ahead(state)
        except BaseException:
            self._abort_execute(state)
            raise
        return state

    def _abort_execute(self, state: _ExecState) -> None:
        """Error path: nothing may leak.  Device-slot tokens are returned
        (resident units first, so a staging worker blocked on a slot can
        finish), staged fetches waited out, the gradient writer drained
        (resolving in-flight activation saves), host-held checkpoints and
        staged act reads freed, and outstanding reads drained back to the
        pool.  (KV pool slots belong to the SpillableKVCache, whose owner
        — generate()'s finally — closes it.)"""
        for tokens in state.live_slots.values():
            self._device_slots.release_all(tokens)
        state.live_slots.clear()
        for tokens in state.kv_slots.values():
            self._device_slots.release_all(tokens)
        state.kv_slots.clear()
        for tokens in state.expert_slots.values():
            if tokens:
                self._device_slots.release_all(tokens)
        state.expert_slots.clear()
        state.expert_live.clear()
        state.live.clear()
        # Staged fetches/KV windows/act checkpoints must settle before the
        # swapper drain: a queued staging job that ran *after* the drain
        # would re-issue its reads and leak device slots.  All three kinds
        # interleave on ONE FIFO worker, so waits must follow stage_seq's
        # submission order — waiting a later weight future while an
        # earlier KV task still blocks on a kv device slot would deadlock.
        # (Act stages never block on their slot: the executor's
        # act_slots_out cap guarantees a free ACT slot per submission.)
        # Consumed submissions have empty deques / absent keys and are
        # skipped; each released token keeps the worker's next blocked
        # acquire satisfiable.
        for kind, unit in state.stage_seq:
            if kind == "w":
                pending = state.h2d.get(unit)
                if not pending:
                    continue
                fut = pending.popleft()
                try:
                    _params, tokens = fut.result()
                except BaseException:
                    continue      # the worker released its own claims
                self._device_slots.release_all(tokens)
            elif kind == "kv":
                fut = state.kv_stage.pop(unit, None)
                if fut is None:
                    continue
                try:
                    fut.result()
                except BaseException:
                    continue      # the worker released its own slot
                self._device_slots.release_all([KV_CLASS])
            elif kind == "ex":
                pending = state.expert_stage.get(unit)
                if not pending:
                    continue
                fut = pending.popleft()
                try:
                    fut.result()
                except BaseException:
                    continue      # the worker released its own slot
                self._device_slots.release_all([EXPERT_CLASS])
            else:   # "act"
                fut = state.act_stage.pop(unit, None)
                if fut is None:
                    continue
                try:
                    fut.result()
                except BaseException:
                    continue      # the worker released its own slot
                self._device_slots.release_all([ACT_CLASS])
        state.stage_seq.clear()
        state.h2d.clear()
        state.kv_live.clear()
        state.kv_append.clear()
        state.act_stage.clear()
        state.expert_stage.clear()
        state.expert_route.clear()
        state.expert_slots_out = 0
        if self._grad_writer is not None:
            # the original executor error propagates; the drain also
            # resolves in-flight activation saves, so the checkpoint
            # discard below sees settled records
            with contextlib.suppress(BaseException):
                self._grad_writer.drain()
        for rec in state.checkpoints.values():
            self._discard_checkpoint(rec, state)
        state.checkpoints.clear()
        for read_fut, _buf, handle in state.act_reads.values():
            with contextlib.suppress(BaseException):
                read_fut.result()   # the async pread targets the buffer
            self.tracker.free(handle)
        state.act_reads.clear()
        state.act_slots_out = 0
        self.swapper.drain()

    def _compute(self, op: ComputeOp, state: _ExecState) -> None:
        params = state.live[op.unit]
        if op.kind == "embed":
            state.h = self._jit_embed(params, state.tokens)
        elif op.kind == "block":
            if op.save_input:
                # bind the device array only — the D2H (and SSD write)
                # happen at the unit's ActSaveOp, off the executor thread
                # under full overlap; device-tier plans keep it as-is
                state.checkpoints[op.unit] = _ActCkpt(op.unit, state.h)
            state.h = self._jit_block(params, state.h)
        elif op.kind == "head_loss_grad":
            state.loss, head_grads, state.dh = self._jit_head(
                params, state.h, state.labels, state.scale)
            state.grads[op.unit] = head_grads
        elif op.kind == "head_loss":
            state.loss = self._jit_head_loss(params, state.h, state.labels)
        elif op.kind == "head_logits":
            state.logits = self._jit_head_logits(params, state.h)
        elif op.kind == "head_logits_last":
            state.logits = self._jit_head_last(params, state.h,
                                               state.last_pos)
        elif op.kind == "block_prefill":
            state.h, k, v = self._jit_block_prefill(params, state.h)
            state.kv_append[op.unit] = (k, v)
        elif op.kind == "block_step":
            k_dev, v_dev = state.kv_live.pop(op.unit)
            state.h, k, v = self._jit_block_step(
                params, state.h, k_dev, v_dev, state.cache_len,
                chunk=self.decode_spec.bucket)
            state.kv_append[op.unit] = (k, v)
        elif op.kind == "block_verify":
            k_dev, v_dev = state.kv_live.pop(op.unit)
            state.h, k, v = self._jit_block_verify(
                params, state.h, k_dev, v_dev, state.cache_len,
                chunk=self.decode_spec.bucket)
            state.kv_append[op.unit] = (k, v)
        elif op.kind == "block_route":
            if op.save_input:
                state.checkpoints[op.unit] = _ActCkpt(op.unit, state.h)
            state.h, idx = self._jit_block_route(params, state.h)
            # host readback: the fetch decision (unavoidable — the routed
            # set IS host control flow); the same indices are fed back to
            # block_moe so decision and compute agree by construction
            state.expert_route[op.unit] = np.asarray(idx)
        elif op.kind == "block_moe":
            gate, up, down = state.expert_live[op.unit]
            state.h = self._jit_block_moe(
                params, gate, up, down,
                jnp.asarray(state.expert_route[op.unit]), state.h)
        elif op.kind == "block_moe_bwd":
            x = self._consume_checkpoint(op.unit, state)
            gate, up, down = state.expert_live[op.unit]
            dparams, dgate, dup, ddown, state.dh = self._jit_block_moe_bwd(
                params, gate, up, down,
                jnp.asarray(state.expert_route[op.unit]), x, state.dh)
            # merge the stacked expert grads back under their per-expert
            # param keys (the flat-buffer layout); unrouted experts' rows
            # are exactly zero — their weights were never read
            grads = dict(dparams)
            for i, triple in enumerate(
                    self._expert_meta[op.unit]["experts"]):
                for g, pname in zip((dgate, dup, ddown), triple):
                    grads[pname] = g[i]
            state.grads[op.unit] = grads
        elif op.kind == "block_prefill_route":
            state.h, k, v, idx = self._jit_prefill_route(params, state.h)
            state.kv_append[op.unit] = (k, v)
            state.expert_route[op.unit] = np.asarray(idx)
        elif op.kind == "block_step_route":
            k_dev, v_dev = state.kv_live.pop(op.unit)
            state.h, k, v, idx = self._jit_step_route(
                params, state.h, k_dev, v_dev, state.cache_len,
                chunk=self.decode_spec.bucket)
            state.kv_append[op.unit] = (k, v)
            state.expert_route[op.unit] = np.asarray(idx)
        elif op.kind == "block_verify_route":
            k_dev, v_dev = state.kv_live.pop(op.unit)
            state.h, k, v, idx = self._jit_verify_route(
                params, state.h, k_dev, v_dev, state.cache_len,
                chunk=self.decode_spec.bucket)
            state.kv_append[op.unit] = (k, v)
            state.expert_route[op.unit] = np.asarray(idx)
        elif op.kind == "block_bwd":
            x = self._consume_checkpoint(op.unit, state)
            state.grads[op.unit], state.dh = self._jit_block_bwd(
                params, x, state.dh)
        elif op.kind == "block_recompute":
            # re-run this block's forward from its own (peeked, not
            # consumed — its block_bwd still needs it) checkpoint to
            # re-derive the successor's dropped checkpoint
            src = state.checkpoints[op.unit]
            if src.tier not in ("device", "ready"):  # validated; defensive
                raise RuntimeError(f"recompute source for {op.unit!r} is "
                                   f"{src.tier!r}, not device-resident")
            state.checkpoints[op.recompute_for] = _ActCkpt(
                op.recompute_for, self._jit_block(params, src.value))
        elif op.kind == "embed_bwd":
            state.grads[op.unit] = self._jit_embed_bwd(
                params, state.tokens, state.dh)
        else:  # validated at plan build; defensive
            raise ValueError(f"unknown compute kind {op.kind!r}")

    def _read_kv(self, unit_name: str, state: _ExecState) -> None:
        """Wait half of the split KVReadOp: take the staged device K/V
        window (overlap modes — the gather + H2D already ran on the
        staging worker under the previous block's compute) or gather and
        H2D inline (sync mode)."""
        fut = state.kv_stage.pop(unit_name, None)
        if fut is not None:
            hit = fut.done()
            t0 = time.perf_counter()
            k_dev, v_dev = fut.result()
            self._ostats.kv_stage_wait_seconds += time.perf_counter() - t0
            self._ostats.kv_stage_gets += 1
            self._ostats.kv_stage_hits += int(hit)
            state.kv_slots[unit_name] = (KV_CLASS,)
            state.kv_live[unit_name] = (k_dev, v_dev)
            return
        # Inline path (sync overlap): the gather already copies out of the
        # pool pages under pins, and _h2d_copy copies again into jax — the
        # page slots are free to be spilled (and their memory reused)
        # while the jitted step still reads the device buffer.
        k_host, v_host = state.kv.gather_window(unit_name, state.kv_time)
        state.kv_live[unit_name] = (self._h2d_copy(k_host),
                                    self._h2d_copy(v_host))

    def _write_kv(self, op: KVWriteOp, state: _ExecState) -> None:
        """Land this unit's new K/V in its host pages (D2H): one token
        appended to the tail page (``step``), a K-token draft window
        appended past each slot's length (``verify`` — lengths advance
        only when the host commits the accepted prefix), or the whole
        padded prompt window scattered across pages (``prefill``); the
        cache spills dirty pages onward if the residency budget is
        exceeded."""
        k, v = state.kv_append.pop(op.unit)
        if op.mode == "prefill":
            state.kv.write_prefill(op.unit, np.asarray(k), np.asarray(v),
                                   slots=state.kv_write_slots)
        elif op.mode == "verify":
            state.kv.append_window(op.unit, np.asarray(k), np.asarray(v))
        else:
            state.kv.append(op.unit, np.asarray(k), np.asarray(v))

    # -- gradient write-back -------------------------------------------------

    def _dispatch_grad_write(self, unit_name: str, state: _ExecState) -> None:
        """Run the D2H + flat-buffer scatter inline (sync/h2d modes) or
        enqueue it on the writer thread (full overlap), gated on the
        previous step's Adam having consumed the unit's flat region."""
        grads = state.grads.pop(unit_name)
        if self._grad_writer is None:
            self._write_grads(unit_name, grads)
            return
        with self._optim_lock:
            gate = self._optim_futures.get(unit_name)
        self._grad_writer.submit(
            functools.partial(self._write_grads, unit_name, grads, gate))

    def _write_grads(self, unit_name: str, grads: dict,  # thread: executor, writer
                     gate: Future | None = None) -> None:
        """Accumulate device grads into the fp32 host flat buffer, then
        screen the unit's region for Inf/NaN (fused policies only): the
        per-subgroup half of the overflow check runs right here — on the
        writer thread under full overlap — and the barrier only ORs the
        verdicts."""
        if self.flat is None:
            raise RuntimeError("serve-mode session has no gradient buffer")
        if gate is not None:
            gate.result()   # step k-1's Adam must consume flat[unit] first
        _unit, meta = self._units[unit_name]
        for key in meta:
            off, size, shape = self._flat_offsets[f"{unit_name}/{key}"]
            g = np.asarray(grads[key], dtype=np.float32).reshape(-1)  # D2H
            self.flat[off:off + size] = g
        if self._screen_regions:
            self._screen_unit_region(unit_name)

    def _screen_unit_region(self, unit_name: str) -> None:  # thread: executor, writer
        lo, hi = self._unit_flat_region[unit_name]
        t0 = time.perf_counter()
        verdict = bool(check_region(self.flat, lo, hi, fused=True,
                                    tracker=self.tracker))
        self._ostats.add_worker_seconds("overflow_screen_seconds",
                                        time.perf_counter() - t0)
        with self._screen_lock:
            self._region_verdicts[unit_name] = verdict

    # -- overflow + optimizer plan ops ---------------------------------------

    def _exec_overflow(self, op: OverflowCheckOp, state: _ExecState) -> None:
        """OverflowCheckOp: drain the writer (the barrier that makes every
        GradWriteOp visible), combine the step verdict, update the scaler.

        With ``op.regions`` under a fused policy the verdict is the OR of
        the per-region screens that already ran as each write-back landed
        (equal to the whole-buffer scan by the partition invariant —
        property-tested); the chained-baseline policy, whose 2.25x
        temporary peak is the thing being measured, keeps the legacy
        whole-buffer scan here."""
        if self.flat is None:
            raise RuntimeError("serve-mode session has no gradient buffer")
        if self._grad_writer is not None:
            t0 = time.perf_counter()
            self._grad_writer.drain()
            self._ostats.gradwrite_drain_seconds += time.perf_counter() - t0
        with self._screen_lock:
            verdicts, self._region_verdicts = self._region_verdicts, {}
        if op.regions and self._screen_regions:
            overflow = False
            for unit in op.regions:
                verdict = verdicts.get(unit)
                if verdict is None:
                    # a write-back that bypassed the screen (e.g. a test
                    # stubbing _write_grads): screen the region now so the
                    # verdict still covers every gradient
                    lo, hi = self._unit_flat_region[unit]
                    t0 = time.perf_counter()
                    verdict = bool(check_region(self.flat, lo, hi,
                                                fused=True,
                                                tracker=self.tracker))
                    self._ostats.add_worker_seconds(
                        "overflow_screen_seconds", time.perf_counter() - t0)
                overflow = overflow or verdict
        else:
            overflow = bool(flat_overflow_check(
                self.flat, fused=self.policy.fused_overflow,
                tracker=self.tracker))
        state.overflowed = overflow
        state.apply = self.scaler.update(state.overflowed)

    def _exec_optim(self, unit_name: str, state: _ExecState) -> None:
        """OptimStepOp: stream one unit's subgroups through the host Adam —
        inline, or pipelined across the optimizer + state-prefetch workers
        with a readiness future that resolves when the unit's **last
        write-back lands** (commit), gating the next step's fetch and
        grad-write for this unit.

        An overflow-skipped step (``state.apply`` false) returns before
        anything is enqueued, so no state is prefetched for it and nothing
        is left in flight to corrupt."""
        if self.optimizer is None:
            raise RuntimeError("serve-mode session has no optimizer")
        if state.apply is None:   # validated at plan build; defensive
            raise RuntimeError("OptimStepOp before OverflowCheckOp")
        if not state.apply:
            return                # skipped step: weights unchanged
        if not state.optim_begun:
            state.optim_begun = True
            if self._optim_worker is not None:
                # previous-step Adam tasks have all resolved (every unit's
                # grad write this step gated on its step k-1 future and the
                # barrier drained the writer), so the pipeline bookkeeping
                # can be reset from this thread before new work lands
                with self._adam_lock:
                    self._adam_work = []
                self._adam_issued = 0
                self._adam_inflight = deque()
                self._adam_poison = None
                self._optim_worker.submit(self.optimizer.begin_step)
            else:
                self.optimizer.begin_step()
        inv_scale = np.float32(1.0 / state.grad_scale)
        if self._optim_worker is not None:
            _unit, meta = self._units[unit_name]
            with self._adam_lock:
                lo = len(self._adam_work)
                self._adam_work.extend(
                    (unit_name, key) for key in meta)
                hi = len(self._adam_work)
            task = (self._optim_unit_paged
                    if unit_name in self._expert_meta
                    else self._optim_unit_pipelined)
            fut = self._optim_worker.submit(
                functools.partial(task, unit_name, lo, hi, inv_scale))
        else:
            self._optim_unit(unit_name, inv_scale)
            if unit_name in self._expert_meta:
                self._expert_cache.invalidate_unit(unit_name)
            fut = done_future()
        with self._optim_lock:
            self._optim_futures[unit_name] = fut

    def _optim_unit(self, unit_name: str, inv_scale: np.float32) -> None:  # thread: executor
        """Inline (sync/h2d) Adam stage: stream subgroups synchronously
        (the same three halves, composed back to back; no compute-weight
        return copy is materialized — the store holds it)."""
        _unit, meta = self._units[unit_name]
        for key in meta:
            skey = f"{unit_name}/{key}"
            staged = self.optimizer.issue_subgroup(skey)
            try:
                self.optimizer.compute_subgroup(
                    staged, self._unit_grad(skey, inv_scale))
            except BaseException:
                self.optimizer.discard_staged(staged)
                raise
            self.optimizer.commit_subgroup(staged)

    def _unit_grad(self, skey: str, inv_scale: np.float32) -> np.ndarray:  # thread: executor, optim-worker
        """Unscale one subgroup's gradient out of the flat buffer.

        Unscale with the scale the grads were produced under, not the
        post-update one — on a growth step they differ by 2x.  The multiply
        also copies out of the flat buffer, whose region is free for the
        next step's write-back once the unit's readiness future resolves.
        """
        off, size, shape = self._flat_offsets[skey]
        return self.flat[off:off + size].reshape(shape) * inv_scale

    # -- the pipelined Adam stage (full overlap) -----------------------------

    def _adam_ensure_issued(self, upto: int) -> None:  # thread: optim-worker
        """Submit state-prefetch issues for work indices < ``upto``.

        Runs on the optimizer worker only.  Deadlock-freedom of the
        arena's blocking acquire (inside the issue, on the state-prefetch
        worker): every held buffer is released by a write-completion
        callback on the store's async pool (commit), by the optimizer
        worker (error paths), or by the issue's own failure handler —
        never by a task queued *behind* the blocked issue on the
        state-prefetch worker itself.
        """
        with self._adam_lock:
            n = len(self._adam_work)
            pending = [self._adam_work[i]
                       for i in range(self._adam_issued, min(upto, n))]
        for unit_name, key in pending:
            fut = self._optim_prefetch.submit(functools.partial(
                self.optimizer.issue_subgroup, f"{unit_name}/{key}"))
            self._adam_inflight.append((self._adam_issued, fut))
            self._adam_issued += 1

    def _optim_unit_pipelined(self, unit_name: str, lo: int, hi: int,  # thread: optim-worker
                              inv_scale: np.float32) -> None:
        """Optimizer-worker task for one unit's subgroups [lo, hi):
        subgroup *k+1*'s (master, m, v) streams into the staging arena
        while *k*'s ``adam_update`` runs, and *k−1*'s write-backs drain
        asynchronously behind them on the optimizer's dedicated
        write-back executor.  Returns — resolving the unit's readiness
        future — only once every commit landed.

        On any failure the whole in-flight window is drained (commits
        waited, issued-but-uncomputed buffers released) so the staging
        arena is whole again, and the step is **poisoned**: the remaining
        unit tasks fail fast with the *same* exception instance before
        issuing anything, so a failure surfaces exactly once (the worker
        never re-latches a delivered instance) while every affected
        unit's readiness future still refuses to serve its un-updated
        weights."""
        if self._adam_poison is not None:
            raise self._adam_poison
        commits: list[Future] = []
        try:
            for g in range(lo, hi):
                self._adam_ensure_issued(g + 2)
                idx, staged_fut = self._adam_inflight.popleft()
                if idx != g:    # defensive; the reset/cleanup paths keep
                    raise RuntimeError(   # issue order == work order
                        f"adam pipeline out of order: staged {idx}, "
                        f"expected {g}")
                t0 = time.perf_counter()
                try:
                    staged = staged_fut.result()
                finally:
                    self._ostats.add_worker_seconds(
                        "optim_prefetch_wait_seconds",
                        time.perf_counter() - t0)
                try:
                    self.optimizer.compute_subgroup(
                        staged, self._unit_grad(staged.key, inv_scale))
                except BaseException:
                    self.optimizer.discard_staged(staged)
                    raise
                commits.append(
                    self.optimizer.commit_subgroup_async(staged))
            for commit in commits:
                commit.result()
        except BaseException as e:
            self._adam_poison = e
            self._adam_abort(commits, resume_at=hi)
            raise

    def _optim_unit_paged(self, unit_name: str, lo: int, hi: int,  # thread: optim-worker
                          inv_scale: np.float32) -> None:
        """Pipelined Adam for a paged-MoE unit, then expert-page
        invalidation (the commit rewrote the unit's SSD compute copies)
        BEFORE the readiness future resolves: the next step's fetch
        window — and therefore every expert prestage/ensure for this
        unit — gates on that future, so no page can be pinned while the
        invalidation drops it."""
        self._optim_unit_pipelined(unit_name, lo, hi, inv_scale)
        self._expert_cache.invalidate_unit(unit_name)

    def _adam_abort(self, commits: list[Future], *, resume_at: int) -> None:  # thread: optim-worker
        """Failure path of a unit task: wait out this unit's commits
        (each releases its own buffer), release every issued-but-never-
        computed staging buffer, and reset the issue counter to
        ``resume_at`` (the failed unit's end).  The reset is bookkeeping
        hygiene only: the step is poisoned, so the remaining unit tasks
        fail fast without ever issuing again — nothing is re-issued until
        the next step resets the pipeline wholesale."""
        for commit in commits:
            # the buffer was released in commit's finally
            with contextlib.suppress(BaseException):
                commit.result()
        while self._adam_inflight:
            _idx, staged_fut = self._adam_inflight.popleft()
            try:
                staged = staged_fut.result()
            except BaseException:
                continue        # a failed issue released its own buffer
            self.optimizer.discard_staged(staged)
        self._adam_issued = resume_at

    def _snapshot_optim_io(self) -> None:  # thread: optim-worker
        # queued after a step's last OptimStepOp: the completed-step ledger.
        # Locked: train_step reads it from the executor thread while this
        # worker task may still be landing the previous step's snapshot.
        io = self.optimizer.last_io_bytes
        with self._optim_lock:
            self._optim_io_completed = io

    # -- workloads -----------------------------------------------------------

    def train_step(self, tokens: np.ndarray, labels: np.ndarray) -> dict:  # thread: executor
        """One streamed training step; the whole pipeline — forward,
        backward, overflow screen, host Adam — executes as the train plan.

        Under ``overlap="full"`` the optimizer stage may still be streaming
        when this returns (it overlaps the *next* step's prefetch window);
        ``metrics["optimizer_io_bytes"]`` then reports the most recently
        *completed* step (0 until one completes) — call :meth:`synchronize`
        first for an exact up-to-date value.
        """
        if self.mode != "train":
            raise RuntimeError("train_step requires a train-mode session")
        wait0 = self.swapper.stats.wait_seconds
        hits0 = self.swapper.stats.prefetch_hits
        o0 = self._ostats.snapshot()
        grad_scale = self.scaler.scale   # the flat-buffer grads carry this
        state = self.execute(self.plan("train"),
                             _ExecState(tokens, labels, grad_scale))
        if self._optim_worker is not None and state.apply:
            self._optim_worker.submit(self._snapshot_optim_io)

        ssd_wait = self.swapper.stats.wait_seconds - wait0
        h2d_wait = self._ostats.h2d_wait_seconds - o0["h2d_wait_seconds"]
        if self._optim_worker is not None:
            with self._optim_lock:
                optim_io = self._optim_io_completed
        else:
            optim_io = self.optimizer.last_io_bytes
        self.metrics = {
            "loss": float(state.loss),
            "overflowed": state.overflowed,
            "applied": state.apply,
            "loss_scale": self.scaler.scale,
            "optimizer_io_bytes": optim_io,
            "peak_host_bytes": self.tracker.peak_allocated,
            # compute-thread stall obtaining device weights at FetchOps —
            # read wait + H2D inline (sync) or staged-future wait (overlap
            # modes).  Comparable across overlap levels by construction.
            "fetch_wait_s": self._ostats.fetch_seconds - o0["fetch_seconds"],
            "ssd_wait_s": ssd_wait,    # raw read waits, whichever thread
            "h2d_wait_s": h2d_wait,    # staged-future share of fetch_wait_s
            "prefetch_hits": (self.swapper.stats.prefetch_hits - hits0
                              + self._ostats.h2d_hits - o0["h2d_hits"]),
            "gradwrite_drain_s": (self._ostats.gradwrite_drain_seconds
                                  - o0["gradwrite_drain_seconds"]),
            "optim_gate_s": (self._ostats.optim_gate_seconds
                             - o0["optim_gate_seconds"]),
        }
        o1 = self._ostats.snapshot()
        # worker-side counters: the Adam stage of step k accrues these
        # while step k+1's window runs, so (like optim_gate_s) they are
        # attributed to the train_step whose wall-clock window they land in
        self.metrics["optim_prefetch_wait_s"] = (
            o1["optim_prefetch_wait_seconds"]
            - o0["optim_prefetch_wait_seconds"])
        self.metrics["overflow_screen_s"] = (
            o1["overflow_screen_seconds"] - o0["overflow_screen_seconds"])
        # activation streaming: executor stall on checkpoint saves (gating
        # on a still-pending writer-thread save, or the inline D2H + store
        # write) and on staged checkpoint fetches at block_bwd gates
        self.metrics["act_save_wait_s"] = (
            o1["act_save_wait_seconds"] - o0["act_save_wait_seconds"])
        self.metrics["act_fetch_wait_s"] = (
            o1["act_fetch_wait_seconds"] - o0["act_fetch_wait_seconds"])
        self.metrics["act_write_failures"] = (
            o1["act_write_failures"] - o0["act_write_failures"])
        # expert paging: executor stall at ExpertFetchOp gates (staged-
        # stack waits, miss restages, and on-demand fetches)
        self.metrics["expert_fetch_wait_s"] = (
            o1["expert_fetch_wait_seconds"]
            - o0["expert_fetch_wait_seconds"])
        return self.metrics

    def eval_loss(self, tokens: np.ndarray, labels: np.ndarray) -> float:
        state = self.execute(self.plan("eval"), _ExecState(tokens, labels))
        return float(state.loss)

    def decode_logits(self, tokens: np.ndarray) -> np.ndarray:
        """One weight-streamed decode step: logits for every position.

        Uncached (full-prefix) path — O(T²) over a generation; kept as the
        ablation baseline and for models without cached-decode applies.
        """
        state = self.execute(self.plan("decode"), _ExecState(tokens))
        return np.asarray(state.logits)

    # -- cached decode (spill-able KV) ---------------------------------------

    def open_kv_cache(self) -> SpillableKVCache:
        """A fresh paged spill-able KV cache drawing from this session's
        pool.

        One at a time: the census reserves exactly the spec's page-slot
        budget, so a second open cache would deadlock on slot
        backpressure.  Close it (``finally:``) to return the slots.
        """
        if self.decode_spec is None:
            raise RuntimeError(
                "session was built without decode=DecodeSpec(...); cached "
                "decode needs its KV page slots sized into the pool census")
        if self._kv_cache is not None and not self._kv_cache.closed:
            raise RuntimeError("a KV cache is already open on this session; "
                               "close it first (its pool slots are shared)")
        self._kv_cache = SpillableKVCache(
            list(self._kv_units), self._kv_page_shape,
            self.decode_spec.max_seq,
            self.policy.adam.compute_np_dtype, self.pool, self.store,
            resident_limit=self._kv_resident,
            slots=self.decode_spec.batch)
        return self._kv_cache

    def _decode_state(self, kv: SpillableKVCache) -> DecodeSpec:
        if self.decode_spec is None:
            raise RuntimeError("session has no decode spec")
        if kv.closed:
            raise RuntimeError("KV cache is closed")
        return self.decode_spec

    def prefill(self, kv: SpillableKVCache, tokens: np.ndarray, *,
                slots: list[int] | None = None,
                lengths: list[int] | None = None) -> np.ndarray:
        """Prompt pass: cache every block's K/V, return the last valid
        position's logits as (batch, vocab).  Prompts are right-padded to
        the spec's time bucket so each prompt-length bucket compiles once.

        Joint path (``slots=None``): every lane carries the same prompt
        length and the whole cache must be empty.

        Joiner path (continuous batching): ``slots`` names the batch slots
        being prefilled — freshly :meth:`~SpillableKVCache.join`\\ ed, empty
        — and ``lengths`` their true per-request prompt lengths (``tokens``
        rows are right-padded to the longest).  Only those slots' pages are
        written (prefill-scatter); the other lanes' rows are computed and
        discarded, so mid-flight requests are untouched and the jitted
        shapes stay fixed.  Callers should group joiners by prompt
        *bucket*: a joiner then runs the exact trace a solo prefill of that
        request would, which is what makes continuously-batched greedy
        output bit-identical to decoding each request alone.
        """
        spec = self._decode_state(kv)
        tokens = np.asarray(tokens)
        if tokens.ndim != 2 or tokens.shape[0] != spec.batch:
            raise ValueError(f"prompts must be (batch={spec.batch}, time), "
                             f"got {tokens.shape}")
        t0 = tokens.shape[1]
        if slots is None:
            if kv.length != 0:
                raise RuntimeError("prefill on a non-empty KV cache; open a "
                                   "fresh one per generation")
            last = jnp.asarray(t0 - 1, jnp.int32)
        else:
            if lengths is None or len(lengths) != len(slots):
                raise ValueError("joiner prefill needs lengths, one per slot")
            for s, n in zip(slots, lengths, strict=True):
                if s not in kv.active or kv.slot_length(s) != 0:
                    raise RuntimeError(
                        f"slot {s} is not a freshly joined empty slot")
                if not 1 <= n <= t0:
                    raise ValueError(f"prompt length {n} outside [1, {t0}]")
            # per-row last valid position; non-joiner rows read position 0
            # (their logits rows are discarded by the caller)
            pos = np.zeros(spec.batch, np.int32)
            for s, n in zip(slots, lengths, strict=True):
                pos[s] = n - 1
            last = jnp.asarray(pos)
        s_bucket = spec.bucket_len(t0)
        padded = np.zeros((spec.batch, s_bucket), np.int32)
        padded[:, :t0] = tokens
        state = _ExecState(padded)
        state.kv = kv
        state.kv_write_slots = slots
        state.last_pos = last
        state = self.execute(self.plan("prefill"), state)
        if slots is None:
            kv.set_length(t0)
        else:
            for s, n in zip(slots, lengths, strict=True):
                kv.set_slot_length(s, n)
        return np.asarray(state.logits)[:, 0]

    def decode_step(self, kv: SpillableKVCache,
                    tokens: np.ndarray) -> np.ndarray:
        """One cached decode step: append ``tokens`` (batch, 1) to the
        cache, return next-token logits as (batch, vocab).  Per-token cost
        is O(bucket) — independent of how many tokens were emitted — and
        every jitted stage retraces only on a bucket crossing.
        """
        spec = self._decode_state(kv)
        tokens = np.asarray(tokens)
        if tokens.shape != (spec.batch, 1):
            raise ValueError(f"step tokens must be (batch={spec.batch}, 1), "
                             f"got {tokens.shape}")
        if kv.length < 1:
            raise RuntimeError("decode_step before prefill")
        if kv.length + 1 > spec.max_seq:
            raise ValueError(f"KV cache full at max_seq={spec.max_seq}")
        state = _ExecState(tokens.astype(np.int32))
        state.kv = kv
        state.kv_time = spec.bucket_len(kv.length)
        state.cache_len = jnp.asarray(kv.length, jnp.int32)
        state = self.execute(self.plan("decode_cached"), state)
        kv.advance(1)
        return np.asarray(state.logits)[:, 0]

    def decode_step_slots(self, kv: SpillableKVCache,
                          tokens: np.ndarray) -> np.ndarray:
        """One cached decode step over per-slot lengths (continuous
        batching): every **active** slot's lane appends its token at that
        slot's own position; inactive lanes carry token 0 and are masked
        to self-attention only (``cache_len`` 0), their logits discarded.

        Same ``decode_cached`` plan and jitted stages as
        :meth:`decode_step` — ``cache_len`` is a traced (B,) vector, so
        join/retire churn costs no retrace; the device extent is the time
        bucket covering the *longest* active slot.  Masked-extent
        invariance of the attention step (tested) keeps each lane's output
        bit-identical to a solo decode of that request.
        """
        spec = self._decode_state(kv)
        tokens = np.asarray(tokens)
        if tokens.shape != (spec.batch, 1):
            raise ValueError(f"step tokens must be (batch={spec.batch}, 1), "
                             f"got {tokens.shape}")
        active = sorted(kv.active)
        if not active:
            raise RuntimeError("decode_step_slots with no active slots")
        for s in active:
            if kv.slot_length(s) < 1:
                raise RuntimeError(f"decode step before slot {s}'s prefill")
            if kv.slot_length(s) + 1 > spec.max_seq:
                raise ValueError(f"slot {s} full at max_seq={spec.max_seq}")
        state = _ExecState(tokens.astype(np.int32))
        state.kv = kv
        state.kv_time = spec.bucket_len(
            max(kv.slot_length(s) for s in active))
        lens = np.zeros(spec.batch, np.int32)
        for s in active:
            lens[s] = kv.slot_length(s)
        state.cache_len = jnp.asarray(lens)
        state = self.execute(self.plan("decode_cached"), state)
        kv.advance(1)
        return np.asarray(state.logits)[:, 0]

    def verify_step(self, kv: SpillableKVCache,
                    tokens: np.ndarray) -> np.ndarray:
        """Speculative-decode verify: step a ``(batch, n)`` draft window in
        ONE streamed pass over the weights and return all ``n`` positions'
        next-token logits as ``(batch, n, vocab)``.  Position ``j``'s row
        is bitwise what :meth:`decode_step` would have produced after the
        first ``j`` draft tokens were appended — the host compares each
        draft token against the previous position's argmax, commits the
        accepted prefix and rolls the cache back over the rejected tail
        (:meth:`~SpillableKVCache.rollback`).  The window is padded to
        :func:`verify_bucket` so warm traces stay bounded; slot lengths do
        NOT advance here (rollback's length-set is the commit).
        """
        spec = self._decode_state(kv)
        tokens = np.asarray(tokens)
        if tokens.ndim != 2 or tokens.shape[0] != spec.batch:
            raise ValueError(f"verify window must be (batch={spec.batch}, "
                             f"n), got {tokens.shape}")
        n = tokens.shape[1]
        k_pad = verify_bucket(n)
        if kv.length < 1:
            raise RuntimeError("verify_step before prefill")
        if kv.length + k_pad > spec.max_seq:
            raise ValueError(
                f"KV cache full: length {kv.length} + padded window "
                f"{k_pad} exceeds max_seq={spec.max_seq}")
        padded = np.zeros((spec.batch, k_pad), np.int32)
        padded[:, :n] = tokens
        state = _ExecState(padded)
        state.kv = kv
        state.kv_time = spec.bucket_len(kv.length + k_pad)
        state.cache_len = jnp.asarray(kv.length, jnp.int32)
        state = self.execute(self.plan("decode_verify"), state)
        return np.asarray(state.logits)[:, :n]

    def verify_step_slots(self, kv: SpillableKVCache,
                          tokens: np.ndarray) -> np.ndarray:
        """:meth:`verify_step` over per-slot lengths (continuous
        batching): each **active** slot's lane steps its own draft window
        at that slot's position; inactive lanes carry token 0, masked to
        self-attention only, logits discarded.  Slots accept and roll
        back independently — one rejected lane costs the others nothing
        but the shared pass.  Extent is the time bucket covering the
        longest active slot plus the padded window."""
        spec = self._decode_state(kv)
        tokens = np.asarray(tokens)
        if tokens.ndim != 2 or tokens.shape[0] != spec.batch:
            raise ValueError(f"verify window must be (batch={spec.batch}, "
                             f"n), got {tokens.shape}")
        n = tokens.shape[1]
        k_pad = verify_bucket(n)
        active = sorted(kv.active)
        if not active:
            raise RuntimeError("verify_step_slots with no active slots")
        for s in active:
            if kv.slot_length(s) < 1:
                raise RuntimeError(f"verify step before slot {s}'s prefill")
            if kv.slot_length(s) + k_pad > spec.max_seq:
                raise ValueError(
                    f"KV cache full: slot {s} length {kv.slot_length(s)} + "
                    f"padded window {k_pad} exceeds max_seq={spec.max_seq}")
        padded = np.zeros((spec.batch, k_pad), np.int32)
        padded[:, :n] = tokens
        state = _ExecState(padded)
        state.kv = kv
        state.kv_time = spec.bucket_len(
            max(kv.slot_length(s) for s in active) + k_pad)
        lens = np.zeros(spec.batch, np.int32)
        for s in active:
            lens[s] = kv.slot_length(s)
        state.cache_len = jnp.asarray(lens)
        state = self.execute(self.plan("decode_verify"), state)
        return np.asarray(state.logits)[:, :n]

    def expert_cache_stats(self) -> dict:
        """Expert page cache spill/refill counters (see
        :class:`~repro.core.paged.PageStats`); empty when expert paging
        is off."""
        return ({} if self._expert_cache is None
                else self._expert_cache.stats.snapshot())

    def overlap_snapshot(self) -> dict:
        """Point-in-time copy of the overlap-pipeline stall counters
        (:class:`~repro.core.overlap.OverlapStats`), including the staged-
        KV numbers serving cares about: ``kv_stage_gets`` / ``_hits`` (was
        the window already on device when the KVReadOp asked?) and
        ``kv_stage_wait_seconds`` (executor stall when it was not).  See
        docs/METRICS.md for the full glossary."""
        return self._ostats.snapshot()

    def decode_compiles(self) -> int:
        """Total jit traces across the decode stages — the bench/test probe
        for "zero retraces after the first token per bucket".  Counts via
        :func:`jit_cache_size`, the repo's single guarded touch point for
        jax's private trace-count probe."""
        fns = (self._jit_embed, self._jit_head_logits, self._jit_head_last,
               self._jit_block_prefill, self._jit_block_step,
               self._jit_block_verify, self._jit_prefill_route,
               self._jit_step_route, self._jit_verify_route,
               self._jit_block_moe)
        return sum(jit_cache_size(f) for f in fns if f is not None)

    # -- weights access ------------------------------------------------------

    def master_param(self, unit_name: str, key: str) -> np.ndarray:
        if self.mode != "train":
            raise RuntimeError("serve-mode sessions hold no master weights")
        self.synchronize()    # an in-flight Adam stage may still be writing
        _unit, meta = self._units[unit_name]
        shape, _ = meta[key]
        sd = self.policy.adam.state_np_dtype
        return self.store.read_new(f"{unit_name}/{key}.master", sd, shape)
