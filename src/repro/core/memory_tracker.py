"""Byte-exact host ("system") memory accounting.

MemAscend's claims are about *peak system memory*: the paper instruments the
host DRAM consumed by the offloading runtime (pinned staging buffers, the
gradient flat buffer, optimizer-state pools, overflow-check temporaries) and
shows that >55% of the peak is allocator/policy waste rather than payload.

This module is the measurement backbone for the whole repo.  Every allocator,
pool, and engine routes its allocations through a :class:`MemoryTracker`,
which records, per *component* (a free-form label such as
``"param_buffer_pool"`` or ``"overflow_tmp"``):

* live bytes *requested* (payload) and live bytes *allocated* (payload +
  policy overhead such as power-of-two rounding),
* global and per-component peaks,
* an event timeline for post-hoc analysis (benchmarks replay it to produce
  the paper's figures).

The tracker is deliberately dumb and deterministic: it never talks to the
OS.  That lets the benchmarks run the *policies* at paper scale (tens of GiB
of bookkeeping, zero actual buffers) while small-scale integration tests back
real numpy buffers with the same accounting.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass


@dataclass(frozen=True)
class AllocEvent:
    """One allocation/free event in the timeline."""

    op: str                 # "alloc" | "free"
    component: str          # logical owner, e.g. "param_buffer_pool"
    requested: int          # payload bytes the caller asked for
    allocated: int          # bytes actually reserved (>= requested)
    live_allocated: int     # total live allocated bytes after this event
    tag: str = ""           # optional sub-label (tensor name, ...)


@dataclass
class ComponentStats:
    live_requested: int = 0
    live_allocated: int = 0
    peak_requested: int = 0
    peak_allocated: int = 0
    n_allocs: int = 0
    n_frees: int = 0

    def snapshot(self) -> dict:
        return {
            "live_requested": self.live_requested,
            "live_allocated": self.live_allocated,
            "peak_requested": self.peak_requested,
            "peak_allocated": self.peak_allocated,
            "n_allocs": self.n_allocs,
            "n_frees": self.n_frees,
        }


class MemoryTracker:
    """Tracks live/peak host-memory bytes per component.

    Thread-safe: the Direct NVMe engine and the prefetch swapper allocate from
    worker threads.
    """

    def __init__(self, *, keep_timeline: bool = False) -> None:
        self._lock = threading.Lock()
        self._components: dict[str, ComponentStats] = {}  # guarded-by: _lock
        self._live_requested = 0   # guarded-by: _lock
        self._live_allocated = 0   # guarded-by: _lock
        self._peak_requested = 0   # guarded-by: _lock
        self._peak_allocated = 0   # guarded-by: _lock
        self._keep_timeline = keep_timeline
        self.timeline: list[AllocEvent] = []  # guarded-by: _lock
        # Monotonic id for handles so double-free is detectable.
        self._next_handle = 1      # guarded-by: _lock
        self._live_handles: dict[int, tuple[str, int, int]] = {}  # guarded-by: _lock

    # ------------------------------------------------------------------ API

    def alloc(self, component: str, requested: int, allocated: int | None = None,
              *, tag: str = "") -> int:  # thread: any
        """Record an allocation; returns an opaque handle for :meth:`free`."""
        if requested < 0:
            raise ValueError(f"negative allocation: {requested}")
        allocated = requested if allocated is None else allocated
        if allocated < requested:
            raise ValueError(
                f"allocated ({allocated}) < requested ({requested}) for {component}")
        with self._lock:
            stats = self._components.setdefault(component, ComponentStats())
            stats.live_requested += requested
            stats.live_allocated += allocated
            stats.n_allocs += 1
            stats.peak_requested = max(stats.peak_requested, stats.live_requested)
            stats.peak_allocated = max(stats.peak_allocated, stats.live_allocated)
            self._live_requested += requested
            self._live_allocated += allocated
            self._peak_requested = max(self._peak_requested, self._live_requested)
            self._peak_allocated = max(self._peak_allocated, self._live_allocated)
            handle = self._next_handle
            self._next_handle += 1
            self._live_handles[handle] = (component, requested, allocated)
            if self._keep_timeline:
                self.timeline.append(AllocEvent(
                    "alloc", component, requested, allocated,
                    self._live_allocated, tag))
            return handle

    def free(self, handle: int) -> None:  # thread: any
        with self._lock:
            try:
                component, requested, allocated = self._live_handles.pop(handle)
            except KeyError:
                raise ValueError(f"double free or unknown handle: {handle}") from None
            stats = self._components[component]
            stats.live_requested -= requested
            stats.live_allocated -= allocated
            stats.n_frees += 1
            self._live_requested -= requested
            self._live_allocated -= allocated
            if self._keep_timeline:
                self.timeline.append(AllocEvent(
                    "free", component, requested, allocated, self._live_allocated))

    # ------------------------------------------------------------- queries

    # The query properties lock: worker threads (store aio pools, the
    # H2D stager, the Adam stage) allocate concurrently with a benchmark
    # thread sampling peaks, and an unlocked read could pair one side of
    # an in-progress alloc's requested/allocated update.

    @property
    def live_requested(self) -> int:  # thread: any
        with self._lock:
            return self._live_requested

    @property
    def live_allocated(self) -> int:  # thread: any
        with self._lock:
            return self._live_allocated

    @property
    def peak_requested(self) -> int:  # thread: any
        with self._lock:
            return self._peak_requested

    @property
    def peak_allocated(self) -> int:  # thread: any
        with self._lock:
            return self._peak_allocated

    @property
    def peak_waste(self) -> int:  # thread: any
        """Policy overhead at peak: allocated − requested (both at peak)."""
        with self._lock:
            return self._peak_allocated - self._peak_requested

    def component(self, name: str) -> ComponentStats:  # thread: any
        with self._lock:
            return self._components.setdefault(name, ComponentStats())

    def breakdown(self) -> dict[str, dict]:  # thread: any
        """Per-component snapshot (for the paper's Fig. 8-style breakdowns)."""
        with self._lock:
            return {k: v.snapshot() for k, v in self._components.items()}

    def assert_quiescent(self) -> None:  # thread: any
        """Raise if anything is still live (leak detector for tests)."""
        with self._lock:
            handles = list(self._live_handles.values())
        if handles:
            live: dict[str, int] = {}
            for comp, req, _ in handles:
                live[comp] = live.get(comp, 0) + req
            raise AssertionError(f"leaked allocations: {live}")


# A process-global default tracker; components accept an explicit tracker so
# tests/benchmarks can isolate, but the training engine uses this by default.
GLOBAL_TRACKER = MemoryTracker()


def fmt_bytes(n: float) -> str:
    """Human-readable bytes, GiB-biased like the paper's tables."""
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:.2f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024.0
    raise AssertionError
