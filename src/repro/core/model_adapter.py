"""Adapter: ModelConfig -> OffloadableModel for the SSD-offloaded trainer.

The offload engine streams *unstacked* per-block parameter dicts (that is
the whole point — one block in device memory at a time), while the jit/pjit
path uses period-stacked scans.  This adapter instantiates the same layer
definitions (:mod:`repro.models.transformer`) in unstacked form and wires
the pure apply functions the engine jits per block.

Restriction: the engine jits ONE block function, so the config must be
layer-homogeneous (period == 1) — true for the dense and MoE families.
Hybrid/xLSTM fine-tuning under offload would need one jitted apply per
position-in-period; straightforward, not needed for the paper's workloads
(the paper fine-tunes dense Llama/Qwen + one MoE).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import (gqa_attention, gqa_prefill, gqa_step,
                                    gqa_verify, mla_attention)
from repro.models.moe import moe_ffn
from repro.models.transformer import (apply_ffn, apply_layer, ffn_kind,
                                      init_layer_params, layer_period,
                                      mixer_kind)
from repro.models.layers import (cross_entropy, dense, embed_lookup,
                                 lm_logits, rms_norm, trunc_normal,
                                 fan_in_init)
from .offload_engine import OffloadableModel, OffloadUnit


def make_offloadable_lm(cfg: ModelConfig, key, compute_dtype=jnp.bfloat16,
                        *, expert_paging: str = "off") -> OffloadableModel:
    if layer_period(cfg) != 1:
        raise ValueError(
            f"{cfg.name}: offloaded trainer requires layer-homogeneous "
            f"configs (period==1); got period={layer_period(cfg)}")
    kinds = (mixer_kind(cfg, 0), ffn_kind(cfg, 0))
    paged_moe = expert_paging != "off"
    if paged_moe and kinds[1] != "moe":
        raise ValueError(
            f"{cfg.name}: expert_paging={expert_paging!r} needs a MoE "
            f"config (ffn kind is {kinds[1]!r})")

    keys = jax.random.split(key, cfg.n_layers + 2)
    units = [OffloadUnit("embed", "standalone", {
        "embed": np.asarray(trunc_normal(keys[0], (cfg.vocab, cfg.d_model),
                                         0.02))})]
    expert_meta: dict | None = {} if paged_moe else None
    for i in range(cfg.n_layers):
        lp = init_layer_params(keys[1 + i], cfg, i)
        params = {k: np.asarray(v) for k, v in lp.items()}
        name = f"block_{i:03d}"
        if paged_moe:
            # split the stacked (E, ...) expert tensors into per-expert
            # params: each becomes an individually fetchable page in the
            # expert page cache instead of a per-fetch streamed tensor
            e = cfg.moe
            gate = params.pop("moe.w_gate")
            up = params.pop("moe.w_up")
            down = params.pop("moe.w_down")
            triples = []
            for x in range(e.n_experts):
                names = (f"moe.expert{x}.w_gate", f"moe.expert{x}.w_up",
                         f"moe.expert{x}.w_down")
                for pname, stack in zip(names, (gate, up, down)):
                    params[pname] = np.ascontiguousarray(stack[x])
                triples.append(names)
            expert_meta[name] = {"n_experts": e.n_experts,
                                 "experts": triples}
        units.append(OffloadUnit(name, "block", params))
    head_params = {"final_norm": np.zeros((cfg.d_model,), np.float32)}
    # tied embeddings share the table; an untied head projects its own
    head_params["head"] = (
        units[0].params["embed"].T.copy() if cfg.tie_embeddings
        else np.asarray(fan_in_init(keys[-1], (cfg.d_model, cfg.vocab))))
    units.append(OffloadUnit("head", "standalone", head_params))

    def embed_apply(params, tokens):
        return embed_lookup(params["embed"].astype(compute_dtype), tokens,
                            scale=cfg.embed_scale)

    def block_apply(params, h):
        out, _aux = apply_layer(cfg, kinds, params, h)
        return out

    def head_logits(params, h):
        h = rms_norm(h, params["final_norm"].astype(compute_dtype),
                     cfg.rms_eps)
        return lm_logits(h, params["head"].astype(compute_dtype))

    def head_loss(params, h, labels):
        return cross_entropy(head_logits(params, h), labels)

    def class_of(param_key: str) -> str:
        return ModelConfig.class_of_param(param_key)

    # Expert-paged MoE applies: one block splits into a routing half (the
    # mixer + router top-k, whose indices the host reads back to decide
    # which expert pages to fetch) and an expert half (the routed FFN,
    # consuming staged (E, ...) stacks whose unrouted rows are zero and —
    # by moe_ffn's dispatch/combine structure — never read, so routed and
    # all-resident residency are bit-identical).  The backward recomputes
    # the whole block under vjp with the forward's pinned expert indices.
    block_route = block_moe = block_moe_bwd = None
    block_prefill_route = block_step_route = block_verify_route = None
    if paged_moe:
        def _mixer(params, hn):
            if kinds[0] == "attn":
                return gqa_attention(params, hn, cfg)
            if kinds[0] == "mla":
                return mla_attention(params, hn, cfg)
            raise ValueError(
                f"expert paging supports attn/mla mixers, got {kinds[0]!r}")

        def _route_idx(params, hmid):
            # the same logits moe_ffn recomputes; only the top-k indices
            # leave the device (the host's fetch decision)
            hn = rms_norm(hmid, params["norm_ffn"], cfg.rms_eps)
            b, s, d = hn.shape
            logits = dense(hn.reshape(b * s, d), params["moe.w_router"])
            probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            _w, idx = jax.lax.top_k(probs, cfg.moe.top_k)
            return idx

        def block_route(params, h):
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            hmid = h + _mixer(params, hn)
            return hmid, _route_idx(params, hmid)

        def block_moe(params, gate, up, down, idx, hmid):
            # apply_ffn's moe half with the expert stacks passed as
            # arguments (staged from the page cache) and the routing
            # pinned to the route stage's choice
            hn = rms_norm(hmid, params["norm_ffn"], cfg.rms_eps)
            full = dict(params)
            full["moe.w_gate"], full["moe.w_up"] = gate, up
            full["moe.w_down"] = down
            out, _aux = moe_ffn(full, hn, cfg, idx=idx)
            return hmid + out

        def block_moe_bwd(params, gate, up, down, idx, h, dh):
            # recompute the full block under vjp (gradient checkpointing),
            # with the forward's expert assignment pinned so the staged
            # stacks cover every expert the backward touches
            def f(p, g, u, dn, hh):
                hn = rms_norm(hh, p["norm_mixer"], cfg.rms_eps)
                hmid = hh + _mixer(p, hn)
                return block_moe(p, g, u, dn, idx, hmid)
            _out, vjp = jax.vjp(f, params, gate, up, down, h)
            dparams, dgate, dup, ddown, dh_in = vjp(dh)
            return dparams, dgate, dup, ddown, dh_in

    # Cached-decode applies (spill-able KV cache): attention mixers only —
    # recurrent-state mixers (mamba/xLSTM) carry different cache pytrees
    # and stay on the uncached full-prefix path for now.  The FFN half is
    # the SAME apply_ffn the train/uncached paths run, so cached decode
    # cannot drift numerically.
    block_prefill = block_step = block_verify = kv_shape = None
    if kinds[0] == "attn":
        def block_prefill(params, h):
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            mix, k, v = gqa_prefill(params, hn, cfg)
            h, _aux = apply_ffn(cfg, kinds[1], params, h + mix)
            return h, k, v

        def block_step(params, h, k_cache, v_cache, cache_len, *,
                       chunk=None):
            # ``chunk`` (static under jit) keeps the attention reductions
            # extent-invariant — see gqa_step; the session passes its
            # decode time-bucket size
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            mix, k_new, v_new = gqa_step(params, hn, cfg, k_cache, v_cache,
                                         cache_len, chunk=chunk)
            h, _aux = apply_ffn(cfg, kinds[1], params, h + mix)
            return h, k_new, v_new

        def block_verify(params, h, k_cache, v_cache, cache_len, *,
                         chunk=None):
            # spec-decode verification: a (B, K) window of draft tokens
            # stepped in one pass; gqa_verify replays the sequential
            # step's reduction structure so the logits match bitwise
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            mix, k_new, v_new = gqa_verify(params, hn, cfg, k_cache,
                                           v_cache, cache_len, chunk=chunk)
            h, _aux = apply_ffn(cfg, kinds[1], params, h + mix)
            return h, k_new, v_new

        def kv_shape(batch: int, time: int) -> tuple:
            return (2, batch, time, cfg.n_kv_heads, cfg.head_dim)

        if paged_moe:
            # cached-decode route variants: the same mixer halves as the
            # plain applies, stopping at hmid + expert indices so the
            # staged expert stacks feed the shared block_moe
            def block_prefill_route(params, h):
                hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
                mix, k, v = gqa_prefill(params, hn, cfg)
                hmid = h + mix
                return hmid, k, v, _route_idx(params, hmid)

            def block_step_route(params, h, k_cache, v_cache, cache_len, *,
                                 chunk=None):
                hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
                mix, k_new, v_new = gqa_step(params, hn, cfg, k_cache,
                                             v_cache, cache_len, chunk=chunk)
                hmid = h + mix
                return hmid, k_new, v_new, _route_idx(params, hmid)

            def block_verify_route(params, h, k_cache, v_cache, cache_len,
                                   *, chunk=None):
                hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
                mix, k_new, v_new = gqa_verify(params, hn, cfg, k_cache,
                                               v_cache, cache_len,
                                               chunk=chunk)
                hmid = h + mix
                return hmid, k_new, v_new, _route_idx(params, hmid)

    return OffloadableModel(units=units, embed_apply=embed_apply,
                            block_apply=block_apply, head_loss=head_loss,
                            class_of=class_of, head_logits=head_logits,
                            block_prefill=block_prefill,
                            block_step=block_step,
                            block_verify=block_verify, kv_shape=kv_shape,
                            block_route=block_route, block_moe=block_moe,
                            block_moe_bwd=block_moe_bwd,
                            block_prefill_route=block_prefill_route,
                            block_step_route=block_step_route,
                            block_verify_route=block_verify_route,
                            expert_meta=expert_meta)
