"""Adapter: ModelConfig -> OffloadableModel for the SSD-offloaded trainer.

The offload engine streams *unstacked* per-block parameter dicts (that is
the whole point — one block in device memory at a time), while the jit/pjit
path uses period-stacked scans.  This adapter instantiates the same layer
definitions (:mod:`repro.models.transformer`) in unstacked form and wires
the pure apply functions the engine jits per block.

Restriction: the engine jits ONE block function, so the config must be
layer-homogeneous (period == 1) — true for the dense and MoE families.
Hybrid/xLSTM fine-tuning under offload would need one jitted apply per
position-in-period; straightforward, not needed for the paper's workloads
(the paper fine-tunes dense Llama/Qwen + one MoE).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import gqa_prefill, gqa_step, gqa_verify
from repro.models.transformer import (apply_ffn, apply_layer, ffn_kind,
                                      init_layer_params, layer_period,
                                      mixer_kind)
from repro.models.layers import (cross_entropy, embed_lookup, lm_logits,
                                 rms_norm, trunc_normal, fan_in_init)
from .offload_engine import OffloadableModel, OffloadUnit


def make_offloadable_lm(cfg: ModelConfig, key,
                        compute_dtype=jnp.bfloat16) -> OffloadableModel:
    if layer_period(cfg) != 1:
        raise ValueError(
            f"{cfg.name}: offloaded trainer requires layer-homogeneous "
            f"configs (period==1); got period={layer_period(cfg)}")
    kinds = (mixer_kind(cfg, 0), ffn_kind(cfg, 0))

    keys = jax.random.split(key, cfg.n_layers + 2)
    units = [OffloadUnit("embed", "standalone", {
        "embed": np.asarray(trunc_normal(keys[0], (cfg.vocab, cfg.d_model),
                                         0.02))})]
    for i in range(cfg.n_layers):
        lp = init_layer_params(keys[1 + i], cfg, i)
        units.append(OffloadUnit(
            f"block_{i:03d}", "block",
            {k: np.asarray(v) for k, v in lp.items()}))
    head_params = {"final_norm": np.zeros((cfg.d_model,), np.float32)}
    # tied embeddings share the table; an untied head projects its own
    head_params["head"] = (
        units[0].params["embed"].T.copy() if cfg.tie_embeddings
        else np.asarray(fan_in_init(keys[-1], (cfg.d_model, cfg.vocab))))
    units.append(OffloadUnit("head", "standalone", head_params))

    def embed_apply(params, tokens):
        return embed_lookup(params["embed"].astype(compute_dtype), tokens,
                            scale=cfg.embed_scale)

    def block_apply(params, h):
        out, _aux = apply_layer(cfg, kinds, params, h)
        return out

    def head_logits(params, h):
        h = rms_norm(h, params["final_norm"].astype(compute_dtype),
                     cfg.rms_eps)
        return lm_logits(h, params["head"].astype(compute_dtype))

    def head_loss(params, h, labels):
        return cross_entropy(head_logits(params, h), labels)

    def class_of(param_key: str) -> str:
        return ModelConfig.class_of_param(param_key)

    # Cached-decode applies (spill-able KV cache): attention mixers only —
    # recurrent-state mixers (mamba/xLSTM) carry different cache pytrees
    # and stay on the uncached full-prefix path for now.  The FFN half is
    # the SAME apply_ffn the train/uncached paths run, so cached decode
    # cannot drift numerically.
    block_prefill = block_step = block_verify = kv_shape = None
    if kinds[0] == "attn":
        def block_prefill(params, h):
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            mix, k, v = gqa_prefill(params, hn, cfg)
            h, _aux = apply_ffn(cfg, kinds[1], params, h + mix)
            return h, k, v

        def block_step(params, h, k_cache, v_cache, cache_len, *,
                       chunk=None):
            # ``chunk`` (static under jit) keeps the attention reductions
            # extent-invariant — see gqa_step; the session passes its
            # decode time-bucket size
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            mix, k_new, v_new = gqa_step(params, hn, cfg, k_cache, v_cache,
                                         cache_len, chunk=chunk)
            h, _aux = apply_ffn(cfg, kinds[1], params, h + mix)
            return h, k_new, v_new

        def block_verify(params, h, k_cache, v_cache, cache_len, *,
                         chunk=None):
            # spec-decode verification: a (B, K) window of draft tokens
            # stepped in one pass; gqa_verify replays the sequential
            # step's reduction structure so the logits match bitwise
            hn = rms_norm(h, params["norm_mixer"], cfg.rms_eps)
            mix, k_new, v_new = gqa_verify(params, hn, cfg, k_cache,
                                           v_cache, cache_len, chunk=chunk)
            h, _aux = apply_ffn(cfg, kinds[1], params, h + mix)
            return h, k_new, v_new

        def kv_shape(batch: int, time: int) -> tuple:
            return (2, batch, time, cfg.n_kv_heads, cfg.head_dim)

    return OffloadableModel(units=units, embed_apply=embed_apply,
                            block_apply=block_apply, head_loss=head_loss,
                            class_of=class_of, head_logits=head_logits,
                            block_prefill=block_prefill,
                            block_step=block_step,
                            block_verify=block_verify, kv_shape=kv_shape)
