"""Pinned host-memory allocators: the paper's §III-B / §IV-C.

Two policies, identical interface:

* :class:`PowerOfTwoCachingAllocator` — faithful model of PyTorch's
  ``CachingHostAllocator``: every request is rounded up to the next power of
  two.  Good for highly dynamic workloads, catastrophic for the large,
  long-lived, exactly-sized buffers of SSD offloading (a 2.1 GiB request
  reserves 4 GiB *forever*).  This is the ZeRO-Infinity baseline.

* :class:`AlignmentFreeAllocator` — MemAscend's fix: requests are padded only
  to the DMA alignment (4096 B, the ``posix_memalign`` alignment the paper
  uses), so long-lived buffers occupy requested-plus-one-page at most.

Both can run in two modes:

* ``backing="accounting"`` (default): no real memory is touched — the
  allocator tracks bytes through a :class:`MemoryTracker`.  This is how
  benchmarks evaluate the policies at 8B–32B-model scale.
* ``backing="numpy"``: allocations are backed by real ``np.empty`` buffers
  (the container-scale equivalent of ``cudaHostAlloc``), used by the real
  offloaded-training engine and the integration tests.

The caching behaviour of the baseline matters too: freed blocks go to a
size-keyed free list and are reused, which is exactly why pow2 rounding was
chosen upstream — and why it backfires here (the paper's point: these buffers
are allocated once and never churn, so the cache buys nothing and the
rounding is pure waste).
"""

from __future__ import annotations

import numpy as np

from .memory_tracker import MemoryTracker, GLOBAL_TRACKER

DMA_ALIGNMENT = 4096  # posix_memalign alignment used by MemAscend


def next_power_of_two(n: int) -> int:
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def align_up(n: int, align: int) -> int:
    return ((n + align - 1) // align) * align


class PinnedBuffer:
    """A handle to one pinned allocation.

    ``array`` is a uint8 view of the payload region (numpy backing only).
    """

    __slots__ = ("size", "capacity", "array", "_handle", "_allocator", "freed",
                 "tag", "_full_array")

    def __init__(self, size: int, capacity: int, array: np.ndarray | None,
                 handle: int, allocator: "PinnedAllocatorBase", tag: str) -> None:
        self.size = size              # requested payload bytes
        self.capacity = capacity      # reserved bytes (>= size)
        self.array = array            # np.uint8[size] or None (accounting mode)
        self._handle = handle
        self._allocator = allocator
        self.freed = False
        self.tag = tag

    def view(self, dtype, shape) -> np.ndarray:
        """Typed view of the payload (numpy backing only)."""
        if self.array is None:
            raise RuntimeError("accounting-mode buffer has no storage")
        nbytes = int(np.dtype(dtype).itemsize * np.prod(shape, dtype=np.int64))
        if nbytes > self.size:
            raise ValueError(f"view of {nbytes} B exceeds buffer payload {self.size} B")
        return self.array[:nbytes].view(dtype).reshape(shape)

    def free(self) -> None:
        self._allocator.free(self)


class PinnedAllocatorBase:
    """Common bookkeeping for both policies."""

    #: subclasses: bytes actually reserved for a request
    def _rounded(self, nbytes: int) -> int:
        raise NotImplementedError

    def __init__(self, *, tracker: MemoryTracker | None = None,
                 component: str = "pinned", backing: str = "accounting",
                 caching: bool = True) -> None:
        if backing not in ("accounting", "numpy"):
            raise ValueError(f"unknown backing {backing!r}")
        self.tracker = tracker or GLOBAL_TRACKER
        self.component = component
        self.backing = backing
        self.caching = caching
        # free-list: reserved-size -> list of (capacity, array|None)
        self._free_list: dict[int, list[np.ndarray | None]] = {}
        self.total_requested = 0      # cumulative
        self.total_reserved = 0       # cumulative

    def alloc(self, nbytes: int, *, tag: str = "") -> PinnedBuffer:
        if nbytes <= 0:
            raise ValueError(f"allocation must be positive, got {nbytes}")
        capacity = self._rounded(nbytes)
        array = None
        cached = self._free_list.get(capacity)
        if self.caching and cached:
            array = cached.pop()
            # cached block: tracker already released it on free(); re-account.
        if array is None and self.backing == "numpy":
            array = np.zeros(capacity, dtype=np.uint8)
        handle = self.tracker.alloc(self.component, nbytes, capacity, tag=tag)
        self.total_requested += nbytes
        self.total_reserved += capacity
        payload = array[:nbytes] if array is not None else None
        buf = PinnedBuffer(nbytes, capacity, payload, handle, self, tag)
        buf._full_array = array  # keep the capacity-sized base alive (or None)
        return buf

    def free(self, buf: PinnedBuffer) -> None:
        if buf.freed:
            raise ValueError(f"double free of pinned buffer {buf.tag!r}")
        buf.freed = True
        self.tracker.free(buf._handle)
        if self.caching:
            base = getattr(buf, "_full_array", None)
            self._free_list.setdefault(buf.capacity, []).append(base)
        buf.array = None

    # -- reporting ---------------------------------------------------------

    @property
    def live_waste(self) -> int:
        stats = self.tracker.component(self.component)
        return stats.live_allocated - stats.live_requested

    def waste_fraction(self) -> float:
        """Fraction of reserved bytes that is rounding overhead (cumulative)."""
        if self.total_reserved == 0:
            return 0.0
        return 1.0 - self.total_requested / self.total_reserved


class PowerOfTwoCachingAllocator(PinnedAllocatorBase):
    """Baseline: PyTorch CachingHostAllocator policy (round to next pow2)."""

    def _rounded(self, nbytes: int) -> int:
        return next_power_of_two(nbytes)


class AlignmentFreeAllocator(PinnedAllocatorBase):
    """MemAscend: exact-size allocation at DMA (4096 B) alignment.

    Models the custom C++ extension: ``posix_memalign(4096)`` +
    ``cudaHostRegister`` — capacity is the request padded to one page.
    Caching is disabled by default: these buffers are allocated once at
    initialization and live until training ends (paper §IV-C), so a free-list
    would only hide leaks.
    """

    def __init__(self, **kw) -> None:
        kw.setdefault("caching", False)
        super().__init__(**kw)

    def _rounded(self, nbytes: int) -> int:
        return align_up(nbytes, DMA_ALIGNMENT)
