"""SSD tensor stores: filesystem baseline vs the Direct NVMe engine (§IV-E).

Two engines with one interface (:class:`TensorStore`):

* :class:`FilesystemEngine` — the DeepNVMe/ZeRO-Infinity design: **one file
  per tensor** on a normal filesystem.  Every I/O pays pathname resolution,
  metadata (inode) updates, block allocation and (journaled) bookkeeping.
  We use real files, so those costs are real in this container too.

* :class:`DirectNVMeEngine` — MemAscend's design: the engine owns N raw
  block devices (here: N preallocated region files standing in for
  ``/dev/nvme*n1``), runs its **own location allocator** (a shared
  next-free-LBA counter per device), keeps a **tensor-location dictionary**
  {tensor key -> stripe extents}, and serves reads/writes by splitting each
  request into equal stripes across devices and issuing positional I/O
  (``os.pwrite``/``os.pread``) from a worker-thread pool — the
  libaio/io_uring analogue.  Striping subsumes software RAID-0, and no
  filesystem metadata is touched on the data path (the region file's blocks
  are allocated once, up front).

Both engines count bytes moved (the paper's Fig. 20 I/O-volume metric) and
wall-clock per op (Fig. 14 latency/bandwidth benchmark).
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor, Future
from dataclasses import dataclass, field

import numpy as np

LBA_ALIGN = 4096  # logical-block alignment for direct I/O


def _as_bytes(arr: np.ndarray) -> np.ndarray:
    """uint8 view of a contiguous array (memoryview chokes on bfloat16)."""
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8)


@dataclass
class IOStats:
    """I/O volume/latency ledger.  ``record`` is reached concurrently —
    the ``-aio`` pool runs several reads/writes at once and the direct
    engine's striped ops land from its worker pool — so the
    read-modify-write counters are lock-guarded."""

    bytes_written: int = 0    # guarded-by: _lock
    bytes_read: int = 0       # guarded-by: _lock
    n_writes: int = 0         # guarded-by: _lock
    n_reads: int = 0          # guarded-by: _lock
    write_seconds: float = 0.0  # guarded-by: _lock
    read_seconds: float = 0.0   # guarded-by: _lock
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def record(self, kind: str, nbytes: int, seconds: float) -> None:  # thread: any
        with self._lock:
            if kind == "w":
                self.bytes_written += nbytes
                self.n_writes += 1
                self.write_seconds += seconds
            else:
                self.bytes_read += nbytes
                self.n_reads += 1
                self.read_seconds += seconds

    def snapshot(self) -> dict:  # thread: any
        with self._lock:
            return {
                "bytes_written": self.bytes_written,
                "bytes_read": self.bytes_read,
                "n_writes": self.n_writes, "n_reads": self.n_reads,
                "write_seconds": self.write_seconds,
                "read_seconds": self.read_seconds,
            }


class TensorStore:
    """Common interface: named tensors on 'SSD'."""

    def __init__(self) -> None:
        self.stats = IOStats()
        # Per-instance, set here rather than as a class-attribute default:
        # a class attribute is shared by every engine until the first
        # lazy assignment shadows it, so one store's close() could tear
        # down (or miss) another's I/O threads.
        self._async_pool: ThreadPoolExecutor | None = None  # guarded-by: _async_pool_lock
        self._async_pool_lock = threading.Lock()

    # -- blocking API ---------------------------------------------------------

    def write(self, key: str, data: np.ndarray) -> None:
        raise NotImplementedError

    def read(self, key: str, out: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def read_new(self, key: str, dtype, shape) -> np.ndarray:
        out = np.empty(shape, dtype=dtype)
        return self.read(key, out)

    def contains(self, key: str) -> bool:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def keys(self):
        raise NotImplementedError

    def close(self) -> None:
        """Shut down the lazily-created async I/O executor (idempotent).

        Engines with more resources extend this — the base class owns the
        ``-aio`` thread pool so no engine can forget it and leak up to 4
        worker threads per session open/close cycle.
        """
        with self._async_pool_lock:
            pool, self._async_pool = self._async_pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # -- async API (the swapper overlaps I/O with compute) ---------------------

    def write_async(self, key: str, data: np.ndarray) -> Future:
        return self._pool().submit(self.write, key, data)

    def read_async(self, key: str, out: np.ndarray) -> Future:
        return self._pool().submit(self.read, key, out)

    def _pool(self) -> ThreadPoolExecutor:
        with self._async_pool_lock:
            if self._async_pool is None:
                self._async_pool = ThreadPoolExecutor(
                    max_workers=4,
                    thread_name_prefix=f"{type(self).__name__}-aio")
            return self._async_pool


# ---------------------------------------------------------------------------
# Baseline: one file per tensor on the filesystem
# ---------------------------------------------------------------------------

class FilesystemEngine(TensorStore):
    """ZeRO-Infinity-style per-tensor files (ext4 + O_DIRECT in the paper).

    ``fsync`` (default on) charges the durability cost the paper's O_DIRECT
    path pays on every offload; turning it off models a page-cache-absorbing
    configuration for comparison.
    """

    def __init__(self, root: str, *, fsync: bool = True) -> None:
        super().__init__()
        self.root = root
        self.fsync = fsync
        os.makedirs(root, exist_ok=True)
        # key -> dtype, shape, nbytes
        self._meta: dict[str, tuple[str, tuple, int]] = {}  # guarded-by: _lock
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        safe = key.replace("/", "__")
        return os.path.join(self.root, safe + ".bin")

    def write(self, key: str, data: np.ndarray) -> None:
        data = np.ascontiguousarray(data)
        t0 = time.perf_counter()
        # open -> allocate blocks -> write -> metadata update: the whole
        # filesystem path, per tensor, per iteration.
        with open(self._path(key), "wb") as f:
            f.write(_as_bytes(data))
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        self.stats.record("w", data.nbytes, time.perf_counter() - t0)
        with self._lock:
            self._meta[key] = (str(data.dtype), data.shape, data.nbytes)

    def read(self, key: str, out: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        path = self._path(key)
        with open(path, "rb") as f:
            n = f.readinto(_as_bytes(out))
        if n != out.nbytes:
            raise IOError(f"short read for {key}: {n} != {out.nbytes}")
        self.stats.record("r", out.nbytes, time.perf_counter() - t0)
        return out

    def contains(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def delete(self, key: str) -> None:
        os.unlink(self._path(key))
        with self._lock:
            self._meta.pop(key, None)

    def keys(self):
        # Snapshot under the lock: concurrent write_async completions
        # mutate _meta while a checkpoint enumerates it, and dict
        # iteration raises on concurrent insert.
        with self._lock:
            return list(self._meta)


# ---------------------------------------------------------------------------
# MemAscend: Direct NVMe engine
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Extent:
    device: int
    offset: int
    length: int


class _LocationAllocator:
    """Shared next-free-offset counters, one per device (paper Fig. 7).

    The paper uses a shared-memory integer per device so multiple processes
    never hand out overlapping LBAs; within this process a lock plays that
    role.  Allocation is append-only (tensors are preallocated once and
    updated in place thereafter — training-state I/O never frees).
    """

    def __init__(self, n_devices: int, capacity: int) -> None:
        self._next = [0] * n_devices   # guarded-by: _lock
        self._capacity = capacity
        self._lock = threading.Lock()

    def alloc(self, device: int, nbytes: int) -> int:
        aligned = ((nbytes + LBA_ALIGN - 1) // LBA_ALIGN) * LBA_ALIGN
        with self._lock:
            off = self._next[device]
            if off + aligned > self._capacity:
                raise IOError(
                    f"device {device} full: need {aligned} B at {off}, "
                    f"capacity {self._capacity} B")
            self._next[device] = off + aligned
            return off


class DirectNVMeEngine(TensorStore):
    """Raw-LBA striped tensor store with a worker-thread I/O pool.

    Parameters
    ----------
    root: directory where the raw 'device' region files live.
    n_devices: stripe width (the paper stripes across SSDs instead of RAID-0).
    device_capacity: bytes preallocated per device region.
    n_workers: I/O threads (the paper's multi-threaded AIO submission).
    min_stripe: don't split requests below this size — small tensors go to a
        single device, avoiding per-stripe overhead.
    """

    def __init__(self, root: str, *, n_devices: int = 2,
                 device_capacity: int = 1 << 30, n_workers: int = 4,
                 min_stripe: int = 1 << 20) -> None:
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.n_devices = n_devices
        self.min_stripe = min_stripe
        self._fds: list[int] = []
        for d in range(n_devices):
            path = os.path.join(root, f"nvme{d}.raw")
            fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
            os.ftruncate(fd, device_capacity)  # preallocate the region once
            self._fds.append(fd)
        self._alloc = _LocationAllocator(n_devices, device_capacity)
        # tensor-location dictionary: key -> (dtype, shape, [extents])
        self._locations: dict[str, tuple[str, tuple, list[Extent]]] = {}  # guarded-by: _loc_lock
        self._loc_lock = threading.Lock()
        self._workers = ThreadPoolExecutor(
            max_workers=n_workers, thread_name_prefix="direct-nvme")
        self._rr = 0  # round-robin start device  # guarded-by: _rr_lock
        self._rr_lock = threading.Lock()

    # -- placement --------------------------------------------------------------

    def _plan_extents(self, nbytes: int) -> list[Extent]:
        """Split a request into per-device stripes and allocate LBAs."""
        if nbytes <= self.min_stripe or self.n_devices == 1:
            # Reached from concurrent write_async workers: the bump must be
            # atomic or lost updates skew the round-robin balance.
            with self._rr_lock:
                dev = self._rr % self.n_devices
                self._rr += 1
            return [Extent(dev, self._alloc.alloc(dev, nbytes), nbytes)]
        per = -(-nbytes // self.n_devices)
        per = ((per + LBA_ALIGN - 1) // LBA_ALIGN) * LBA_ALIGN
        extents, pos = [], 0
        for dev in range(self.n_devices):
            if pos >= nbytes:
                break
            length = min(per, nbytes - pos)
            extents.append(Extent(dev, self._alloc.alloc(dev, length), length))
            pos += length
        return extents

    def _extents_for(self, key: str, data: np.ndarray) -> list[Extent]:
        with self._loc_lock:
            entry = self._locations.get(key)
            if entry is not None:
                dtype, shape, extents = entry
                if sum(e.length for e in extents) != data.nbytes:
                    raise ValueError(
                        f"size change for {key}: {data.nbytes} vs recorded "
                        f"{sum(e.length for e in extents)}")
                return extents
        extents = self._plan_extents(data.nbytes)
        with self._loc_lock:
            self._locations[key] = (str(data.dtype), data.shape, extents)
        return extents

    # -- I/O ---------------------------------------------------------------------

    def _rw_striped(self, kind: str, extents: list[Extent], buf: memoryview) -> None:
        def one(extent: Extent, piece: memoryview) -> None:
            fd = self._fds[extent.device]
            if kind == "w":
                written = os.pwrite(fd, piece, extent.offset)
                if written != len(piece):
                    raise IOError(f"short pwrite: {written}/{len(piece)}")
            else:
                data = os.pread(fd, len(piece), extent.offset)
                if len(data) != len(piece):
                    raise IOError(
                        f"short pread on device {extent.device} at offset "
                        f"{extent.offset}: got {len(data)} of "
                        f"{len(piece)} B (region truncated or extent "
                        f"beyond preallocated capacity)")
                piece[:] = data

        pos = 0
        futures = []
        for e in extents:
            futures.append(self._workers.submit(one, e, buf[pos:pos + e.length]))
            pos += e.length
        for f in futures:
            f.result()

    def write(self, key: str, data: np.ndarray) -> None:
        data = np.ascontiguousarray(data)
        extents = self._extents_for(key, data)
        t0 = time.perf_counter()
        self._rw_striped("w", extents, memoryview(_as_bytes(data)))
        self.stats.record("w", data.nbytes, time.perf_counter() - t0)

    def read(self, key: str, out: np.ndarray) -> np.ndarray:
        with self._loc_lock:
            entry = self._locations.get(key)
        if entry is None:
            raise KeyError(f"tensor {key!r} not in location dictionary")
        _, _, extents = entry
        total = sum(e.length for e in extents)
        if total != out.nbytes:
            raise ValueError(f"read size mismatch for {key}: {out.nbytes} vs {total}")
        t0 = time.perf_counter()
        self._rw_striped("r", extents, memoryview(_as_bytes(out)))
        self.stats.record("r", out.nbytes, time.perf_counter() - t0)
        return out

    def contains(self, key: str) -> bool:
        with self._loc_lock:
            return key in self._locations

    def delete(self, key: str) -> None:
        # Raw-LBA space is append-allocated; delete only drops the mapping
        # (training-state tensors are never actually freed mid-run).
        with self._loc_lock:
            self._locations.pop(key)

    def keys(self):
        with self._loc_lock:
            return list(self._locations)

    def close(self) -> None:
        self._workers.shutdown(wait=True)
        super().close()           # the base-class -aio pool, once, here
        for fd in self._fds:
            os.close(fd)
        self._fds = []
