"""Dynamic loss scaling driven by the overflow check.

Standard fp16-style mixed-precision recipe (Micikevicius et al., 2018),
reproduced because the *overflow check it requires every iteration* is one
of MemAscend's four targets.  The scaler is deliberately tiny; the
interesting part (the check itself) lives in :mod:`repro.core.overflow` and
:mod:`repro.kernels.overflow_check`.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass
class DynamicLossScaler:
    scale: float = 2.0 ** 16
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    growth_interval: int = 2000
    min_scale: float = 1.0
    max_scale: float = 2.0 ** 24
    _good_steps: int = 0
    n_overflows: int = 0
    n_steps: int = 0

    def update(self, overflowed: bool) -> bool:
        """Record one step's overflow status.

        Returns True if the optimizer step should be APPLIED (no overflow),
        False if it must be skipped.
        """
        self.n_steps += 1
        if overflowed:
            self.n_overflows += 1
            self.scale = max(self.scale * self.backoff_factor, self.min_scale)
            self._good_steps = 0
            return False
        self._good_steps += 1
        if self._good_steps >= self.growth_interval:
            self.scale = min(self.scale * self.growth_factor, self.max_scale)
            self._good_steps = 0
        return True
