"""Host (CPU) Adam with SSD-resident state: the paper's optimizer substrate.

ZeRO-Infinity executes the optimizer on the CPU (DeepSpeedCPUAdam: fused
AVX512/AVX2 + OpenMP) because Adam's arithmetic intensity never justifies
shipping optimizer states over PCIe.  States live on NVMe and are streamed
through host subgroup buffers.

This module provides:

* :func:`adam_update` — the vectorized numpy update (our AVX analogue),
  with bias correction and decoupled weight decay, dtype-templated like the
  DeepSpeed C++ backend (fp32 or bf16 optimizer states).
* :class:`OffloadedAdam` — streams (master, m, v) subgroups from a
  :class:`~repro.core.nvme.TensorStore`, updates on host, writes back, and
  emits new half-precision compute weights.  Counts per-iteration I/O volume
  (paper Fig. 20) and supports the **bf16 half-precision optimizer** mode
  (paper §VI-B-3a): master/m/v stored and transferred in bf16, cutting I/O
  per parameter from 26 B to 14 B (−46%; with fp16 grads counted the paper
  reports −58%).

The streamed step is split into three halves so the session's Adam stage
can pipeline them across threads (SSDTrain, arXiv 2408.10013, hides the
state I/O the same way):

* :meth:`OffloadedAdam.issue_subgroup`  — acquire one buffer of the
  **double-buffered staging arena** and read (master, m, v) into its fp32
  views (one read stream, on the state-prefetch thread),
* :meth:`OffloadedAdam.compute_subgroup` — :func:`adam_update` in place on
  the staged fp32 state (optimizer thread),
* :meth:`OffloadedAdam.commit_subgroup_async` — truncate + write back
  master/m/v and the fresh compute-precision weights on a dedicated
  single-thread write-back executor (one write stream, draining behind
  the reads), bump the I/O ledger, release the staging buffer from the
  last write's completion callback.

:meth:`step_subgroup` remains the synchronous composition of the three.
The arena (2 buffers × (3 × max-subgroup fp32 + a truncation scratch)) is
tracker-charged up front; the former per-call ``astype`` transients are
gone — bf16/fp16 truncation now casts into the accounted scratch region,
so ``bench_peak_memory``'s Adam-stage numbers reflect real memory.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np
import ml_dtypes

BF16 = np.dtype(ml_dtypes.bfloat16)
F32 = np.dtype(np.float32)


@dataclass
class AdamConfig:
    lr: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"  (paper's bf16 mode)
    compute_dtype: str = "bfloat16"   # precision of weights used by fwd/bwd

    @property
    def state_np_dtype(self):
        return BF16 if self.state_dtype == "bfloat16" else np.dtype(np.float32)

    @property
    def compute_np_dtype(self):
        return {"bfloat16": BF16, "float16": np.dtype(np.float16),
                "float32": np.dtype(np.float32)}[self.compute_dtype]

    @property
    def state_bytes_per_param(self) -> int:
        return self.state_np_dtype.itemsize


def adam_update(master: np.ndarray, grad: np.ndarray, m: np.ndarray,
                v: np.ndarray, step: int, cfg: AdamConfig) -> None:
    """In-place Adam step on fp32 working copies.

    ``master``, ``m``, ``v`` are fp32 views; callers holding bf16 state
    upcast before and truncate after (exactly the paper's direct-truncation
    scheme).  ``grad`` is fp32 (already unscaled).
    """
    b1, b2 = cfg.beta1, cfg.beta2
    m *= b1
    m += (1.0 - b1) * grad
    v *= b2
    v += (1.0 - b2) * np.square(grad)
    bias1 = 1.0 - b1 ** step
    bias2 = 1.0 - b2 ** step
    denom = np.sqrt(v / bias2) + cfg.eps
    update = (m / bias1) / denom
    if cfg.weight_decay:
        update += cfg.weight_decay * master
    master -= cfg.lr * update


@dataclass
class SubgroupMeta:
    key: str            # base key; store keys are f"{key}.master" etc.
    shape: tuple
    size: int           # element count


class _StagingArena:
    """Double-buffered host staging for the pipelined Adam stage.

    Two buffers, each holding fp32 working copies of one subgroup's
    (master, m, v) plus a scratch region for half-precision truncation:
    the I/O thread reads subgroup *k+1* into one buffer while the
    optimizer thread updates subgroup *k* in the other, and the committed
    buffer is recycled once its write-back lands.

    :meth:`acquire` blocks until a buffer is free.  Deadlock-freedom:
    only the state-prefetch worker blocks here, and every held buffer is
    released from an independent thread — a commit's write-completion
    callback on the dedicated write-back executor, or the optimizer
    thread on error paths — never from a task queued behind the blocked
    acquire.  :meth:`close` wakes blocked waiters, which raise instead of
    hanging.
    """

    def __init__(self, max_elems: int, scratch_bytes: int, tracker,
                 component: str) -> None:
        self.max_elems = max_elems
        self.scratch_bytes = scratch_bytes
        self._tracker = tracker
        self._bufs = []
        for _ in range(2):
            self._bufs.append((
                np.empty(3 * max_elems, dtype=np.float32),
                np.empty(scratch_bytes, dtype=np.uint8),
            ))
        self._handle = tracker.alloc(
            component, 2 * (3 * max_elems * 4 + scratch_bytes),
            tag="adam_staging_arena")
        self._free = [0, 1]     # guarded-by: _cv
        self._cv = threading.Condition()
        self._closed = False    # guarded-by: _cv

    def acquire(self) -> int:
        with self._cv:
            while not self._free:
                if self._closed:
                    raise RuntimeError("staging arena is closed")
                self._cv.wait()
            if self._closed:
                raise RuntimeError("staging arena is closed")
            return self._free.pop()

    def release(self, index: int) -> None:
        with self._cv:
            if index in self._free:
                raise ValueError(f"double release of staging buffer {index}")
            self._free.append(index)
            self._cv.notify_all()

    def views(self, index: int, n: int):
        """(master, m, v) fp32 views of length ``n`` plus the raw scratch."""
        f32, scratch = self._bufs[index]
        me = self.max_elems
        return (f32[0:n], f32[me:me + n], f32[2 * me:2 * me + n], scratch)

    def idle(self) -> bool:
        with self._cv:
            return len(self._free) == 2

    def close(self) -> None:
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()   # a blocked acquire raises, never hangs
        self._tracker.free(self._handle)


@dataclass
class StagedSubgroup:
    """One subgroup's staged state between issue and commit."""

    key: str
    buf: int                # staging-arena buffer index
    master: np.ndarray      # fp32 views into the arena
    m: np.ndarray
    v: np.ndarray
    io_read: int            # bytes read at issue (ledger half)


class OffloadedAdam:
    """Adam whose full state lives on the tensor store, streamed per subgroup.

    One "subgroup" = one parameter tensor (the paper streams optimizer-state
    subgroups through a fixed host buffer; tensor granularity matches its
    description and keeps peak host usage to the staging arena: 2 buffers of
    max-tensor-size × 3 fp32 + truncation scratch).

    Thread contract: the split halves are designed for exactly two extra
    threads — :meth:`issue_subgroup` and :meth:`commit_subgroup` run on one
    I/O thread (the session's state-prefetch worker) and
    :meth:`compute_subgroup` on the optimizer worker, with
    :meth:`begin_step` sequenced before its subgroups on the optimizer
    worker.  One step is in flight at a time.  The I/O ledger
    (``last_io_bytes``) is lock-guarded so the training thread can read a
    coherent value mid-step.

    ``write_guard`` (optional, set by the session) is called with the base
    key before the refreshed compute weights are written — the stale-read
    guard asserting no prefetched read of those weights is still in flight.
    """

    MASTER, M, V, COMPUTE = ".master", ".m", ".v", ".compute"

    def __init__(self, store, cfg: AdamConfig, *, tracker=None,
                 component: str = "optimizer_stream") -> None:
        from .memory_tracker import GLOBAL_TRACKER
        self.store = store
        self.cfg = cfg
        self.tracker = tracker or GLOBAL_TRACKER
        self.component = component
        self.step_count = 0
        self.subgroups: dict[str, SubgroupMeta] = {}
        self.write_guard = None
        self._io_lock = threading.Lock()
        self._arena_lock = threading.Lock()
        self._arena: _StagingArena | None = None   # guarded-by: _arena_lock
        # Dedicated single-thread write-back executor.  Two deliberate
        # choices, both measured at bench scale: (a) NOT the store's
        # shared "-aio" pool — the next step's small, latency-critical
        # weight prefetches must never queue behind this stage's large
        # state transfers; (b) exactly ONE write stream next to the one
        # read stream (the state-prefetch worker) — the Adam stage keeps
        # at most two transfers in flight, overlapping its reads with its
        # write-backs without starving the concurrent forward window's
        # weight reads of disk bandwidth (wider Adam I/O made the whole
        # pipeline slower).
        self._io_pool: ThreadPoolExecutor | None = None  # guarded-by: _arena_lock
        self._closed = False     # guarded-by: _arena_lock
        # I/O volume of the most recent step
        self.last_io_bytes = 0   # guarded-by: _io_lock

    # -- registration ------------------------------------------------------------

    def register(self, key: str, init_value: np.ndarray) -> None:  # thread: executor
        """Seed master weights + zero moments on the store; emit compute copy."""
        sd = self.cfg.state_np_dtype
        meta = SubgroupMeta(key, init_value.shape, init_value.size)
        self.subgroups[key] = meta
        master = init_value.astype(np.float32)
        self.store.write(key + self.MASTER, master.astype(sd))
        zeros = np.zeros(meta.shape, dtype=sd)
        self.store.write(key + self.M, zeros)
        self.store.write(key + self.V, zeros)
        self.store.write(key + self.COMPUTE,
                         master.astype(self.cfg.compute_np_dtype))

    # -- staging arena -----------------------------------------------------------

    def _scratch_bytes_per_elem(self) -> int:
        # issue/commit fan the three state tensors (plus the compute
        # weights) out on the store's async pool, so each concurrently
        # in-flight half-precision tensor needs its own scratch region
        sd = self.cfg.state_np_dtype
        cd = self.cfg.compute_np_dtype
        return ((3 * sd.itemsize if sd != F32 else 0)
                + (cd.itemsize if cd != F32 else 0))

    def _ensure_arena(self) -> _StagingArena:
        with self._arena_lock:
            if self._closed:
                # a step after close() must fail loudly, not resurrect a
                # fresh arena/pool behind the freed tracker charge
                raise RuntimeError("optimizer is closed")
            if self._arena is None:
                if not self.subgroups:
                    raise RuntimeError("no subgroups registered")
                max_elems = max(s.size for s in self.subgroups.values())
                self._arena = _StagingArena(
                    max_elems, max_elems * self._scratch_bytes_per_elem(),
                    self.tracker, self.component)
            return self._arena

    def staging_idle(self) -> bool:  # thread: any
        """True when no staging buffer is checked out — the leak probe."""
        with self._arena_lock:
            arena = self._arena
        return arena is None or arena.idle()

    def _pool(self) -> ThreadPoolExecutor:
        with self._arena_lock:
            if self._closed:
                # a commit racing close() must fail loudly: recreating the
                # executor here would resurrect a write stream nobody joins
                # (close() already shut the old one down and returned)
                raise RuntimeError("optimizer is closed")
            if self._io_pool is None:
                self._io_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="offload-optim-io")
            return self._io_pool

    def close(self) -> None:  # thread: executor
        """Free the staging arena's tracker charge and stop the I/O pool
        (waiting out in-flight write-backs).  Idempotent; later streaming
        calls raise instead of resurrecting the arena."""
        with self._arena_lock:
            self._closed = True
            pool, self._io_pool = self._io_pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        with self._arena_lock:
            arena, self._arena = self._arena, None
        if arena is not None:
            arena.close()

    # -- the streamed step, split into issue / compute / commit ------------------

    def _state_scratch(self, scratch: np.ndarray, n: int):
        """Three disjoint state-precision regions of the scratch (one per
        concurrently in-flight tensor) — only meaningful when sd != fp32."""
        sd = self.cfg.state_np_dtype
        w = n * sd.itemsize
        return [scratch[i * w:(i + 1) * w].view(sd) for i in range(3)]

    def issue_subgroup(self, key: str) -> StagedSubgroup:  # thread: executor, optim-prefetch
        """Acquire a staging buffer and read (master, m, v) into its fp32
        views.  Runs on the state-prefetch thread — reads stay a single
        stream there, overlapping the write-back stream and the optimizer
        arithmetic without crowding the disk (see ``_io_pool``).  Blocks
        while both buffers are in use.  On a failed read the buffer is
        released before re-raising."""
        meta = self.subgroups[key]
        sd = self.cfg.state_np_dtype
        arena = self._ensure_arena()
        buf = arena.acquire()
        try:
            n = meta.size
            master, m, v, scratch = arena.views(buf, n)
            targets = [(self.MASTER, master), (self.M, m), (self.V, v)]
            if sd == F32:
                for skey, out in targets:
                    self.store.read(key + skey, out)
            else:
                # read at state precision into the scratch, upcast in place
                halves = self._state_scratch(scratch, n)
                for (skey, out), half in zip(targets, halves, strict=True):
                    self.store.read(key + skey, half)
                    out[:] = half
            return StagedSubgroup(key, buf, master, m, v,
                                  io_read=3 * n * sd.itemsize)
        except BaseException:
            arena.release(buf)
            raise

    def compute_subgroup(self, staged: StagedSubgroup,
                         grad_f32: np.ndarray) -> None:  # thread: executor, optim-worker
        """In-place :func:`adam_update` on the staged fp32 state.  Runs on
        the optimizer thread; ``grad_f32`` is already unscaled."""
        adam_update(staged.master, np.reshape(grad_f32, -1), staged.m,
                    staged.v, self.step_count, self.cfg)

    def commit_subgroup_async(self, staged: StagedSubgroup, *,
                              return_compute: bool = False
                              ) -> "Future":  # thread: executor, optim-worker
        """Submit the write-back batch — master/m/v (truncated in the
        accounted scratch when half-precision) plus the fresh compute
        weights — on the dedicated single-thread write-back executor
        (``_io_pool``; deliberately not the store's shared pool) and
        return a Future that resolves once **every** write landed, the
        I/O ledger was bumped, and the staging buffer was released (all
        from the last write's completion callback).  The buffer is
        released on failure too; the future carries the first write
        error.

        The caller (the pipelined Adam stage) keeps streaming the next
        subgroups while these writes drain — write-backs overlap both the
        state-prefetch reads and the arithmetic.  If preparing the batch
        fails (the write guard fires, a cast raises), the buffer is
        released here and the error propagates synchronously."""
        meta = self.subgroups[staged.key]
        sd = self.cfg.state_np_dtype
        cd = self.cfg.compute_np_dtype
        key, n = staged.key, meta.size
        arena = self._ensure_arena()
        try:
            if self.write_guard is not None:
                self.write_guard(key)
            _master, _m, _v, scratch = arena.views(staged.buf, n)
            sources = [(self.MASTER, staged.master), (self.M, staged.m),
                       (self.V, staged.v)]
            state_off = 0
            if sd != F32:
                halves = self._state_scratch(scratch, n)
                for (_skey, src), half in zip(list(sources), halves,
                                              strict=True):
                    half[:] = src       # truncate into the accounted scratch
                sources = [(skey, half) for (skey, _src), half
                           in zip(sources, halves, strict=True)]
                state_off = 3 * n * sd.itemsize
            if cd == F32:
                compute_src = staged.master
            else:
                compute_src = scratch[state_off:
                                      state_off + n * cd.itemsize].view(cd)
                compute_src[:] = staged.master
            result = (compute_src.reshape(meta.shape).copy()
                      if return_compute else None)
        except BaseException:
            arena.release(staged.buf)
            raise
        done: Future = Future()
        done.set_running_or_notify_cancel()
        io = staged.io_read + 3 * n * sd.itemsize + n * cd.itemsize
        pending = {"left": 4, "error": None}
        agg_lock = threading.Lock()

        def _one_landed(fut) -> None:
            err = fut.exception()
            with agg_lock:
                if err is not None and pending["error"] is None:
                    pending["error"] = err
                pending["left"] -= 1
                if pending["left"]:
                    return
                error = pending["error"]
            # last write settled: nothing references the buffer any more
            arena.release(staged.buf)
            if error is None:
                with self._io_lock:
                    self.last_io_bytes += io
                done.set_result(result)
            else:
                done.set_exception(error)

        batch = sources + [(self.COMPUTE, compute_src)]
        writes = []
        try:
            pool = self._pool()
            for skey, src in batch:
                writes.append(pool.submit(self.store.write, key + skey, src))
        except BaseException:
            # submit itself failed (e.g. executor shut down mid-teardown):
            # the buffer must still come back — via the already-submitted
            # writes' callbacks if any are in flight, directly otherwise
            if writes:
                with agg_lock:
                    pending["left"] = len(writes)
                for fut in writes:
                    fut.add_done_callback(_one_landed)
            else:
                arena.release(staged.buf)
            raise
        for fut in writes:
            fut.add_done_callback(_one_landed)
        return done

    def commit_subgroup(self, staged: StagedSubgroup, *,
                        return_compute: bool = False
                        ) -> np.ndarray | None:  # thread: executor, optim-worker
        """Blocking commit: the async batch, waited out."""
        return self.commit_subgroup_async(
            staged, return_compute=return_compute).result()

    def discard_staged(self, staged: StagedSubgroup) -> None:  # thread: any
        """Error-path release of an issued-but-never-committed buffer."""
        self._ensure_arena().release(staged.buf)

    def step_subgroup(self, key: str, grad_f32: np.ndarray) -> np.ndarray:  # thread: executor
        """Stream one subgroup synchronously: issue, compute, commit.

        Returns the refreshed compute-precision weights (also written to the
        store for the next iteration's parameter prefetch).
        """
        staged = self.issue_subgroup(key)
        try:
            self.compute_subgroup(staged, grad_f32)
        except BaseException:
            self.discard_staged(staged)
            raise
        return self.commit_subgroup(staged, return_compute=True)

    def begin_step(self) -> None:  # thread: executor, optim-worker
        self.step_count += 1
        with self._io_lock:
            self.last_io_bytes = 0

    # -- static accounting (paper Fig. 20, at any model scale) ---------------------

    @staticmethod
    def io_bytes_per_param(cfg: AdamConfig, *, include_grad_offload: bool = True) -> int:
        """Per-parameter optimizer-step I/O volume for a given precision mode.

        The paper's Fig. 20 counts everything the optimizer step moves over
        NVMe: (master, m, v) read+write at state precision, the refreshed
        compute-precision weights, and — when gradients spill to SSD — the
        gradient write+read.  ZeRO-Infinity's gradient flat buffer is fp32,
        so the bf16-optimizer mode shrinks the gradient traffic too (the
        paper transfers "parameters, gradients, and momentum in
        half-precision")."""
        s = cfg.state_bytes_per_param
        c = cfg.compute_np_dtype.itemsize
        io = 3 * s + 3 * s + c          # read m/v/master + write back + compute wts
        if include_grad_offload:
            io += 2 * s                  # grad spill w+r at state precision
        return io
