"""Host (CPU) Adam with SSD-resident state: the paper's optimizer substrate.

ZeRO-Infinity executes the optimizer on the CPU (DeepSpeedCPUAdam: fused
AVX512/AVX2 + OpenMP) because Adam's arithmetic intensity never justifies
shipping optimizer states over PCIe.  States live on NVMe and are streamed
through host subgroup buffers.

This module provides:

* :func:`adam_update` — the vectorized numpy update (our AVX analogue),
  with bias correction and decoupled weight decay, dtype-templated like the
  DeepSpeed C++ backend (fp32 or bf16 optimizer states).
* :class:`OffloadedAdam` — streams (master, m, v) subgroups from a
  :class:`~repro.core.nvme.TensorStore`, updates on host, writes back, and
  emits new half-precision compute weights.  Counts per-iteration I/O volume
  (paper Fig. 20) and supports the **bf16 half-precision optimizer** mode
  (paper §VI-B-3a): master/m/v stored and transferred in bf16, cutting I/O
  per parameter from 26 B to 14 B (−46%; with fp16 grads counted the paper
  reports −58%).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import ml_dtypes

BF16 = np.dtype(ml_dtypes.bfloat16)


@dataclass
class AdamConfig:
    lr: float = 1e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"  (paper's bf16 mode)
    compute_dtype: str = "bfloat16"   # precision of weights used by fwd/bwd

    @property
    def state_np_dtype(self):
        return BF16 if self.state_dtype == "bfloat16" else np.dtype(np.float32)

    @property
    def compute_np_dtype(self):
        return {"bfloat16": BF16, "float16": np.dtype(np.float16),
                "float32": np.dtype(np.float32)}[self.compute_dtype]

    @property
    def state_bytes_per_param(self) -> int:
        return self.state_np_dtype.itemsize


def adam_update(master: np.ndarray, grad: np.ndarray, m: np.ndarray,
                v: np.ndarray, step: int, cfg: AdamConfig) -> None:
    """In-place Adam step on fp32 working copies.

    ``master``, ``m``, ``v`` are fp32 views; callers holding bf16 state
    upcast before and truncate after (exactly the paper's direct-truncation
    scheme).  ``grad`` is fp32 (already unscaled).
    """
    b1, b2 = cfg.beta1, cfg.beta2
    m *= b1
    m += (1.0 - b1) * grad
    v *= b2
    v += (1.0 - b2) * np.square(grad)
    bias1 = 1.0 - b1 ** step
    bias2 = 1.0 - b2 ** step
    denom = np.sqrt(v / bias2) + cfg.eps
    update = (m / bias1) / denom
    if cfg.weight_decay:
        update += cfg.weight_decay * master
    master -= cfg.lr * update


@dataclass
class SubgroupMeta:
    key: str            # base key; store keys are f"{key}.master" etc.
    shape: tuple
    size: int           # element count


class OffloadedAdam:
    """Adam whose full state lives on the tensor store, streamed per subgroup.

    One "subgroup" = one parameter tensor (the paper streams optimizer-state
    subgroups through a fixed host buffer; tensor granularity matches its
    description and keeps peak host usage to max-tensor-size × 3).

    Thread contract: subgroups of one step may be streamed from a
    background pipeline thread (the session's optimizer worker) while the
    owner enqueues nothing else — one step in flight at a time, with
    :meth:`begin_step` sequenced before its subgroups on the same thread or
    queue.  The I/O ledger (``last_io_bytes``) is lock-guarded so the
    training thread can read a coherent value mid-step.
    """

    MASTER, M, V, COMPUTE = ".master", ".m", ".v", ".compute"

    def __init__(self, store, cfg: AdamConfig, *, tracker=None,
                 component: str = "optimizer_stream") -> None:
        from .memory_tracker import GLOBAL_TRACKER
        import threading
        self.store = store
        self.cfg = cfg
        self.tracker = tracker or GLOBAL_TRACKER
        self.component = component
        self.step_count = 0
        self.subgroups: dict[str, SubgroupMeta] = {}
        self._io_lock = threading.Lock()
        self.last_io_bytes = 0   # I/O volume of the most recent step

    # -- registration ------------------------------------------------------------

    def register(self, key: str, init_value: np.ndarray) -> None:
        """Seed master weights + zero moments on the store; emit compute copy."""
        sd = self.cfg.state_np_dtype
        meta = SubgroupMeta(key, init_value.shape, init_value.size)
        self.subgroups[key] = meta
        master = init_value.astype(np.float32)
        self.store.write(key + self.MASTER, master.astype(sd))
        zeros = np.zeros(meta.shape, dtype=sd)
        self.store.write(key + self.M, zeros)
        self.store.write(key + self.V, zeros)
        self.store.write(key + self.COMPUTE,
                         master.astype(self.cfg.compute_np_dtype))

    # -- the streamed step ---------------------------------------------------------

    def step_subgroup(self, key: str, grad_f32: np.ndarray) -> np.ndarray:
        """Stream one subgroup: read states, update, write back.

        Returns the refreshed compute-precision weights (also written to the
        store for the next iteration's parameter prefetch).
        """
        meta = self.subgroups[key]
        sd = self.cfg.state_np_dtype
        cd = self.cfg.compute_np_dtype
        state_bytes = meta.size * sd.itemsize

        # Host staging for (master, m, v): charged to the tracker.
        h = self.tracker.alloc(self.component, 3 * meta.size * 4,
                               tag=key)  # fp32 working copies
        try:
            master = self.store.read_new(key + self.MASTER, sd, meta.shape)
            m = self.store.read_new(key + self.M, sd, meta.shape)
            v = self.store.read_new(key + self.V, sd, meta.shape)
            io = 3 * state_bytes

            master32 = master.astype(np.float32)
            m32 = m.astype(np.float32)
            v32 = v.astype(np.float32)
            adam_update(master32, grad_f32.reshape(meta.shape), m32, v32,
                        self.step_count, self.cfg)

            self.store.write(key + self.MASTER, master32.astype(sd))
            self.store.write(key + self.M, m32.astype(sd))
            self.store.write(key + self.V, v32.astype(sd))
            compute = master32.astype(cd)
            self.store.write(key + self.COMPUTE, compute)
            io += 3 * state_bytes + meta.size * cd.itemsize
            with self._io_lock:
                self.last_io_bytes += io
            return compute
        finally:
            self.tracker.free(h)

    def begin_step(self) -> None:
        self.step_count += 1
        with self._io_lock:
            self.last_io_bytes = 0

    # -- static accounting (paper Fig. 20, at any model scale) ---------------------

    @staticmethod
    def io_bytes_per_param(cfg: AdamConfig, *, include_grad_offload: bool = True) -> int:
        """Per-parameter optimizer-step I/O volume for a given precision mode.

        The paper's Fig. 20 counts everything the optimizer step moves over
        NVMe: (master, m, v) read+write at state precision, the refreshed
        compute-precision weights, and — when gradients spill to SSD — the
        gradient write+read.  ZeRO-Infinity's gradient flat buffer is fp32,
        so the bf16-optimizer mode shrinks the gradient traffic too (the
        paper transfers "parameters, gradients, and momentum in
        half-precision")."""
        s = cfg.state_bytes_per_param
        c = cfg.compute_np_dtype.itemsize
        io = 3 * s + 3 * s + c          # read m/v/master + write back + compute wts
        if include_grad_offload:
            io += 2 * s                  # grad spill w+r at state precision
        return io
