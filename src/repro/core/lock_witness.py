"""Dynamic lock-order witness: records the lock-acquisition graph while
tests run and fails on a cycle (a potential deadlock), complementing the
static checkers in :mod:`tools.analyze`.

The offload pipeline holds several locks across five thread roles
(executor, H2D stager, gradient writer, optimizer worker, state-prefetch
worker) plus the store's aio pools.  The static lock-discipline checkers
prove each *field* is accessed under its lock; they cannot prove the
*order* locks nest in is globally consistent.  This witness closes that
gap dynamically: wrap ``threading.Lock``/``threading.Condition`` for the
duration of a test run (``pytest --lock-witness``), record every edge
``A → B`` ("B was acquired while A was held"), and fail the moment the
edge set develops a cycle — i.e. two code paths nest the same two locks
in opposite orders, which deadlocks under the right interleaving even if
this run got lucky.

Locks are keyed by *creation site* (``file:line`` of the constructor
call), so every ``SpillableKVCache._lock`` across all instances is one
node — an AB/BA inversion between two *instances* of the same pair of
classes is still an inversion.  Same-site edges (two instances created
on the same line, e.g. a lock per pool in a list comprehension) are
ignored: ordering within a homogeneous group needs an instance-level
protocol, not a site-level one, and flagging it would false-positive
every ``[Lock() for _ in ...]``.

Usage::

    from repro.core import lock_witness
    lock_witness.install()
    try:
        ...  # run the workload
        lock_witness.check()     # raises LockOrderError on a cycle
    finally:
        lock_witness.uninstall()

or via the pytest flag (see ``tests/conftest.py``), which installs for
the whole session and checks after every test.
"""

from __future__ import annotations

import threading
import traceback
from collections import defaultdict

__all__ = ["LockOrderError", "WitnessLock", "install", "uninstall",
           "check", "reset", "edges", "installed"]

_real_lock = threading.Lock
_real_condition = threading.Condition

# ---------------------------------------------------------------------------
# Global witness state.  The edge map is guarded by a REAL lock (created
# before install() swaps the factories) so the witness never recurses
# into itself.
# ---------------------------------------------------------------------------

_state_lock = _real_lock()
_edges: dict[str, dict[str, tuple]] = {}   # site -> {site -> witness stack}
_installed = False
_held = threading.local()                  # per-thread stack of held sites


class LockOrderError(AssertionError):
    """Two code paths nest the same locks in opposite orders."""


def _creation_site() -> str:
    """file:line of the frame that called Lock()/Condition(), skipping
    frames inside this module."""
    for frame in reversed(traceback.extract_stack()):
        if frame.filename != __file__:
            return f"{frame.filename}:{frame.lineno}"
    return "<unknown>"


def _held_stack() -> list:
    stack = getattr(_held, "stack", None)
    if stack is None:
        stack = _held.stack = []
    return stack


def _record_acquire(site: str) -> None:
    stack = _held_stack()
    if stack:
        top = stack[-1]
        if top != site:
            with _state_lock:
                inner = _edges.setdefault(top, {})
                if site not in inner:
                    # remember one witness path per edge for the report
                    inner[site] = tuple(traceback.format_stack()[-8:-2])
    stack.append(site)


def _record_release(site: str) -> None:
    stack = _held_stack()
    # release order need not be LIFO (explicit lock.release() patterns
    # like SpillableKVCache._spill drop the lock mid-scope): remove the
    # most recent matching entry
    for i in range(len(stack) - 1, -1, -1):
        if stack[i] == site:
            del stack[i]
            return


class WitnessLock:
    """A ``threading.Lock`` stand-in that reports acquisitions to the
    witness graph.  Plain object (not a subclass — ``threading.Lock`` is
    a factory function, not a type); exposes the full lock protocol, so
    ``threading.Condition`` accepts it as its underlying lock."""

    __slots__ = ("_lock", "_site")

    def __init__(self, site: str | None = None) -> None:
        self._lock = _real_lock()
        self._site = site or _creation_site()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            _record_acquire(self._site)
        return got

    def release(self) -> None:
        self._lock.release()
        _record_release(self._site)

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<WitnessLock site={self._site!r} {self._lock!r}>"


def _witness_condition(lock=None):
    """Condition factory: a Condition over a WitnessLock, so ``with cv:``
    edges are recorded too.  ``wait()`` works unchanged — Condition only
    needs acquire/release (and uses its own waiter queue), and the
    witness stack is per-thread, so the release inside wait() correctly
    pops this thread's entry."""
    if lock is None:
        lock = WitnessLock(_creation_site())
    return _real_condition(lock)


def install() -> None:
    """Swap ``threading.Lock``/``threading.Condition`` for witnessing
    versions.  Locks created *before* install are invisible — install
    early (conftest does it at session start, before any repro module
    instantiates)."""
    global _installed
    with _state_lock:
        if _installed:
            return
        _installed = True
    threading.Lock = WitnessLock
    threading.Condition = _witness_condition


def uninstall() -> None:
    global _installed
    with _state_lock:
        if not _installed:
            return
        _installed = False
    threading.Lock = _real_lock
    threading.Condition = _real_condition


def installed() -> bool:
    with _state_lock:
        return _installed


def reset() -> None:
    """Drop every recorded edge (NOT the currently-held stacks)."""
    with _state_lock:
        _edges.clear()


def edges() -> dict[str, set[str]]:
    """Snapshot of the acquisition graph: held-site -> {acquired-site}."""
    with _state_lock:
        return {a: set(bs) for a, bs in _edges.items()}


def check() -> None:
    """Raise :class:`LockOrderError` if the acquisition graph has a cycle.

    A cycle A → B → ... → A means some thread acquired B while holding A
    and some (other) run acquired A while holding B — the classic
    inversion that deadlocks when both paths run concurrently."""
    with _state_lock:
        graph = {a: list(bs) for a, bs in _edges.items()}
        witnesses = {(a, b): w for a, bs in _edges.items()
                     for b, w in bs.items()}
    # iterative DFS with colors; report the first cycle found
    WHITE, GRAY, BLACK = 0, 1, 2
    color: dict[str, int] = defaultdict(int)
    parent: dict[str, str] = {}
    for root in graph:
        if color[root] != WHITE:
            continue
        stack = [(root, iter(graph.get(root, ())))]
        color[root] = GRAY
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color[nxt] == GRAY:
                    # unwind the cycle nxt -> ... -> node -> nxt
                    cycle = [node]
                    while cycle[-1] != nxt:
                        cycle.append(parent[cycle[-1]])
                    cycle.reverse()
                    cycle.append(nxt)
                    pairs = list(zip(cycle, cycle[1:], strict=False))
                    lines = [f"lock-order cycle: "
                             f"{' -> '.join(s.rsplit('/', 1)[-1] for s in cycle)}"]
                    for a, b in pairs:
                        lines.append(f"\n  {b} acquired while holding {a}; "
                                     f"witness:")
                        lines.extend("    " + ln.rstrip() for ln in
                                     witnesses.get((a, b), ()))
                    raise LockOrderError("\n".join(lines))
                if color[nxt] == WHITE:
                    color[nxt] = GRAY
                    parent[nxt] = node
                    stack.append((nxt, iter(graph.get(nxt, ()))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                stack.pop()
