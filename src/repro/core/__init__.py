"""MemAscend core: the paper's contribution as composable JAX/host modules.

Public surface:

* memory accounting      — :mod:`repro.core.memory_tracker`
* pinned allocators      — :mod:`repro.core.pinned_alloc` (§III-B/§IV-C)
* parameter buffer pools — :mod:`repro.core.buffer_pool` (§III-A/§IV-B)
* overflow checking      — :mod:`repro.core.overflow` (§III-C/§IV-D)
* loss scaling           — :mod:`repro.core.loss_scale`
* SSD tensor stores      — :mod:`repro.core.nvme` (§III-D/§IV-E)
* host Adam              — :mod:`repro.core.optimizer`
* prefetch swapper       — :mod:`repro.core.swapper`
* overlap machinery      — :mod:`repro.core.overlap` (H2D/writer/optimizer
                           pipeline legs of Fig. 6)
* schedule IR            — :mod:`repro.core.stream_plan` (Fig. 5/6 as data)
* the offload session    — :mod:`repro.core.session` (lookahead executor)
* policies + trainer shim— :mod:`repro.core.offload_engine`
"""

from .memory_tracker import MemoryTracker, GLOBAL_TRACKER, fmt_bytes
from .pinned_alloc import (AlignmentFreeAllocator, PinnedAllocatorBase,
                           PowerOfTwoCachingAllocator, next_power_of_two,
                           align_up, DMA_ALIGNMENT)
from .buffer_pool import (AdaptiveBufferPool, FixedBufferPool, KV_CLASS,
                          PoolCensus, ShapeClass)
from .kv_cache import DecodeSpec, KVStats, SpillableKVCache
from .overflow import (baseline_overflow_check, fused_overflow_check,
                       baseline_overflow_check_jnp, fused_overflow_check_jnp)
from .loss_scale import DynamicLossScaler
from .nvme import DirectNVMeEngine, FilesystemEngine, TensorStore, IOStats
from .optimizer import AdamConfig, OffloadedAdam, adam_update
from .swapper import ParameterSwapper, SwapStats
from .overlap import DeviceSlots, OverlapStats, SerialWorker
from .stream_plan import (ActFetchOp, ActSaveOp, ComputeOp, FetchOp,
                          GradWriteOp, KVReadOp,
                          KVWriteOp, OptimStepOp, OverflowCheckOp, PlanError,
                          ReleaseOp, StreamPlan,
                          compile_decode, compile_decode_cached, compile_eval,
                          compile_prefill, compile_train, resolve_act_policy)
from .session import OffloadSession
from .offload_engine import (OffloadableModel, OffloadUnit, OffloadPolicy,
                             OffloadedTrainer, PolicyBuilder,
                             memascend_bf16_policy, memascend_policy,
                             policy_names, register_policy,
                             zero_infinity_policy)
from .checkpoint import (load_pytree, restore_trainer_step, save_pytree,
                         snapshot_trainer)

__all__ = [n for n in dir() if not n.startswith("_")]
