"""Parameter swapper: the SSD→host prefetch pipeline (paper Fig. 5/6).

The swapper sits between the tensor store (SSD) and the device: when the
training engine is about to need block *i*'s weights, the swapper has
already (a) checked a pool slot out of the parameter buffer pool, (b) issued
the SSD read into that slot from a worker thread, and keeps (c) a bounded
number of blocks "in flight" — the prefetch depth N that sizes the pool.

The engine calls :meth:`prefetch` ahead of use and :meth:`get` at use time;
``get`` blocks on the outstanding read, hands back a typed numpy view of the
pool slot, and the engine releases the slot once the tensor has been copied
to the device (H2D), returning capacity to the pool — exactly the lifecycle
in §IV-A.

For the full-overlap executor the blocking half moves off the compute
thread: :meth:`claim` is the *issue* half of a split ``get`` — it takes
ownership of the in-flight ticket without waiting — and the H2D worker
waits the ticket itself, reporting the blocked time back through
:meth:`record_get` so the stats stay one coherent ledger no matter which
thread paid the wait.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import numpy as np

from .buffer_pool import BufferPoolBase, PoolBuffer
from .nvme import TensorStore


@dataclass
class SwapStats:
    """Prefetch-pipeline effectiveness counters (paper Fig. 5/6 overlap).

    ``wait_seconds`` is the time :meth:`ParameterSwapper.get` spent blocked —
    pool-slot backpressure plus outstanding SSD reads.  With lookahead
    pipelining most reads complete under compute, so waits shrink,
    ``prefetch_hits`` approaches ``n_gets``, and ``sync_fallbacks`` stays 0.
    """

    n_prefetches: int = 0     # async reads actually issued
    n_gets: int = 0
    prefetch_hits: int = 0    # read had already completed when get() asked
    sync_fallbacks: int = 0   # get() found nothing in flight: synchronous read
    wait_seconds: float = 0.0

    def snapshot(self) -> dict:
        return {"n_prefetches": self.n_prefetches, "n_gets": self.n_gets,
                "prefetch_hits": self.prefetch_hits,
                "sync_fallbacks": self.sync_fallbacks,
                "wait_seconds": self.wait_seconds}


@dataclass
class FetchTicket:
    key: str
    buf: PoolBuffer
    future: Future
    dtype: object
    shape: tuple

    def wait(self) -> np.ndarray:
        self.future.result()
        return self.buf.view(self.dtype, self.shape)

    def release(self) -> None:
        self.buf.release()


class ParameterSwapper:
    """Bounded-depth asynchronous SSD→pool prefetcher."""

    def __init__(self, store: TensorStore, pool: BufferPoolBase,
                 *, class_of: dict[str, str] | None = None) -> None:
        self.store = store
        self.pool = pool
        self.class_of = class_of or {}
        self.stats = SwapStats()                    # guarded-by: _lock
        self._inflight: dict[str, FetchTicket] = {}  # guarded-by: _lock
        # keys whose SSD pread has not completed yet (count per key):
        # unlike _inflight — which claim() pops while the read may still
        # be copying — this follows the read future itself, so the
        # stale-read write guard covers the claimed-but-still-reading
        # window too
        self._reading: dict[str, int] = {}          # guarded-by: _lock
        self._lock = threading.Lock()

    def _read_done(self, key: str) -> None:  # thread: any
        # (store-worker completion callback, or the failed-issue unwind)
        with self._lock:
            n = self._reading.get(key, 0) - 1
            if n > 0:
                self._reading[key] = n
            else:
                self._reading.pop(key, None)

    def _shape_class(self, key: str, explicit: str | None) -> str:
        if explicit is not None:
            return explicit
        try:
            return self.class_of[key]
        except KeyError:
            raise KeyError(
                f"no shape class registered for {key!r}; pass class_name=") from None

    def prefetch(self, key: str, dtype, shape, *,
                 class_name: str | None = None
                 ) -> FetchTicket:  # thread: executor, h2d-worker
        """Queue an async read of ``key`` into a pool slot; idempotent.

        The h2d-worker role covers :meth:`claim`'s fallback issue on the
        staging thread; every structure touched here is lock-guarded, so
        the two roles may issue concurrently for different keys."""
        with self._lock:
            if key in self._inflight:
                return self._inflight[key]
        cls = self._shape_class(key, class_name)
        nbytes = int(np.dtype(dtype).itemsize * np.prod(shape, dtype=np.int64))
        buf = self.pool.acquire(cls, nbytes, tag=key)  # may block = backpressure
        try:
            out = buf.view(dtype, shape)
            with self._lock:
                self._reading[key] = self._reading.get(key, 0) + 1
            try:
                future = self.store.read_async(key, out)
            except BaseException:
                self._read_done(key)   # no read issued: undo the guard count
                raise
            future.add_done_callback(lambda _f: self._read_done(key))
        except BaseException:
            # Failed issue: nothing owns the slot yet — release it here or
            # it is checked out of the pool for the rest of the session.
            buf.release()
            raise
        ticket = FetchTicket(key, buf, future, dtype, shape)
        with self._lock:
            self._inflight[key] = ticket
            self.stats.n_prefetches += 1
        return ticket

    def in_flight(self, key: str) -> bool:  # thread: any
        """True if an issued read for ``key`` has not been consumed yet."""
        with self._lock:
            return key in self._inflight

    def assert_not_in_flight(self, key: str) -> None:  # thread: any
        """Stale-read guard for store writers (the Adam commit's
        compute-weight write path): a write to ``key`` while a prefetched
        read of it is still copying would race the in-flight ``pread``
        and could serve half-old bytes to the next fetch.  The session's
        per-unit readiness gates make this impossible by construction —
        this assertion locks the invariant down at the write site.  Both
        windows are covered: an unconsumed ticket (``_inflight``) and a
        claimed ticket whose pread has not completed (``_reading``, which
        follows the read future itself)."""
        with self._lock:
            outstanding = key in self._inflight or key in self._reading
        if outstanding:
            raise RuntimeError(
                f"write to {key!r} while a prefetched read of it is in "
                f"flight; the writer must wait for the fetch gate (per-unit "
                f"readiness) before refreshing weights on the store")

    def claim(self, key: str, dtype, shape, *,
              class_name: str | None = None
              ) -> tuple[FetchTicket, bool, bool]:  # thread: executor, h2d-worker
        """Issue half of a split :meth:`get`: take ownership of the
        in-flight ticket (issuing a fallback read if none) WITHOUT waiting.

        Returns ``(ticket, hit, fallback)``.  The caller owns the ticket
        from here on — it must ``wait()`` it (releasing the slot itself on
        a failed read, since drain() can no longer see the ticket) and
        report the blocked time via :meth:`record_get`.
        """
        with self._lock:
            ticket = self._inflight.pop(key, None)
        fallback = ticket is None
        hit = ticket is not None and ticket.future.done()
        if ticket is None:
            ticket = self.prefetch(key, dtype, shape, class_name=class_name)
            with self._lock:
                self._inflight.pop(key, None)
        return ticket, hit, fallback

    def record_get(self, *, hit: bool, fallback: bool,
                   wait_seconds: float) -> None:  # thread: any
        """Account one completed (claim, wait) pair — from any thread."""
        with self._lock:
            self.stats.n_gets += 1
            self.stats.prefetch_hits += int(hit)
            self.stats.sync_fallbacks += int(fallback)
            self.stats.wait_seconds += wait_seconds

    def get(self, key: str, dtype, shape, *,
            class_name: str | None = None) -> FetchTicket:  # thread: executor
        """Fetch (prefetched or not) and wait for the data to be resident."""
        t0 = time.perf_counter()
        ticket, hit, fallback = self.claim(key, dtype, shape,
                                           class_name=class_name)
        try:
            ticket.wait()
        except BaseException:
            # The ticket left _inflight in claim(), so drain() can no longer
            # see it — release the pool slot here or it leaks for the session.
            ticket.release()
            raise
        self.record_get(hit=hit, fallback=fallback,
                        wait_seconds=time.perf_counter() - t0)
        return ticket

    def drain(self) -> None:  # thread: executor
        """Wait out and release everything in flight (error paths/tests)."""
        with self._lock:
            tickets = list(self._inflight.values())
            self._inflight.clear()
        interrupt = None
        for t in tickets:
            try:
                t.wait()
            except (KeyboardInterrupt, SystemExit) as e:
                interrupt = e   # finish releasing every slot first
            except BaseException:
                # the data is being discarded; a failed read must neither
                # keep later slots checked out nor mask the error that
                # brought us here
                pass
            finally:
                t.release()
        if interrupt is not None:
            raise interrupt
