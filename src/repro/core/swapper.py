"""Parameter swapper: the SSD→host prefetch pipeline (paper Fig. 5/6).

The swapper sits between the tensor store (SSD) and the device: when the
training engine is about to need block *i*'s weights, the swapper has
already (a) checked a pool slot out of the parameter buffer pool, (b) issued
the SSD read into that slot from a worker thread, and keeps (c) a bounded
number of blocks "in flight" — the prefetch depth N that sizes the pool.

The engine calls :meth:`prefetch` ahead of use and :meth:`get` at use time;
``get`` blocks on the outstanding read, hands back a typed numpy view of the
pool slot, and the engine releases the slot once the tensor has been copied
to the device (H2D), returning capacity to the pool — exactly the lifecycle
in §IV-A.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass

import numpy as np

from .buffer_pool import BufferPoolBase, PoolBuffer
from .nvme import TensorStore


@dataclass
class FetchTicket:
    key: str
    buf: PoolBuffer
    future: Future
    dtype: object
    shape: tuple

    def wait(self) -> np.ndarray:
        self.future.result()
        return self.buf.view(self.dtype, self.shape)

    def release(self) -> None:
        self.buf.release()


class ParameterSwapper:
    """Bounded-depth asynchronous SSD→pool prefetcher."""

    def __init__(self, store: TensorStore, pool: BufferPoolBase,
                 *, class_of: dict[str, str] | None = None) -> None:
        self.store = store
        self.pool = pool
        self.class_of = class_of or {}
        self._inflight: dict[str, FetchTicket] = {}
        self._lock = threading.Lock()

    def _shape_class(self, key: str, explicit: str | None) -> str:
        if explicit is not None:
            return explicit
        try:
            return self.class_of[key]
        except KeyError:
            raise KeyError(
                f"no shape class registered for {key!r}; pass class_name=") from None

    def prefetch(self, key: str, dtype, shape, *,
                 class_name: str | None = None) -> FetchTicket:
        """Queue an async read of ``key`` into a pool slot; idempotent."""
        with self._lock:
            if key in self._inflight:
                return self._inflight[key]
        cls = self._shape_class(key, class_name)
        nbytes = int(np.dtype(dtype).itemsize * np.prod(shape, dtype=np.int64))
        buf = self.pool.acquire(cls, nbytes, tag=key)  # may block = backpressure
        out = buf.view(dtype, shape)
        future = self.store.read_async(key, out)
        ticket = FetchTicket(key, buf, future, dtype, shape)
        with self._lock:
            self._inflight[key] = ticket
        return ticket

    def get(self, key: str, dtype, shape, *,
            class_name: str | None = None) -> FetchTicket:
        """Fetch (prefetched or not) and wait for the data to be resident."""
        with self._lock:
            ticket = self._inflight.pop(key, None)
        if ticket is None:
            ticket = self.prefetch(key, dtype, shape, class_name=class_name)
            with self._lock:
                self._inflight.pop(key, None)
        else:
            pass
        ticket.wait()
        return ticket

    def drain(self) -> None:
        """Wait out and release everything in flight (error paths/tests)."""
        with self._lock:
            tickets = list(self._inflight.values())
            self._inflight.clear()
        for t in tickets:
            try:
                t.wait()
            finally:
                t.release()
